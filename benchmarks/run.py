"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale rounds
    PYTHONPATH=src python -m benchmarks.run --only Fig9,Fig14+Table1

Each module reproduces one artifact of the paper and validates the result
against the paper's claims; results land in results/benchmarks.json.
"""

from __future__ import annotations

import argparse
import json
import os
import time
import traceback

from . import (
    bench_abort_curve,
    bench_bandwidth_filtering,
    bench_comm_heatmap,
    bench_compression,
    bench_group_number,
    bench_grouping_strategies,
    bench_long_horizon,
    bench_loss_jitter,
    bench_makespan_cdf,
    bench_makespan_regression,
    bench_scaling_cost_benefit,
    bench_serving,
    bench_skew,
    bench_sync_strategies,
    bench_throughput,
    bench_tiv,
)

MODULES = [
    ("Fig5", bench_tiv),
    ("Fig9", bench_makespan_cdf),
    # tripwire for the transmission engine: the event-driven DAG must never
    # lose to (and on trace topologies must strictly beat) barrier phases
    ("makespan-regression", bench_makespan_regression),
    ("Fig10", bench_comm_heatmap),
    ("Fig11", bench_throughput),
    # staleness-aware OCC: measured commit staleness -> read-abort rate;
    # gates the abort-vs-cadence coupling and the default-off digest identity
    ("abort-curve", bench_abort_curve),
    # read serving plane over the same measured staleness: bounded follower
    # reads, redirect/reject policies, geococo-vs-flat serving throughput
    ("serving", bench_serving),
    # O(E) incremental timeline: 1000-epoch diurnal replay, identity vs the
    # resim oracle, wall-clock scaling gate, vectorized-OCC speedup
    ("long-horizon", bench_long_horizon),
    ("Fig12", bench_grouping_strategies),
    ("Fig13", bench_scaling_cost_benefit),
    ("Fig14+Table1", bench_bandwidth_filtering),
    ("Fig16", bench_compression),
    ("Fig17", bench_loss_jitter),
    ("Fig18", bench_skew),
    ("Fig19", bench_group_number),
    ("sync-strategies", bench_sync_strategies),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated figure names")
    ap.add_argument("--out", default="results/benchmarks.json")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    all_results = {}
    # engine provenance: which simulation engines produced these numbers
    # (the barrier phase-sum stays available everywhere as the regression
    # reference; streaming is exercised/gated by makespan-regression and
    # the Fig11 streaming arm)
    all_results["_engine"] = {
        "wan_simulator": "event-driven fluid-flow DAG",
        "bandwidth_admission": True,
        "barrier_reference": True,
        "streaming": "incremental appendable timeline, O(E) per run "
                     "(StreamingTimeline; stitch-and-resim retained as the "
                     "reference oracle, identity gated in long-horizon; "
                     "makespan-regression + Fig11 streaming arm unchanged)",
        "occ": {
            "validation": "epoch OCC: first-writer-wins incl. read-aborted "
                          "writers (no reinstatement), txn_id tie-break; "
                          "read rule vs epoch-start snapshot",
            "staleness_feedback": "off by default (digest-preserving); "
                                  "abort-curve exercises the feedback loop "
                                  "(per-node views from measured stitched "
                                  "commit times)",
            "raft_throughput": "batches pipelined through one stitched "
                               "leader-schedule stream (leader-NIC "
                               "contention; no linear batch scaling)",
        },
        "serve": {
            "plane": "staleness-bounded follower reads on per-node views "
                     "at measured node_commit_ms times (streaming-only, "
                     "observer: digest/timing-neutral)",
            "policies": "redirect (freshest replica, RTT from the trace) "
                        "/ reject",
            "clients": "analytic region-affine populations (1M/node in "
                       "bench_serving); cache-aside hit mass = top-k Zipf",
            "modeled_cpu": "bytes-proportional filter/zlib CPU for gated "
                           "runs (Fig16 + abort-curve tolerances now exact)",
        },
    }
    n_pass = n_fail = n_err = 0
    t_start = time.perf_counter()
    for name, mod in MODULES:
        if only and name not in only:
            continue
        print(f"\n=== {name}: {mod.__name__} ===")
        t0 = time.perf_counter()
        try:
            res = mod.run(quick=not args.full)
            res["seconds"] = round(time.perf_counter() - t0, 1)
            for c in res.get("checks", []):
                if c["status"] == "PASS":
                    n_pass += 1
                else:
                    n_fail += 1
            all_results[name] = res
        except Exception as e:
            n_err += 1
            print(f"  [ERROR] {type(e).__name__}: {e}")
            all_results[name] = {
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
        print(f"  ({time.perf_counter() - t0:.1f}s)")

    # static-verification provenance: the gate benchmarks (makespan
    # regression, abort curve) run their engines with verify_schedules on,
    # so this counts transfer DAGs that passed repro.analysis.schedule_check
    # with zero violations (a violation raises and lands in n_err above).
    # Snapshot the counter BEFORE the model-check sweep below — its
    # valid-side verification would otherwise inflate the engine count.
    from repro.analysis.schedule_check import verified_schedule_count

    n_schedules_verified = verified_schedule_count()

    # model-checking provenance: a smoke-scope sweep of the bounded
    # explicit-state checker (the full quick tier is the CI lint gate;
    # deep is opt-in), recording violation-free instances per theorem
    print("\n=== modelcheck: repro.analysis.modelcheck (smoke) ===")
    t0 = time.perf_counter()
    from repro.analysis.modelcheck import (
        reset_model_checked_count,
        run_tier,
        scope_for,
    )

    reset_model_checked_count()
    mc = run_tier(scope_for("smoke"))
    if mc.ok:
        n_pass += 1
    else:
        n_fail += 1
        for theorem in mc.theorems:
            for v in theorem.violations:
                print(f"  [FAIL] {v}")
    print(f"  ({time.perf_counter() - t0:.1f}s)")

    all_results["_engine"]["verified"] = {
        "schedule_invariants": "repro.analysis.schedule_check "
                               "(acyclicity, phase monotonicity, epoch "
                               "contiguity, clock chain, payload/node "
                               "bounds)",
        "schedules_verified": n_schedules_verified,
        "model_checked": {
            "scope": "smoke (quick tier gates CI; deep is opt-in)",
            "ok": mc.ok,
            "instances": mc.counts(),
            "selftest_mutants_rejected": mc.mutants_rejected,
        },
    }

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    total = time.perf_counter() - t_start
    print(f"\n==== benchmark summary: {n_pass} checks passed, "
          f"{n_fail} failed, {n_err} errored, {total:.0f}s ====")
    print(f"results -> {args.out}")
    if n_fail or n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
