"""Serving plane: million-user follower reads over stale replica views.

The user-facing payoff of faster synchronization: each of the 5 testbed
nodes fronts 1M region-affine clients issuing staleness-bounded follower
reads against its own (possibly lagging) replica view — the per-node view
the stitched streaming simulation advances at measured ``node_commit_ms``
times.  Sweeps staleness bound x epoch cadence x read/write ratio x
grouping strategy on the Fig. 11 testbed (15 Mbps WAN to Hong Kong,
TPC-C write-intensive mix) and gates:

* served-read throughput monotone non-decreasing, redirect rate monotone
  non-increasing in the staleness bound (exact theorems of the model —
  see ``tests/test_property_serve.py``),
* a slack cadence (sync completes within the epoch window) serves
  everything locally and fresh even at a tight bound,
* GeoCoCo's faster synchronization converts into strictly higher serving
  throughput than the flat baseline at the same staleness bound — the
  serving-plane restatement of the paper's headline,
* the plane is an observer: commit digests are byte-identical with
  serving on or off.
"""

from __future__ import annotations

import numpy as np

from repro.serve import ServeConfig

from .bench_throughput import _run_tpcc
from .common import check, paper_testbed

CLIENTS_PER_NODE = 1_000_000.0


def _serve_cfg(bound: float, *, read_ratio: float = 0.95,
               policy: str = "redirect") -> ServeConfig:
    return ServeConfig(
        clients_per_node=CLIENTS_PER_NODE,
        read_ratio=read_ratio,
        max_staleness_ms=bound,
        policy=policy,
        cache_keys=200,
    )


def _run(trace, regions, *, epochs: int, serve, grouping: bool = True,
         epoch_ms: float = 10.0, planner: str = "milp"):
    rs, _ = _run_tpcc(
        "TPCC-A", grouping, trace, regions, epochs=epochs, streaming=True,
        modeled_cpu=True, epoch_ms=epoch_ms, planner=planner,
        txns_per_node=20, serve=serve,
    )
    return rs


def run(quick: bool = True) -> dict:
    epochs = 24 if quick else 100
    _, regions, trace = paper_testbed(epochs)

    # -- staleness-bound sweep ------------------------------------------------
    bounds = [0.0, 10.0, 25.0, 50.0, 100.0, 200.0, 1e9]
    sweep = {}
    for b in bounds:
        s = _run(trace, regions, epochs=epochs, serve=_serve_cfg(b)).serve
        sweep[b] = s.summary()
    tputs = [sweep[b]["throughput_rps"] for b in bounds]
    redirs = [sweep[b]["redirect_rate"] for b in bounds]
    stales = [sweep[b]["stale_serve_rate"] for b in bounds]

    # -- cadence sweep (fixed 50 ms bound) ------------------------------------
    cadence = {}
    for ems in (5.0, 10.0, 2_000.0):
        s = _run(trace, regions, epochs=epochs, serve=_serve_cfg(50.0),
                 epoch_ms=ems).serve
        cadence[ems] = s.summary()
    slack = cadence[2_000.0]
    tight = cadence[5.0]

    # -- read/write-ratio sweep (staleness is engine-side, so rates must be
    # ratio-invariant and served reads exactly proportional) ------------------
    ratios = (0.5, 0.8, 0.95)
    ratio_runs = {
        r: _run(trace, regions, epochs=epochs,
                serve=_serve_cfg(50.0, read_ratio=r)).serve
        for r in ratios
    }
    offered = {
        r: 5 * CLIENTS_PER_NODE * r * (10.0 / 1e3) * epochs for r in ratios
    }
    prop = [ratio_runs[r].served_reads / r for r in ratios]

    # -- policy comparison ----------------------------------------------------
    rej = _run(trace, regions, epochs=epochs,
               serve=_serve_cfg(50.0, policy="reject")).serve
    red = _run(trace, regions, epochs=epochs, serve=_serve_cfg(50.0)).serve

    # -- grouping strategies at the same bound --------------------------------
    strategies = {}
    for label, grouping, planner in (
        ("geococo", True, "milp"),
        ("geococo-kcenter", True, "kcenter"),
        ("flat", False, "milp"),
    ):
        rs = _run(trace, regions, epochs=epochs, serve=_serve_cfg(50.0),
                  grouping=grouping, planner=planner)
        strategies[label] = {
            "throughput_rps": rs.serve.throughput_rps,
            "reject_rate": rs.serve.reject_rate,
            "wall_s": rs.serve.wall_ms / 1e3,
            "state_digest": rs.state_digest,
        }

    # -- observer regression --------------------------------------------------
    on = _run(trace, regions, epochs=epochs, serve=_serve_cfg(50.0))
    off = _run(trace, regions, epochs=epochs, serve=None)

    checks = [
        check(all(b >= a - 1e-9 for a, b in zip(tputs, tputs[1:])),
              "serving: throughput monotone non-decreasing in staleness bound",
              " -> ".join(f"{t/1e3:.0f}k" for t in tputs)),
        check(all(b <= a + 1e-12 for a, b in zip(redirs, redirs[1:])),
              "serving: redirect rate monotone non-increasing in bound",
              " -> ".join(f"{r:.2f}" for r in redirs)),
        check(all(b >= a - 1e-12 for a, b in zip(stales, stales[1:])),
              "serving: stale-serve rate monotone non-decreasing in bound",
              " -> ".join(f"{r:.2f}" for r in stales)),
        check(tputs[-1] > tputs[0],
              "serving: the bound sweep spans starved -> fully served",
              f"{tputs[0]/1e3:.0f}k -> {tputs[-1]/1e3:.0f}k rps"),
        check(slack["redirect_rate"] == 0.0 and slack["reject_rate"] == 0.0
              and slack["stale_serve_rate"] == 0.0,
              "serving: slack cadence (sync < epoch window) serves all reads "
              "locally and fresh"),
        check(tight["reject_rate"] > slack["reject_rate"],
              "serving: WAN backlog at tight cadence starves bounded reads",
              f"reject {tight['reject_rate']:.2f} @5ms vs "
              f"{slack['reject_rate']:.2f} @2s"),
        check(all(abs(ratio_runs[r].reads_total - offered[r]) < 1e-6 * offered[r]
                  for r in ratios)
              and all(abs(p - prop[0]) < 1e-6 * max(prop[0], 1.0) for p in prop)
              and all(abs(ratio_runs[r].reject_rate
                          - ratio_runs[ratios[0]].reject_rate) < 1e-12
                      for r in ratios),
              "serving: offered load matches the closed form; rates are "
              "read-ratio-invariant (staleness is engine-side)"),
        check(red.served_reads >= rej.served_reads
              and rej.redirected == 0.0 and red.redirected > 0.0,
              "serving: redirecting to the freshest replica serves at least "
              "as many reads as rejecting outright",
              f"redirect {red.served_reads:.0f} vs reject {rej.served_reads:.0f}"),
        check(red.read_latency_p99_ms >= red.read_latency_p50_ms
              and red.read_latency_p99_ms > rej.read_latency_p99_ms,
              "serving: redirected reads pay the WAN RTT in the latency tail",
              f"redirect p99 {red.read_latency_p99_ms:.1f} ms vs reject p99 "
              f"{rej.read_latency_p99_ms:.1f} ms"),
        check(strategies["geococo"]["throughput_rps"]
              > strategies["flat"]["throughput_rps"],
              "serving: GeoCoCo strictly beats flat serving throughput at the "
              "same bound (faster sync -> fresher views -> more served reads)",
              f"{strategies['geococo']['throughput_rps']/1e3:.0f}k vs "
              f"{strategies['flat']['throughput_rps']/1e3:.0f}k rps"),
        check(strategies["geococo"]["state_digest"]
              == strategies["flat"]["state_digest"],
              "serving: grouping strategies commit byte-identical state"),
        check(on.state_digest == off.state_digest
              and on.wan_bytes == off.wan_bytes
              and [e.wall_ms for e in on.epochs]
              == [e.wall_ms for e in off.epochs],
              "serving: the plane is an observer — digests, WAN bytes and "
              "timing identical with serving on or off"),
    ]
    for s in strategies.values():
        s.pop("state_digest")
    return {
        "figure": "serving",
        "bound_sweep": {str(b): v for b, v in sweep.items()},
        "cadence_sweep": {str(k): v for k, v in cadence.items()},
        "ratio_sweep": {str(r): ratio_runs[r].summary() for r in ratios},
        "policies": {"redirect": red.summary(), "reject": rej.summary()},
        "strategies": strategies,
        "clients": {"per_node": CLIENTS_PER_NODE, "nodes": 5,
                    "total": 5 * CLIENTS_PER_NODE},
        "checks": checks,
    }


if __name__ == "__main__":
    run(quick=False)
