"""Paper Fig. 18: impact of Zipfian access skew under two read/write mixes.

theta in {0.5..0.9} x {95/5 read-heavy, 50/50 balanced}.  Paper: GeoCoCo
sustains 7.2-17.6% gains through moderate skew and stays >= baseline at
extreme skew (theta=0.9).
"""

from __future__ import annotations

import numpy as np

from .common import check, run_engine, wan_cluster


def run(quick: bool = True) -> dict:
    n = 8
    epochs = 15 if quick else 60
    lat, regions, _, trace = wan_cluster(n, epochs, seed=61)
    thetas = [0.5, 0.7, 0.9] if quick else [0.5, 0.6, 0.7, 0.8, 0.9]
    out = {}
    for read_ratio, label in ((0.95, "95/5"), (0.50, "50/50")):
        row = {}
        for th in thetas:
            kw = dict(
                n=n, trace=trace, regions=regions, bandwidth=120.0,
                theta=th, read_ratio=read_ratio, hot_write_frac=0.15,
                txns_per_node=14, n_keys=20_000,
            )
            base = run_engine(grouping=False, filtering=False, tiv=False, **kw)
            geo = run_engine(grouping=True, filtering=True, **kw)
            row[th] = {
                "base_tps": base.throughput_tps,
                "geo_tps": geo.throughput_tps,
                "gain": geo.throughput_tps / base.throughput_tps - 1.0,
                "consistent": base.state_digest == geo.state_digest,
            }
        out[label] = row

    all_cells = [v for row in out.values() for v in row.values()]
    checks = [
        check(all(c["consistent"] for c in all_cells),
              "Fig18: consistency across all skew/mix cells"),
        check(all(c["gain"] > -0.02 for c in all_cells),
              "Fig18: never materially worse than baseline",
              f"min gain {min(c['gain'] for c in all_cells):+.1%}"),
        check(sum(c["gain"] > 0.03 for c in all_cells) >= len(all_cells) * 0.6,
              "Fig18: clear gains in the moderate-skew regime (paper 7-18%)",
              ", ".join(
                  f"{lbl} θ={th}: {v['gain']:+.1%}"
                  for lbl, row in out.items() for th, v in row.items()
              )),
    ]
    return {
        "figure": "Fig18",
        "results": {lbl: {str(k): v for k, v in row.items()}
                    for lbl, row in out.items()},
        "checks": checks,
    }


if __name__ == "__main__":
    run(quick=False)
