"""Paper Fig. 16: stacking GeoCoCo with zlib compression.

Normalized makespan of one synchronization round under {Baseline, zlib,
GeoCoCo, GeoCoCo+zlib} on a bandwidth-constrained WAN.  Paper: zlib alone
-54%, GeoCoCo larger, the combination ~33.6% of baseline (complementary
dimensions stack).
"""

from __future__ import annotations

import numpy as np

from .common import check, run_engine, wan_cluster


def run(quick: bool = True) -> dict:
    n = 8
    epochs = 20 if quick else 80
    lat, regions, _, trace = wan_cluster(n, epochs, seed=41)
    kw = dict(
        n=n, trace=trace, regions=regions, bandwidth=40.0,  # bandwidth-bound
        theta=0.7, hot_write_frac=0.35, rewrite_frac=0.10,
        txns_per_node=15 if quick else 25, n_keys=20_000,
        # bytes-proportional filter/zlib CPU model: the gated comparison is
        # deterministic, so the stacking check below can be exact
        modeled_cpu=True,
    )
    runs = {
        "baseline": run_engine(grouping=False, filtering=False, tiv=False, **kw),
        "zlib": run_engine(grouping=False, filtering=False, tiv=False,
                           compression=True, **kw),
        "geococo": run_engine(grouping=True, filtering=True, **kw),
        "geococo+zlib": run_engine(grouping=True, filtering=True,
                                   compression=True, **kw),
    }
    base = runs["baseline"].makespans_ms.mean()
    norm = {k: float(v.makespans_ms.mean() / base) for k, v in runs.items()}
    digests = {k: v.state_digest for k, v in runs.items()}

    checks = [
        check(len(set(digests.values())) == 1,
              "Fig16: all four configurations converge to identical state"),
        check(norm["zlib"] < 1.0,
              "Fig16: compression alone reduces makespan (paper -54%)",
              f"zlib {norm['zlib']:.2f}x"),
        check(norm["geococo"] < norm["zlib"] + 0.15,
              "Fig16: GeoCoCo comparable/better than compression alone",
              f"geococo {norm['geococo']:.2f}x"),
        # exact gate (1e-9): with modeled_cpu the zlib/filter CPU is
        # bytes-proportional and deterministic, so the former 0.015
        # measured-wall-clock noise allowance is gone — the stacking margin
        # is now a property of the model, not of harness load
        check(norm["geococo+zlib"]
              <= min(norm["zlib"], norm["geococo"]) + 1e-9,
              "Fig16: the combination beats either alone (they stack; "
              "exact under modeled CPU)",
              f"combo {norm['geococo+zlib']:.2f}x"),
        check(norm["geococo+zlib"] <= 0.55,
              "Fig16: combo in the paper's band (paper: 33.6% of baseline)",
              f"{norm['geococo+zlib']:.1%} of baseline"),
    ]
    return {"figure": "Fig16", "normalized_makespan": norm, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
