"""Long-horizon streaming: O(E) incremental timeline + vectorized OCC.

Before this module's tentpole, every epoch of a streaming feedback run
re-stitched and re-simulated the entire prefix (``_stream_prefix``), making
an E-epoch run O(E^2) in simulated transfers — 1000-epoch traces were
unreachable.  The :class:`repro.core.stream.StreamingTimeline` keeps the
event-engine state (NIC clear floors + frontier finish times) alive across
``append_epoch`` calls and simulates only the new epoch's transfers, which
the bandwidth-admission theorem makes *byte-identical* to the full
re-simulation (``tests/test_streaming.py`` pins this exactly).

Gates:

* **identity** — an abort-curve-testbed prefix run twice, once with
  ``stream_mode="incremental"`` and once with the retained ``"resim"``
  oracle, produces identical digests, per-epoch commit walls and abort
  breakdowns.
* **trajectory** — a 1000-epoch (quick: 300) diurnal replay: TPC-C load
  modulated by a sinusoidal day cycle (:class:`repro.core.workload.
  DiurnalLoad`); the staleness-feedback read-abort rate must *track* the
  cycle — peak-load phases abort more than trough phases — instead of
  saturating, which is what the long horizon exists to show.
* **scaling** — doubling the horizon costs ~2x wall-clock (O(E)), not ~4x
  (the old O(E^2)).  Gate: ``t(2E) <= 2.5 * t(E)`` with real wall time.
* **occ-vectorized** — ``validate_epoch_detailed``'s numpy fast path beats
  the reference loop on a >=100k-txn epoch while returning an identical
  :class:`~repro.core.occ.ValidationResult`.
* **memory** — O(E) *time* is only half the long-horizon story: with
  ``EngineConfig(keep_epochs=False)`` + ``ServeConfig(keep_epochs=False)``
  the epoch-sink pipeline (``repro.core.sinks``) retains no per-epoch
  state beyond the view/retention frontiers, so doubling the horizon must
  leave the tracemalloc peak flat — gate ``peak(2E) <= 1.1 * peak(E)``
  (the trace itself is a fixed one-day cycle, so input memory is constant
  too).
* **equivalence** — the bounded-memory run's online ``RunSummary``,
  state/value digests, ``ServeStats`` totals/latency distribution and
  trailing ``EpochStats`` window are byte-identical to the retained
  ``keep_epochs=True`` run of the same replay.
"""

from __future__ import annotations

import time
import tracemalloc

import numpy as np

from repro.core import DeltaCRDTStore, Update, Version
from repro.core.occ import Txn, validate_epoch_detailed
from repro.core.workload import DiurnalLoad
from repro.serve import ServeConfig

from .bench_abort_curve import PLANNER
from .bench_throughput import _run_tpcc
from .common import check, paper_testbed

# the abort-curve saturation-boundary cadence: slack enough that the view
# lag breathes with the load cycle instead of diverging (at the native
# 10 ms cadence a 1000-epoch feedback run saturates: abort rate > 0.9
# regardless of load phase, which gates nothing)
DIURNAL_EPOCH_MS = 80.0
DIURNAL_PERIOD = 100       # epochs per simulated "day"
DIURNAL_AMPLITUDE = 0.6    # load swings 0.4x..1.6x around the mean


def _diurnal_run(epochs: int, trace, regions):
    diurnal = {}

    def wrap(gen):
        load = DiurnalLoad(gen, period_epochs=DIURNAL_PERIOD,
                           amplitude=DIURNAL_AMPLITUDE)
        diurnal["load"] = load
        return load

    t0 = time.perf_counter()
    rs, _ = _run_tpcc("TPCC-A", True, trace, regions, epochs=epochs,
                      streaming=True, staleness_feedback=True,
                      epoch_ms=DIURNAL_EPOCH_MS, planner=PLANNER,
                      modeled_cpu=True, load=wrap)
    wall = time.perf_counter() - t0
    return rs, diurnal["load"], wall


def _bounded_run(epochs: int, trace, regions, *, keep_epochs: bool,
                 traced: bool = False):
    """One diurnal feedback + serving replay through the epoch-sink
    pipeline.  ``keep_epochs=False`` is the bounded-memory configuration
    (trailing stats window, online summaries, evicting timeline/serve
    sinks); ``keep_epochs=True`` the retained reference.  With ``traced``
    the tracemalloc peak over the run is returned (bytes, else 0)."""
    serve = ServeConfig(clients_per_node=1_000_000.0, max_staleness_ms=200.0,
                        cache_keys=100, keep_epochs=keep_epochs)

    def wrap(gen):
        return DiurnalLoad(gen, period_epochs=DIURNAL_PERIOD,
                           amplitude=DIURNAL_AMPLITUDE)

    if traced:
        tracemalloc.start()
    try:
        rs, _ = _run_tpcc("TPCC-A", True, trace, regions, epochs=epochs,
                          streaming=True, staleness_feedback=True,
                          epoch_ms=DIURNAL_EPOCH_MS, planner=PLANNER,
                          modeled_cpu=True, serve=serve,
                          keep_epochs=keep_epochs, load=wrap)
        peak = tracemalloc.get_traced_memory()[1] if traced else 0
    finally:
        if traced:
            tracemalloc.stop()
    return rs, peak


def run(quick: bool = True) -> dict:
    horizon = 300 if quick else 1000
    base, regions, trace = paper_testbed(horizon)

    # --- identity: incremental timeline vs the O(E^2) resim oracle -------
    pre = 12
    kw = dict(epochs=pre, streaming=True, staleness_feedback=True,
              epoch_ms=10.0, planner=PLANNER, modeled_cpu=True,
              verify_schedules=True)
    inc, _ = _run_tpcc("TPCC-A", True, trace, regions,
                       stream_mode="incremental", **kw)
    ref, _ = _run_tpcc("TPCC-A", True, trace, regions,
                       stream_mode="resim", **kw)
    same_epochs = all(
        # exact float equality is the point: the incremental timeline is
        # byte-identical to the oracle, not merely close
        (a.stream_commit_ms == b.stream_commit_ms  # lint: allow[float-time-eq]
         and a.wall_ms == b.wall_ms  # lint: allow[float-time-eq]
         and a.read_aborts == b.read_aborts
         and a.ww_aborts == b.ww_aborts
         and a.view_lag_mean == b.view_lag_mean
         and a.view_lag_max == b.view_lag_max)
        for a, b in zip(inc.epochs, ref.epochs)
    )
    identity_ok = (inc.state_digest == ref.state_digest
                   and inc.value_digest == ref.value_digest
                   and same_epochs)

    # --- trajectory + scaling: the diurnal replay itself is the 2E leg ---
    half_rs, _, t_half = _diurnal_run(horizon // 2, trace, regions)
    rs, load, t_full = _diurnal_run(horizon, trace, regions)

    lf = np.array([load.load_factor(e.epoch) for e in rs.epochs])
    rates = np.array([e.read_aborts / e.n_txns if e.n_txns else 0.0
                      for e in rs.epochs])
    # skip the first day: the pipeline warms up from empty NICs
    settled = np.arange(len(rs.epochs)) >= DIURNAL_PERIOD
    peak = float(rates[settled & (lf > 1.1)].mean())
    trough = float(rates[settled & (lf < 0.9)].mean())
    ratio = t_full / t_half

    # --- memory + equivalence: bounded epoch-sink pipeline ---------------
    # a fixed one-day trace cycled by EpochLatencyCycle keeps input memory
    # constant across horizons, so the tracemalloc peak isolates run-state
    # retention: with keep_epochs=False it must stay flat when the horizon
    # doubles
    mem_trace = trace[:DIURNAL_PERIOD]
    mem_half, peak_half = _bounded_run(horizon // 2, mem_trace, regions,
                                       keep_epochs=False, traced=True)
    mem_full, peak_full = _bounded_run(horizon, mem_trace, regions,
                                       keep_epochs=False, traced=True)
    mem_ratio = peak_full / peak_half
    ref_rs, _ = _bounded_run(horizon // 2, mem_trace, regions,
                             keep_epochs=True)
    serve_eq = (
        mem_half.serve.summary() == ref_rs.serve.summary()
        and mem_half.serve.totals == ref_rs.serve.totals
        and np.array_equal(mem_half.serve.latency_values_ms,
                           ref_rs.serve.latency_values_ms)
        and np.array_equal(mem_half.serve.latency_weights,
                           ref_rs.serve.latency_weights)
    )
    window_eq = (len(mem_half.epochs) < len(ref_rs.epochs)
                 and mem_half.epochs == ref_rs.epochs[-len(mem_half.epochs):])
    equivalence_ok = (
        mem_half.summary == ref_rs.summary
        and mem_half.state_digest == ref_rs.state_digest
        and mem_half.value_digest == ref_rs.value_digest
        and serve_eq and window_eq
    )

    # --- occ-vectorized: >=100k-txn epoch, identical result, faster ------
    # mostly-fresh reads (the common regime: only ~5% of reads versioned
    # stale), 3 reads + 2 contended writes per transaction
    rng = np.random.default_rng(7)
    n_txns, n_keys = 100_000, 5_000
    snap = DeltaCRDTStore()
    sv = {}
    for j in range(n_keys):
        v = Version(1, int(rng.integers(40)), int(rng.integers(5)))
        snap.apply(Update(f"k{j}", b"s", v))
        sv[f"k{j}"] = v
    key_draw = rng.integers(n_keys, size=(n_txns, 5))
    stale_txn = rng.random(n_txns) < 0.05
    txns = [
        Txn(txn_id=i, node=int(i % 5), epoch=2, seq=i // 5,
            read_set=tuple(
                (f"k{k}", Version.ZERO if (stale_txn[i] and j == 0)
                 else sv[f"k{k}"])
                for j, k in enumerate(key_draw[i, :3])
            ),
            write_set=tuple((f"k{k}", b"w") for k in key_draw[i, 3:]))
        for i in range(n_txns)
    ]
    t0 = time.perf_counter()
    res_py = validate_epoch_detailed(txns, snap, mode="python")
    t_py = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_np = validate_epoch_detailed(txns, snap, mode="numpy")
    t_np = time.perf_counter() - t0
    speedup = t_py / t_np

    checks = [
        check(identity_ok,
              "identity: incremental timeline == resim oracle on the "
              "abort-curve prefix (digests + per-epoch commits/aborts/lag)",
              f"{pre} epochs at 10 ms cadence, schedules verified"),
        check(peak > trough,
              "trajectory: read-abort rate tracks the diurnal load cycle "
              "(peak phases abort more than trough phases)",
              f"peak {peak:.3f} vs trough {trough:.3f} over "
              f"{horizon} epochs"),
        check(rates[settled].mean() < 0.8,
              "trajectory: the long horizon breathes instead of saturating",
              f"settled mean read-abort rate {rates[settled].mean():.3f}"),
        check(ratio <= 2.5,
              "scaling: doubling the horizon costs ~2x wall (O(E)), "
              "not ~4x (the old O(E^2) re-simulation)",
              f"{horizon // 2}ep {t_half:.1f}s -> {horizon}ep {t_full:.1f}s "
              f"({ratio:.2f}x)"),
        check(mem_ratio <= 1.1,
              "memory: keep_epochs=False holds the tracemalloc peak flat "
              "when the horizon doubles (frontier-bounded retention)",
              f"{horizon // 2}ep {peak_half / 1e6:.1f}MB -> {horizon}ep "
              f"{peak_full / 1e6:.1f}MB ({mem_ratio:.3f}x)"),
        check(equivalence_ok,
              "equivalence: bounded run's online summary, digests, serve "
              "totals/latency distribution and trailing epoch window are "
              "byte-identical to the retained run",
              f"{horizon // 2} epochs, window {len(mem_half.epochs)}"),
        check(res_py == res_np,
              "occ-vectorized: numpy fast path returns an identical "
              "ValidationResult at 100k txns",
              f"{len(res_py.committed)} committed, "
              f"{len(res_py.aborted)} aborted"),
        check(speedup > 1.1,
              "occ-vectorized: measured speedup over the reference loop",
              f"python {t_py:.2f}s vs numpy {t_np:.2f}s ({speedup:.2f}x)"),
    ]
    return {
        "figure": "long-horizon",
        "identity": {"epochs": pre, "ok": identity_ok},
        "diurnal": {
            "horizon": horizon, "epoch_ms": DIURNAL_EPOCH_MS,
            "period_epochs": DIURNAL_PERIOD, "amplitude": DIURNAL_AMPLITUDE,
            "read_abort_peak": peak, "read_abort_trough": trough,
            "read_abort_mean": float(rates[settled].mean()),
            "view_lag_max": max(e.view_lag_max for e in rs.epochs),
            "committed": rs.committed, "total_txns": rs.total_txns,
        },
        "scaling": {"epochs": [horizon // 2, horizon],
                    "wall_s": [round(t_half, 2), round(t_full, 2)],
                    "ratio": round(ratio, 3)},
        "memory": {"epochs": [horizon // 2, horizon],
                   "peak_mb": [round(peak_half / 1e6, 2),
                               round(peak_full / 1e6, 2)],
                   "ratio": round(mem_ratio, 3)},
        "equivalence": {"epochs": horizon // 2,
                        "window": len(mem_half.epochs),
                        "ok": equivalence_ok},
        "occ": {"n_txns": n_txns, "n_keys": n_keys,
                "python_s": round(t_py, 3), "numpy_s": round(t_np, 3),
                "speedup": round(speedup, 2)},
        "checks": checks,
    }


if __name__ == "__main__":
    run(quick=False)
