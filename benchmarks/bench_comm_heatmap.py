"""Paper Fig. 10: communication-frequency heatmap, 7 nodes x 400 rounds.

Baseline all-to-all vs GeoCoCo hierarchical transmission.  Paper claims:
communication concentrates on a few aggregation nodes, yet every node's
total message count stays below the baseline's per-node count.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    Replanner,
    WANSimulator,
    all_to_all_schedule,
    best_plan,
    hierarchical_schedule,
)
from repro.core.latency import GeoClusterSpec, geo_clustered_matrix, jitter_trace

from .common import check


def run(quick: bool = True) -> dict:
    n, rounds = 7, (150 if quick else 400)
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=3), np.random.default_rng(5)
    )
    trace = jitter_trace(lat, rounds, np.random.default_rng(6))
    from .common import lan_wan_bandwidth

    bw = lan_wan_bandwidth(regions, n, 100.0)
    payload = 100_000.0
    rp = Replanner(lambda l: best_plan(l, tiv=True, method="milp",
                                       payload_bytes=payload,
                                       bandwidth_mbps=bw))

    base_msgs = np.zeros((n, n), dtype=int)
    geo_msgs = np.zeros((n, n), dtype=int)
    for f in trace:
        sim = WANSimulator(f, bw)
        base_msgs += sim.run(all_to_all_schedule(n, payload)).msg_matrix
        plan = rp.observe(f)
        geo_msgs += sim.run(
            hierarchical_schedule(plan, payload, lat=f, tiv=True)
        ).msg_matrix

    base_per_node = base_msgs.sum(0) + base_msgs.sum(1)
    geo_per_node = geo_msgs.sum(0) + geo_msgs.sum(1)
    concentration = float(np.sort(geo_per_node)[-3:].sum() / geo_per_node.sum())

    checks = [
        check(bool((geo_per_node <= base_per_node.max()).all()),
              "Fig10: every node's message count <= baseline max",
              f"geo max {geo_per_node.max()} vs base max {base_per_node.max()}"),
        check(geo_msgs.sum() < base_msgs.sum(),
              "Fig10: total messages reduced",
              f"{base_msgs.sum()} -> {geo_msgs.sum()}"),
        check(concentration > 0.5,
              "Fig10: traffic concentrates on aggregation nodes",
              f"top-3 nodes carry {concentration:.0%}"),
    ]
    return {
        "figure": "Fig10",
        "baseline_matrix": base_msgs.tolist(),
        "geococo_matrix": geo_msgs.tolist(),
        "per_node": {"baseline": base_per_node.tolist(),
                     "geococo": geo_per_node.tolist()},
        "checks": checks,
    }


if __name__ == "__main__":
    run(quick=False)
