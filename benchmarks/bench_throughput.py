"""Paper Fig. 11: end-to-end throughput.

(a) GeoGauss plane: 5-node testbed (2 Kalgan + 2 Hohhot + 1 Hong Kong),
TPC-C mixes A-D, tpmTOTAL with vs without GeoCoCo.  Paper: +14.1% on the
write-intensive mix, +8.1%..+11.4% elsewhere.

(b) CockroachDB plane: Raft AppendEntries relayed through group aggregators,
YCSB-style replicated batches.  Paper: up to +11.5% throughput.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    EngineConfig,
    GeoCluster,
    RaftCluster,
    TPCCConfig,
    TPCCGenerator,
)

from .common import check, paper_testbed


def _run_tpcc(mix: str, grouping: bool, trace, regions, *, epochs: int, seed=3,
              streaming: bool = False, staleness_feedback: bool = False,
              epoch_ms: float = 10.0, planner: str = "milp",
              modeled_cpu: bool = False, serve=None, txns_per_node: int = 40,
              verify_schedules: bool = False, stream_mode: str = "incremental",
              keep_epochs: bool = True, stats_window: int = 64, load=None):
    """Paper regime: Alibaba-cloud 5-node testbed, WAN bandwidth in the
    Fig. 3 constrained band (~15 Mbps to HK), 100 warehouses with hot item
    contention "to stress inter-node coordination" (Sec 6.3)."""
    import numpy as np

    from .common import lan_wan_bandwidth

    n = 5
    cfg = EngineConfig(
        n_nodes=n, grouping=grouping, filtering=grouping, tiv=grouping,
        planner=planner, epoch_ms=epoch_ms, streaming=streaming,
        staleness_feedback=staleness_feedback,
        modeled_cpu=modeled_cpu, serve=serve,
        verify_schedules=verify_schedules, stream_mode=stream_mode,
        keep_epochs=keep_epochs, stats_window=stats_window,
    )
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    eng = GeoCluster(
        cfg, bandwidth_mbps=lan_wan_bandwidth(regions, n, 15.0),
        wan_mask=wan, seed=seed,
    )
    gen = TPCCGenerator(
        TPCCConfig(n_warehouses=100, mix=mix, remote_prob=0.25,
                   items_per_warehouse=50),
        n, seed=seed,
    )
    if load is not None:
        gen = load(gen)
    rs = eng.run(gen, trace, txns_per_node=txns_per_node, n_epochs=epochs)
    tpm_total = rs.throughput_tps * 60.0
    return rs, tpm_total


def run(quick: bool = True) -> dict:
    epochs = 40 if quick else 200
    _, regions, trace = paper_testbed(epochs)

    geogauss = {}
    geo_a_rs = None
    for mix in ("TPCC-A", "TPCC-B", "TPCC-C", "TPCC-D"):
        base_rs, base_tpm = _run_tpcc(mix, False, trace, regions, epochs=epochs)
        geo_rs, geo_tpm = _run_tpcc(mix, True, trace, regions, epochs=epochs)
        if mix == "TPCC-A":
            geo_a_rs = geo_rs  # reused by the streaming arm below
        gain = geo_tpm / base_tpm - 1.0
        geogauss[mix] = {
            "tpmTotal_base": base_tpm,
            "tpmTotal_geococo": geo_tpm,
            "gain": gain,
            "wan_reduction": 1.0 - geo_rs.wan_bytes / base_rs.wan_bytes,
            "state_consistent": base_rs.state_digest == geo_rs.state_digest,
        }

    # streaming arm (engine regime comparison on the write-intensive mix):
    # the measured cross-epoch pipeline vs the max(epoch, exec, sync)
    # formula, same workload/plan machinery
    stream_rs, stream_tpm = _run_tpcc("TPCC-A", True, trace, regions,
                                      epochs=epochs, streaming=True)
    streaming = {
        "tpmTotal_geococo_streaming": stream_tpm,
        "wall_s_formula": geo_a_rs.wall_s,
        "wall_s_streaming": stream_rs.wall_s,
        "pipeline_overlap_ms": stream_rs.pipeline_overlap_ms,
        "state_consistent": stream_rs.state_digest == geo_a_rs.state_digest,
        # abort breakdown: default staleness_feedback=False keeps the read
        # rule vacuous (the abort-curve module exercises the feedback arm)
        "read_aborts": stream_rs.read_aborts,
        "ww_aborts": stream_rs.ww_aborts,
    }

    # CRDB plane: modeled Raft batches over a 9-node WAN
    from .common import wan_cluster

    lat, regions9, bw, trace9 = wan_cluster(9, 30 if quick else 120, seed=11)
    crdb = {}
    for wl, payload in {"YCSB-A": 64_000.0, "YCSB-B": 24_000.0,
                        "YCSB-C": 12_000.0, "YCSB-D": 24_000.0}.items():
        t_base = RaftCluster(9, grouping=False, tiv=False).throughput(
            trace9, payload_bytes=payload
        )
        t_geo = RaftCluster(9, grouping=True, tiv=True).throughput(
            trace9, payload_bytes=payload
        )
        crdb[wl] = {"base": t_base, "geococo": t_geo, "gain": t_geo / t_base - 1.0}

    gains = [v["gain"] for v in geogauss.values()]
    checks = [
        check(all(v["state_consistent"] for v in geogauss.values()),
              "Fig11a: final replicated state identical with/without GeoCoCo"),
        check(all(g > -0.02 for g in gains),
              "Fig11a: no mix materially regresses; write mixes gain",
              ", ".join(f"{m}={v['gain']:+.1%}" for m, v in geogauss.items())),
        check(geogauss["TPCC-A"]["gain"] == max(gains),
              "Fig11a: largest gain on the write-intensive mix (paper: 14.1%)",
              f"TPCC-A {geogauss['TPCC-A']['gain']:+.1%}"),
        check(0.08 <= max(gains) <= 0.40,
              "Fig11a: peak gain in/near the paper's band (paper 14.1%)",
              f"max {max(gains):+.1%}"),
        check(abs(geogauss["TPCC-A"]["wan_reduction"] - 0.403) < 0.12,
              "Fig11a: WAN cost reduction matches the paper's 40.3% headline",
              f"{geogauss['TPCC-A']['wan_reduction']:.1%}"),
        check(all(v["gain"] > 0 for v in crdb.values()),
              "Fig11b: CRDB-plane gains positive (paper: up to 11.5%)",
              ", ".join(f"{m}={v['gain']:+.1%}" for m, v in crdb.items())),
        check(streaming["state_consistent"],
              "Fig11a streaming arm: stitched engine commits byte-identical "
              "state"),
        check(streaming["wall_s_streaming"]
              <= streaming["wall_s_formula"] * 1.01,
              "Fig11a streaming arm: measured cross-epoch pipeline within 1% "
              "of (or better than) the formula wall-clock",
              f"formula {streaming['wall_s_formula']:.2f}s vs streaming "
              f"{streaming['wall_s_streaming']:.2f}s"),
    ]
    return {"figure": "Fig11", "geogauss": geogauss, "crdb": crdb,
            "streaming": streaming,
            "engine": {"formula": "max(epoch, exec, sync) per epoch",
                       "streaming": "stitched cross-epoch DAG"},
            "checks": checks}


if __name__ == "__main__":
    run(quick=False)
