"""Paper Fig. 14 + Table 1: WAN bandwidth reduction vs conflict ratio.

YCSB with calibrated conflict ratios (hot-set contention) at ~5/10/20/30/40%.
Paper: WAN traffic drops 8.7/27.2/32.2/35.7/40.3% monotonically; filtering
costs <2.8% CPU and ~0% at conflict-free; p99 shifts <= ~13 ms.
"""

from __future__ import annotations

import numpy as np

from .common import check, run_engine, wan_cluster


# hot_write_frac values calibrated to land near the paper's conflict ratios
_CONFLICT_KNOBS = [
    (0.00, 0.0),   # conflict-free control (Table 1 row 1)
    (0.05, 0.08),
    (0.10, 0.16),
    (0.20, 0.33),
    (0.30, 0.52),
    (0.40, 0.75),
]


def run(quick: bool = True) -> dict:
    n = 8
    epochs = 25 if quick else 120
    txns = 12 if quick else 25
    lat, regions, bw, trace = wan_cluster(n, epochs, seed=31)

    rows = []
    for target, hot in _CONFLICT_KNOBS:
        base = run_engine(
            n=n, trace=trace, regions=regions, grouping=True, filtering=False,
            hot_write_frac=hot, rewrite_frac=0.10, txns_per_node=txns,
            theta=0.6, n_keys=50_000,
        )
        geo = run_engine(
            n=n, trace=trace, regions=regions, grouping=True, filtering=True,
            hot_write_frac=hot, rewrite_frac=0.10, txns_per_node=txns,
            theta=0.6, n_keys=50_000,
        )
        achieved_conflict = 1.0 - geo.committed / max(geo.total_txns, 1)
        reduction = 1.0 - geo.wan_bytes / base.wan_bytes
        n_updates = geo.white_stats.total_updates
        cpu_per_update_us = (
            sum(e.filter_cpu_ms for e in geo.epochs) * 1e3 / max(n_updates, 1)
        )
        rows.append({
            "target_conflict": target,
            "achieved_conflict": achieved_conflict,
            "wan_reduction": reduction,
            "white_byte_ratio": geo.white_stats.white_byte_ratio,
            "filter_cpu_us_per_update": cpu_per_update_us,
            "p99_delta_ms": geo.p99_sync_ms - base.p99_sync_ms,
            "state_consistent": base.state_digest == geo.state_digest,
        })

    reductions = [r["wan_reduction"] for r in rows]
    checks = [
        check(all(r["state_consistent"] for r in rows),
              "Fig14: filtering is lossless at every conflict level"),
        check(all(reductions[i] <= reductions[i + 1] + 0.03
                  for i in range(1, len(reductions) - 1)),
              "Fig14: WAN reduction grows monotonically with conflict ratio",
              ", ".join(f"{r['target_conflict']:.0%}->{r['wan_reduction']:.1%}"
                        for r in rows)),
        check(rows[0]["wan_reduction"] < 0.12,
              "Table1: near-zero saving on the conflict-free workload",
              f"{rows[0]['wan_reduction']:.1%}"),
        check(reductions[-1] >= 0.30,
              "Fig14: >=30% WAN reduction at the highest conflict (paper 40.3%)",
              f"{reductions[-1]:.1%}"),
        check(
            max(r["filter_cpu_us_per_update"] for r in rows)
            < 5.0 * max(min(r["filter_cpu_us_per_update"] for r in rows), 1e-3),
            "Table1: O(1) filtering — per-update cost flat across conflict "
            "ratios (paper: constant-time version/hash checks)",
            ", ".join(f"{r['target_conflict']:.0%}:"
                      f"{r['filter_cpu_us_per_update']:.1f}us" for r in rows),
        ),
    ]
    return {"figure": "Fig14+Table1", "rows": rows, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
