"""Paper Fig. 17: robustness under WAN loss and jitter (BBR comparison).

Packet loss {1%, 5%} and RTT inflation {+30 ms, +50 ms} injected on the
trace; throughput and p99 sync latency for Baseline vs GeoCoCo.  Paper:
GeoCoCo keeps a 9.3-15.8% throughput edge under loss and 9.3-9.6% under
jitter, with p99 reductions.
"""

from __future__ import annotations

import numpy as np

from repro.core import LatencyTrace

from .common import check, run_engine, wan_cluster


def run(quick: bool = True) -> dict:
    n = 8
    epochs = 20 if quick else 80
    lat, regions, _, trace = wan_cluster(n, epochs, seed=51)
    scenarios = {
        "loss_1pct": {"loss": 0.01, "shift": 0.0},
        "loss_5pct": {"loss": 0.05, "shift": 0.0},
        "jitter_30ms": {"loss": 0.0, "shift": 30.0},
        "jitter_50ms": {"loss": 0.0, "shift": 50.0},
    }
    out = {}
    for name, sc in scenarios.items():
        frames = trace.frames.copy()
        if sc["shift"]:
            off = ~np.eye(n, dtype=bool)
            frames[:, off] += sc["shift"]
        tr = LatencyTrace(base=trace.base, frames=frames)
        kw = dict(
            n=n, trace=tr, regions=regions, bandwidth=150.0, loss=sc["loss"],
            theta=0.7, hot_write_frac=0.3, txns_per_node=12, n_keys=20_000,
        )
        base = run_engine(grouping=False, filtering=False, tiv=False, **kw)
        geo = run_engine(grouping=True, filtering=True, **kw)
        out[name] = {
            "tput_gain": geo.throughput_tps / base.throughput_tps - 1.0,
            "p99_base_ms": base.p99_sync_ms,
            "p99_geo_ms": geo.p99_sync_ms,
            "p99_delta_ms": base.p99_sync_ms - geo.p99_sync_ms,
            "consistent": base.state_digest == geo.state_digest,
        }

    checks = [
        check(all(v["consistent"] for v in out.values()),
              "Fig17: consistency preserved under loss/jitter"),
        check(all(v["tput_gain"] > 0.0 for v in out.values()),
              "Fig17: GeoCoCo retains a throughput edge in every impairment",
              ", ".join(f"{k}={v['tput_gain']:+.1%}" for k, v in out.items())),
        check(all(v["p99_delta_ms"] > 0.0 for v in out.values()),
              "Fig17: p99 sync latency reduced in every impairment",
              ", ".join(f"{k}=-{v['p99_delta_ms']:.0f}ms" for k, v in out.items())),
    ]
    return {"figure": "Fig17", "scenarios": out, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
