"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    YCSBConfig,
    YCSBGenerator,
    aws_latency_matrix,
    bandwidth_matrix,
    geo_clustered_matrix,
    jitter_trace,
)

PASS = "PASS"
FAIL = "FAIL"


def check(cond: bool, claim: str, detail: str = "") -> dict:
    status = PASS if cond else FAIL
    print(f"  [{status}] {claim}" + (f"  ({detail})" if detail else ""))
    return {"claim": claim, "status": status, "detail": detail}


def paper_testbed(n_rounds: int, seed: int = 0):
    """5-node testbed like the paper's: 2 Kalgan + 2 Hohhot + 1 Hong Kong.

    Kalgan<->Hohhot ~ 8 ms (both Inner Mongolia region), either <-> HK ~ 42 ms,
    intra-site < 2 ms.  Jitter is mild and spikes rare: the paper's testbed
    runs on Alibaba Cloud's intra-China backbone, far more stable than
    intercontinental WAN paths.
    """
    base = np.array(
        [
            # K1    K2    H1    H2    HK
            [0.0,  1.5,  8.0,  8.5, 42.0],
            [1.5,  0.0,  8.2,  8.0, 43.0],
            [8.0,  8.2,  0.0,  1.8, 38.0],
            [8.5,  8.0,  1.8,  0.0, 39.0],
            [42.0, 43.0, 38.0, 39.0, 0.0],
        ]
    )
    # Kalgan and Hohhot share the Inner-Mongolia backbone (one region, fast
    # interconnect); Hong Kong is the WAN-separated site — matching the
    # paper's deployment and its Fig. 3 bandwidth-constrained regime.
    regions = np.array([0, 0, 0, 0, 1])
    trace = jitter_trace(
        base, n_rounds, np.random.default_rng(seed),
        rel_sigma=0.04, spike_prob=0.002, spike_mult=(1.3, 1.8),
    )
    return base, regions, trace


def wan_cluster(n: int, n_rounds: int, seed: int = 0, **spec_kw):
    spec = GeoClusterSpec(n_nodes=n, n_clusters=max(2, min(5, n // 3)), **spec_kw)
    rng = np.random.default_rng(seed)
    lat, regions = geo_clustered_matrix(spec, rng)
    bw = bandwidth_matrix(regions, n, rng)
    trace = jitter_trace(lat, n_rounds, np.random.default_rng(seed + 1))
    return lat, regions, bw, trace


def lan_wan_bandwidth(regions, n: int, wan_mbps: float,
                      lan_mbps: float = 10_000.0):
    """Bandwidth matrix with the paper's LAN >> WAN asymmetry (Sec 2.2)."""
    regions = np.asarray(regions)
    same = regions[:, None] == regions[None, :]
    bw = np.where(same, lan_mbps, wan_mbps).astype(float)
    np.fill_diagonal(bw, np.inf)
    return bw


def run_engine(
    *,
    n: int,
    trace,
    regions,
    grouping: bool,
    filtering: bool,
    tiv: bool = True,
    compression: bool = False,
    bandwidth=200.0,
    loss=0.0,
    theta: float = 0.7,
    read_ratio: float = 0.5,
    hot_write_frac: float = 0.25,
    rewrite_frac: float = 0.05,
    txns_per_node: int = 10,
    n_epochs: int | None = None,
    n_keys: int = 5_000,
    value_bytes: int = 100,
    planner: str = "milp",
    seed: int = 7,
    modeled_cpu: bool = False,
):
    cfg = EngineConfig(
        n_nodes=n, grouping=grouping, filtering=filtering, tiv=tiv,
        compression=compression, planner=planner, modeled_cpu=modeled_cpu,
    )
    wan_mask = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    if np.isscalar(bandwidth) and np.isfinite(bandwidth):
        bandwidth = lan_wan_bandwidth(regions, n, float(bandwidth))
    eng = GeoCluster(cfg, bandwidth_mbps=bandwidth, loss=loss,
                     wan_mask=wan_mask, seed=seed)
    gen = YCSBGenerator(
        YCSBConfig(
            n_keys=n_keys, theta=theta, read_ratio=read_ratio,
            hot_write_frac=hot_write_frac, hot_locality=True,
            rewrite_frac=rewrite_frac, value_bytes=value_bytes,
        ),
        n, seed=seed + 1, node_region=regions,
    )
    return eng.run(gen, trace, txns_per_node=txns_per_node, n_epochs=n_epochs)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
