"""Staleness-aware OCC: abort rate vs epoch cadence (Fig-style curve).

The feedback loop under test (``EngineConfig(staleness_feedback=True)``):
the stitched streaming simulation measures per-node commit times, each
node's snapshot view advances only when its inbound epoch transfers have
delivered, and reads are versioned against the executing node's view — so
read-validation aborts become a function of network conditions.  On the
paper's alibaba-like 5-node testbed (Fig 11 TPC-C regime, ~15 Mbps WAN):

* at the paper's native 10 ms cadence the WAN backlog keeps views stale and
  the read-abort rate is substantially nonzero;
* the abort rate is monotonically non-increasing in ``epoch_ms`` (cadence
  slack pays the backlog down), reaching zero once the cadence exceeds the
  sync makespan;
* write-write aborts are invariant across all of it (same transaction
  stream; the read rule only ever adds aborts);
* a bursty trace (latency spikes) raises the read-abort rate vs the steady
  trace at the pipeline's saturation boundary;
* with the default ``staleness_feedback=False`` the streaming engine's
  digests remain byte-identical to the formula engine (the regression gate
  for the timing-dependent mode staying opt-in).
"""

from __future__ import annotations

import numpy as np

from repro.core import jitter_trace

from .bench_throughput import _run_tpcc
from .common import check, paper_testbed

# steady-trace sync makespan on this testbed is ~90 ms: 80 ms sits at the
# saturation boundary where burstiness has headroom to bite (at 10 ms both
# traces are deep in backlog and the lag saturates either way)
BOUNDARY_EPOCH_MS = 80.0

# the deterministic planner keeps the curve reproducible: the MILP search is
# wall-clock-limited, so under harness CPU load it can pick different plans
# run-to-run, shifting commit times across the view-advance threshold
PLANNER = "kcenter"


def run(quick: bool = True) -> dict:
    epochs = 30 if quick else 60
    base, regions, trace = paper_testbed(epochs)

    # abort-rate vs cadence curve (plus the ww-invariance it rides on)
    grid = [5.0, 10.0, 20.0, 40.0, 80.0, 160.0, 320.0]
    curve = []
    ww = []
    for ems in grid:
        rs, _ = _run_tpcc("TPCC-A", True, trace, regions, epochs=epochs,
                          streaming=True, staleness_feedback=True,
                          epoch_ms=ems, planner=PLANNER, modeled_cpu=True,
                          verify_schedules=True)
        curve.append(rs.read_abort_rate)
        ww.append(rs.ww_aborts)
    native_rate = curve[grid.index(10.0)]

    # bursty vs steady trace at the saturation boundary
    bursty_trace = jitter_trace(
        base, epochs, np.random.default_rng(5), rel_sigma=0.15,
        spike_prob=0.10, spike_mult=(2.0, 4.0), spike_len=(3, 10),
    )
    rates = {}
    for name, tr in (("steady", trace), ("bursty", bursty_trace)):
        rs, _ = _run_tpcc("TPCC-A", True, tr, regions, epochs=epochs,
                          streaming=True, staleness_feedback=True,
                          epoch_ms=BOUNDARY_EPOCH_MS, planner=PLANNER,
                          modeled_cpu=True, verify_schedules=True)
        rates[name] = rs.read_abort_rate

    # default-off regression gate: streaming digests byte-identical to the
    # formula engine, and the read rule stays vacuous
    formula_rs, _ = _run_tpcc("TPCC-A", True, trace, regions, epochs=epochs,
                              planner=PLANNER, modeled_cpu=True,
                              verify_schedules=True)
    stream_rs, _ = _run_tpcc("TPCC-A", True, trace, regions, epochs=epochs,
                             streaming=True, planner=PLANNER,
                             modeled_cpu=True, verify_schedules=True)
    default_off = {
        "state_consistent": formula_rs.state_digest == stream_rs.state_digest,
        "value_consistent": formula_rs.value_digest == stream_rs.value_digest,
        "read_aborts": stream_rs.read_aborts,
    }

    checks = [
        check(native_rate > 0.0,
              "staleness feedback: nonzero read-abort rate on the Fig11 "
              "TPC-C workload at the native 10 ms cadence",
              f"read-abort rate {native_rate:.1%}"),
        # exact gate: the filter/compress CPU riding the simulated timeline
        # is now modeled (bytes-proportional, modeled_cpu=True), so the
        # curve is deterministic and the former 2.5pp harness-load
        # allowance is gone — boundary commits can no longer drift across
        # the view-advance threshold between runs
        check(all(a >= b - 1e-9 for a, b in zip(curve, curve[1:])),
              "abort rate monotonically non-increasing as epoch cadence "
              "grows (alibaba-like topology)",
              ", ".join(f"{int(e)}ms={r:.1%}" for e, r in zip(grid, curve))),
        check(curve[0] > 0.25 and curve[-1] <= 0.005,
              "cadence above the sync makespan pays the backlog down to "
              "(near-)zero read-aborts",
              f"{int(grid[0])}ms={curve[0]:.1%} -> {int(grid[-1])}ms="
              f"{curve[-1]:.1%}"),
        check(len(set(ww)) == 1,
              "write-write aborts invariant across cadences (same txn "
              "stream; the read rule only ever adds aborts)",
              f"ww_aborts={ww[0]}"),
        # +2pp margin kept for headroom even though the comparison is now
        # deterministic under modeled CPU (gap ~6.5pp at the boundary
        # cadence, ratio ~1.75x): the margin is intrinsic to the traces,
        # not a noise allowance
        check(rates["bursty"] > rates["steady"] + 0.02,
              "bursty trace raises the read-abort rate vs the steady trace",
              f"steady {rates['steady']:.1%} vs bursty {rates['bursty']:.1%}"),
        check(default_off["state_consistent"]
              and default_off["value_consistent"]
              and default_off["read_aborts"] == 0,
              "staleness_feedback=False (default) keeps streaming digests "
              "byte-identical and the read rule vacuous"),
    ]
    return {
        "figure": "abort-curve",
        "epoch_ms_grid": grid,
        "read_abort_rate": curve,
        "ww_aborts": ww,
        "native_cadence_read_abort_rate": native_rate,
        "boundary_epoch_ms": BOUNDARY_EPOCH_MS,
        "trace_rates": rates,
        "default_off": default_off,
        "checks": checks,
    }


if __name__ == "__main__":
    run(quick=False)
