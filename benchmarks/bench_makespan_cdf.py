"""Paper Fig. 9: CDF of single-round all-to-all makespan — both engines.

Origin (flat all-to-all) vs GeoCoCo grouping vs the theoretical lower bound
(all-pairs shortest-path max), over a jittered AWS-style 10-region trace.
Paper claims: CDF shifts left, >=100 ms reduction at p90, tighter tail.
The paper-comparable series run under the **barrier** engine (the paper's
Eq. 1 phase-sum objective); the event-driven DAG engine's CDF is reported
alongside, and pipelining must shift the grouped CDF further left while
never crossing the theoretical bound.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    Replanner,
    WANSimulator,
    all_to_all_schedule,
    aws_latency_matrix,
    best_plan,
    hierarchical_schedule,
    jitter_trace,
)

from .common import check


def run(quick: bool = True) -> dict:
    n_rounds = 150 if quick else 1000
    base = aws_latency_matrix()
    trace = jitter_trace(base, n_rounds, np.random.default_rng(0),
                         spike_prob=0.02)
    payload = 250_000.0  # 250 kB epoch batch per node
    bw = 500.0

    rp = Replanner(lambda l: best_plan(l, tiv=True, method="milp",
                                       time_limit_s=10.0))
    origin, geo, lb = [], [], []          # barrier engine (paper objective)
    origin_ev, geo_ev = [], []            # event-driven DAG engine
    for lat in trace:
        sim = WANSimulator(lat, bw)
        flat = all_to_all_schedule(10, payload)
        origin.append(sim.run(flat, barrier=True).makespan_ms)
        origin_ev.append(sim.run(flat).makespan_ms)
        plan = rp.observe(lat)
        sched = hierarchical_schedule(plan, payload, lat=lat, tiv=True)
        geo.append(sim.run(sched, barrier=True).makespan_ms)
        geo_ev.append(sim.run(sched).makespan_ms)
        lb.append(sim.lower_bound_ms(payload))
    origin, geo, lb, origin_ev, geo_ev = map(
        np.asarray, (origin, geo, lb, origin_ev, geo_ev)
    )

    def pct(x, q):
        return float(np.percentile(x, q))

    res = {
        "p50": {"origin": pct(origin, 50), "geococo": pct(geo, 50), "lb": pct(lb, 50)},
        "p90": {"origin": pct(origin, 90), "geococo": pct(geo, 90), "lb": pct(lb, 90)},
        "p99": {"origin": pct(origin, 99), "geococo": pct(geo, 99), "lb": pct(lb, 99)},
        "mean": {"origin": float(origin.mean()), "geococo": float(geo.mean())},
        "event": {
            "p50": {"origin": pct(origin_ev, 50), "geococo": pct(geo_ev, 50)},
            "p90": {"origin": pct(origin_ev, 90), "geococo": pct(geo_ev, 90)},
            "mean": {"origin": float(origin_ev.mean()),
                     "geococo": float(geo_ev.mean())},
        },
        "replans": rp.replan_count,
    }
    p90_red = res["p90"]["origin"] - res["p90"]["geococo"]
    # fraction of the origin->lower-bound gap closed at p90
    gap_closed = p90_red / max(res["p90"]["origin"] - res["p90"]["lb"], 1e-9)
    res["p90_reduction_ms"] = p90_red
    res["p90_gap_closed"] = float(gap_closed)
    res["event"]["pipelining_p90_reduction_ms"] = (
        res["p90"]["geococo"] - res["event"]["p90"]["geococo"]
    )

    checks = [
        check(res["p50"]["geococo"] < res["p50"]["origin"],
              "Fig9: CDF shifts left (median makespan reduced)",
              f'{res["p50"]["origin"]:.0f} -> {res["p50"]["geococo"]:.0f} ms'),
        check(p90_red >= 100.0,
              "Fig9: >=100 ms makespan reduction at p90",
              f"reduction {p90_red:.0f} ms"),
        check(bool((geo >= lb - 1e-6).all()),
              "Fig9: grouped makespan never beats the theoretical bound"),
        check(geo.std() < origin.std(),
              "Fig9: variance tightened vs origin",
              f"std {origin.std():.0f} -> {geo.std():.0f} ms"),
        check(res["replans"] <= n_rounds // 5,
              "Fig9: damped replanning (no per-round churn)",
              f"{res['replans']} replans / {n_rounds} rounds"),
        # percentile dominance, not per-round .all(): event <= barrier is
        # not a per-round invariant for dep-edged DAGs, and the MILP's time
        # limit makes exact plans machine-speed dependent — the distribution
        # shift is the claim, and it is robust to both
        check(res["event"]["p50"]["geococo"] < res["p50"]["geococo"]
              and res["event"]["p90"]["geococo"] <= res["p90"]["geococo"]
              and res["event"]["mean"]["geococo"] < res["mean"]["geococo"],
              "Fig9: event-driven DAG shifts the grouped CDF further left "
              "(lower median/mean, p90 no worse)",
              f'p50 {res["p50"]["geococo"]:.0f} -> '
              f'{res["event"]["p50"]["geococo"]:.0f} ms, p90 '
              f'{res["p90"]["geococo"]:.0f} -> '
              f'{res["event"]["p90"]["geococo"]:.0f} ms'),
        check(bool((geo_ev >= lb - 1e-6).all()),
              "Fig9: pipelined makespan still respects the theoretical bound"),
    ]
    return {"figure": "Fig9", "makespan_ms": res, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
