"""Beyond-paper: GeoCoCo gradient-sync strategies on the JAX training plane.

Reads dry-run artifacts (results/dryrun/*.json) when available to report the
measured per-axis collective link bytes; otherwise falls back to the
analytic model in ``repro.dist.collectives.estimate_sync_bytes``.  Shows the
inter-pod (WAN-analogue) byte reduction of hier(FSDP-scattered) and
geococo(top-k filtered) over the flat baseline.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import get_config
from repro.dist.collectives import SyncConfig, estimate_sync_bytes
from repro.models.model import param_count

from .common import check


def run(quick: bool = True) -> dict:
    # analytic model (per device, per step, inter-pod)
    analytic = {}
    for arch in ("minitron-8b", "deepseek-coder-33b", "deepseek-v3-671b"):
        n = param_count(get_config(arch))
        shard = n / 256  # FSDP+TP shard per device within a pod
        flat = estimate_sync_bytes(n / 16, SyncConfig(strategy="flat"), 2)
        hier = estimate_sync_bytes(shard, SyncConfig(strategy="hier"), 2)
        geo = estimate_sync_bytes(shard, SyncConfig(strategy="geococo",
                                                    density=0.10), 2)
        analytic[arch] = {
            "flat_gb": flat / 1e9, "hier_gb": hier / 1e9, "geo_gb": geo / 1e9,
            "hier_vs_flat": 1 - hier / flat, "geo_vs_hier": 1 - geo / hier,
        }

    # measured from dry-run artifacts, if present
    measured = {}
    for path in sorted(glob.glob("results/dryrun/*__multi__*.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        key = f"{rec['arch']}__{rec['shape']}__{rec['strategy']}"
        measured[key] = {
            "pod_link_bytes": rec["collective_link_bytes_by_axes"].get("pod", 0.0),
            "data_link_bytes": rec["collective_link_bytes_by_axes"].get("data", 0.0),
            "model_link_bytes": rec["collective_link_bytes_by_axes"].get("model", 0.0),
        }

    checks = [
        check(all(v["hier_vs_flat"] > 0.9 for v in analytic.values()),
              "Sync: hierarchical (FSDP-scattered) cuts inter-pod bytes ~16x",
              ", ".join(f"{k}={v['hier_vs_flat']:.1%}" for k, v in analytic.items())),
        check(all(v["geo_vs_hier"] > 0.5 for v in analytic.values()),
              "Sync: white-data filtering cuts another >50% at density 0.10",
              ", ".join(f"{k}={v['geo_vs_hier']:.1%}" for k, v in analytic.items())),
    ]
    return {"figure": "sync-strategies", "analytic": analytic,
            "measured": measured, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
