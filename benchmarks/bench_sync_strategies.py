"""Beyond-paper: GeoCoCo gradient-sync strategies on the JAX training plane.

Three views of the same strategy surface:

* **analytic** — ``repro.dist.collectives.estimate_sync_bytes`` per model:
  inter-pod bytes for flat (replicated), hier (FSDP-scattered) and geococo
  (top-k filtered) sync;
* **WAN-plane cross-check** — the identical 2-pod exchange expressed as a
  ``repro.core.schedule`` transmission schedule: the simulator's byte
  accounting must reproduce the device-plane reduction factors (the two
  planes share one wire model through the strategy registry);
* **measured** — dry-run artifacts (results/dryrun/*.json), when present,
  report the per-axis collective link bytes XLA actually emits; the
  reduced-tier artifacts the CI cell produces (results/dryrun-reduced/)
  are additionally *checked* against ``estimate_sync_bytes``: the per-leaf
  analytic model must stay within 2x of the pod-axis bytes XLA really
  moved, or the check fails — the estimator is load-bearing for planning,
  so silent drift is a bug;
* **control-plane** — the relay ring ``relay_psum`` would run is computed
  from a *monitor-estimated* inter-pod latency matrix (a ``repro.control``
  NetworkView), and compared against the ground-truth ring: estimate-vs-
  truth relay-order agreement plus the bottleneck-latency cost of planning
  from estimates.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs.registry import get_config
from repro.control import MonitorView, TraceView
from repro.core.latency import aws_latency_matrix, jitter_trace
from repro.core.planner import no_grouping
from repro.core.schedule import all_to_all_schedule, hierarchical_schedule
from repro.dist.collectives import SyncConfig, estimate_sync_bytes
from repro.models.model import param_count

from .common import check

N_PODS = 2
DENSITY = 0.10
RING_PODS = 4  # the relay-ring section models a 4-pod deployment


def _wan_plane_bytes(shard_bytes: float, *, filtered: float | None) -> float:
    """Total WAN bytes of one 2-pod exchange on the core plane.

    Each pod is a node; with singleton groups the hierarchical schedule
    degenerates to the pure aggregator exchange — the WAN mirror of the
    device plane's pod-boundary all-reduce.  ``filtered`` replaces the
    consolidated group payload (post top-k bytes), as the white-data filter
    does for write sets.
    """
    lat = np.array([[0.0, 50.0], [50.0, 0.0]])
    plan = no_grouping(lat)
    if filtered is None:
        sched = hierarchical_schedule(plan, shard_bytes)
    else:
        gp = np.full(plan.k, filtered)
        sched = hierarchical_schedule(plan, shard_bytes, group_payload_bytes=gp)
    return sched.total_bytes


def _measured_vs_estimate() -> dict:
    """Reduced-tier dry-run artifacts vs the analytic wire model.

    For every ``results/dryrun-reduced/*.json`` multi-pod cell, compare the
    pure pod-axis collective link bytes XLA emitted (per device, per step —
    the compact ``collective_link_bytes_by_axes['pod']`` summary) against
    ``estimate_sync_bytes`` fed the *actual gradient pytree* of the compiled
    config with ``shard_factor`` = in-pod devices, so the estimator's
    per-leaf dense-fallback / chunk-granular top-k accounting is exercised
    exactly as ``sync_gradients`` applies it.
    """
    out = {}
    for path in sorted(glob.glob("results/dryrun-reduced/*.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        mesh_shape = rec.get("mesh_shape", {})
        n_pods = int(mesh_shape.get("pod", 1))
        if n_pods <= 1:
            continue
        import jax.numpy as jnp

        from repro.configs.registry import get_smoke_config
        from repro.train.train_step import abstract_params

        cfg = (get_smoke_config(rec["arch"]) if rec.get("smoke")
               else get_config(rec["arch"]))
        params = abstract_params(cfg, jnp.float32)
        in_pod = float(mesh_shape.get("data", 1) * mesh_shape.get("model", 1))
        bpv = 2 if "bf" in rec.get("param_dtype", "float32") else 4
        est = estimate_sync_bytes(
            params,
            SyncConfig(strategy=rec["strategy"],
                       density=rec.get("density", DENSITY)),
            n_pods, bytes_per_value=bpv, shard_factor=in_pod,
        )
        meas = float(rec["collective_link_bytes_by_axes"].get("pod", 0.0))
        out[f"{rec['arch']}__{rec['shape']}__{rec['strategy']}"] = {
            "measured_pod_bytes": meas,
            "estimate_bytes": est,
            "ratio": meas / est if est > 0 else float("inf"),
        }
    return out


def _relay_ring_from_view(quick: bool, view_factory) -> dict:
    """Estimate-vs-truth relay order for the device plane's pod ring.

    The inter-pod WAN is the first ``RING_PODS`` AWS-style regions under
    jitter; the ring order fed to ``relay_psum`` comes from the view's
    *estimated* matrices (the trainer's ControlPlane path), evaluated
    against the rings a ground-truth oracle would pick.  ``view_factory``
    receives the generated trace so the view always observes the same
    ground truth it is scored against.
    """
    from benchmarks.bench_tiv import relay_order_agreement

    rounds = 20 if quick else 80
    base = aws_latency_matrix()[:RING_PODS, :RING_PODS]
    trace = jitter_trace(base, rounds, np.random.default_rng(11))
    if view_factory is None:
        view_factory = lambda tr: MonitorView(  # noqa: E731
            TraceView(tr), noise=0.10, rng=np.random.default_rng(12)
        )
    view = view_factory(trace)
    if view.n != RING_PODS:
        raise ValueError(
            f"view_factory built a {view.n}-node view for the "
            f"{RING_PODS}-pod trace it was given"
        )
    return relay_order_agreement(trace, view, rounds=rounds)


def run(quick: bool = True, view_factory=None) -> dict:
    """``view_factory(trace) -> NetworkView`` optionally supplies the view
    for the relay-ring section (default: full-mesh EWMA monitoring of the
    given trace with 10% probe noise) — same shape as bench_tiv's."""
    # analytic model (per device, per step, inter-pod)
    analytic = {}
    for arch in ("minitron-8b", "deepseek-coder-33b", "deepseek-v3-671b"):
        n = param_count(get_config(arch))
        shard = n / 256  # FSDP+TP shard per device within a pod
        flat = estimate_sync_bytes(n / 16, SyncConfig(strategy="flat"), N_PODS)
        hier = estimate_sync_bytes(shard, SyncConfig(strategy="hier"), N_PODS)
        geo = estimate_sync_bytes(
            shard, SyncConfig(strategy="geococo", density=DENSITY), N_PODS
        )
        analytic[arch] = {
            "flat_gb": flat / 1e9, "hier_gb": hier / 1e9, "geo_gb": geo / 1e9,
            "hier_vs_flat": 1 - hier / flat, "geo_vs_hier": 1 - geo / hier,
        }

    # WAN-plane cross-check: same exchange as a core-plane transmission
    # schedule.  The WAN side computes its filtered payload from first
    # principles (kept fraction at chunk granularity, value+index cost) —
    # independently of estimate_sync_bytes — so ratio agreement actually
    # tests the estimator's model and the schedule's byte accounting
    # against each other, not against themselves.
    ref = analytic["minitron-8b"]
    shard_bytes = ref["hier_gb"] * 1e9
    scfg = SyncConfig(strategy="geococo", density=DENSITY)
    kept_fraction = max(1, round(DENSITY * scfg.chunk)) / scfg.chunk
    value_and_index = 2.0  # 4 B value + 4 B chunk-local index, / 4 B dense
    wan_dense = _wan_plane_bytes(shard_bytes, filtered=None)
    wan_filtered = _wan_plane_bytes(
        shard_bytes, filtered=shard_bytes * kept_fraction * value_and_index
    )
    device_ratio = ref["geo_gb"] / ref["hier_gb"]
    wan_ratio = wan_filtered / wan_dense
    two_plane = {
        "wan_dense_gb": wan_dense / 1e9,
        "wan_filtered_gb": wan_filtered / 1e9,
        "device_geo_over_hier": device_ratio,
        "wan_geo_over_hier": wan_ratio,
    }
    print(f"  two-plane bytes: device geo/hier={device_ratio:.3f}  "
          f"WAN-schedule geo/hier={wan_ratio:.3f}  "
          f"(dense {wan_dense/1e9:.2f} GB -> filtered {wan_filtered/1e9:.2f} GB)")

    # control-plane: relay_psum ring order from monitor-estimated matrices
    ring = _relay_ring_from_view(quick, view_factory)
    print(f"  relay ring from NetworkView: edge agreement "
          f"{ring['edge_agreement']:.1%}, bottleneck cost ratio "
          f"{ring['cost_ratio']:.3f}, probes {ring['probe_bytes']/1e3:.1f} KB")

    # measured from dry-run artifacts, if present
    measured = {}
    for path in sorted(glob.glob("results/dryrun/*__multi__*.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        key = f"{rec['arch']}__{rec['shape']}__{rec['strategy']}"
        measured[key] = {
            "pod_link_bytes": rec["collective_link_bytes_by_axes"].get("pod", 0.0),
            "data_link_bytes": rec["collective_link_bytes_by_axes"].get("data", 0.0),
            "model_link_bytes": rec["collective_link_bytes_by_axes"].get("model", 0.0),
        }

    # reduced-tier CI artifacts: measured XLA pod-axis bytes vs the analytic
    # estimator, per strategy.  >2x drift in either direction fails the run.
    measured_reduced = _measured_vs_estimate()
    for key, rec in measured_reduced.items():
        print(f"  dryrun-reduced {key}: measured {rec['measured_pod_bytes']/1e3:.1f} KB "
              f"vs estimate {rec['estimate_bytes']/1e3:.1f} KB "
              f"(ratio {rec['ratio']:.2f})")

    checks = [
        check(all(v["hier_vs_flat"] > 0.9 for v in analytic.values()),
              "Sync: hierarchical (FSDP-scattered) cuts inter-pod bytes ~16x",
              ", ".join(f"{k}={v['hier_vs_flat']:.1%}" for k, v in analytic.items())),
        check(all(v["geo_vs_hier"] > 0.5 for v in analytic.values()),
              "Sync: white-data filtering cuts another >50% at density 0.10",
              ", ".join(f"{k}={v['geo_vs_hier']:.1%}" for k, v in analytic.items())),
        check(abs(device_ratio - wan_ratio) < 0.01,
              "Two-plane consistency: WAN schedule + first-principles filter "
              "payload reproduce the device-plane byte reduction",
              f"device={device_ratio:.4f} wan={wan_ratio:.4f}"),
        check(ring["cost_ratio"] < 1.15,
              "Control: relay rings planned from monitor estimates stay "
              "within 15% of ground-truth bottleneck latency",
              f"cost_ratio={ring['cost_ratio']:.3f} "
              f"agreement={ring['edge_agreement']:.1%}"),
        check(all(0.5 <= r["ratio"] <= 2.0 for r in measured_reduced.values()),
              "Measured: estimate_sync_bytes stays within 2x of the XLA "
              "pod-axis collective bytes on the reduced-tier dry-run cells",
              (", ".join(f"{k.split('__')[-1]}={v['ratio']:.2f}x"
                         for k, v in measured_reduced.items())
               if measured_reduced
               else "no artifacts (run repro.launch.dryrun --tier reduced)")),
    ]
    return {"figure": "sync-strategies", "analytic": analytic,
            "two_plane": two_plane, "relay_ring": ring,
            "measured": measured, "measured_reduced": measured_reduced,
            "checks": checks}


if __name__ == "__main__":
    run(quick=False)
