"""Paper Fig. 19: the optimal group number.

Makespan reduction over no-grouping as a function of k, for 10- and 15-node
clusters across two WAN settings; the empirical optimum should sit in the
guided band around k* = (N^2/2)^(1/3) (paper: empirical optima 4 and 5).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    WANSimulator,
    all_to_all_schedule,
    hierarchical_schedule,
    k_search_band,
    milp_grouping,
    optimal_k,
)
from repro.core.latency import GeoClusterSpec, geo_clustered_matrix, jitter_trace

from .common import check


def _sweep(n: int, seed: int, rounds: int) -> dict:
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=4), np.random.default_rng(seed)
    )
    from .common import lan_wan_bandwidth

    bw = lan_wan_bandwidth(regions, n, 100.0)
    trace = jitter_trace(lat, rounds, np.random.default_rng(seed + 1))
    payload = 100_000.0
    base = np.mean([
        WANSimulator(f, bw).run(all_to_all_schedule(n, payload)).makespan_ms
        for f in trace
    ])
    red = {}
    for k in range(2, min(n - 1, 9)):
        plan = milp_grouping(lat, k, tiv=True, time_limit_s=15.0)
        ms = np.mean([
            WANSimulator(f, bw).run(
                hierarchical_schedule(plan, payload, lat=f, tiv=True)
            ).makespan_ms
            for f in trace
        ])
        red[k] = float(1.0 - ms / base)
    return red


def run(quick: bool = True) -> dict:
    rounds = 25 if quick else 100
    out = {}
    checks = []
    for n, seed in ((10, 71), (15, 73)):
        red = _sweep(n, seed, rounds)
        best_k = max(red, key=red.get)
        band = k_search_band(n, tolerance=1)
        out[n] = {"reduction_by_k": red, "best_k": best_k,
                  "k_star": optimal_k(n), "band": band}
        checks.append(check(
            min(abs(best_k - b) for b in band) <= 1,
            f"Fig19 (N={n}): empirical optimum k={best_k} within the k* band "
            f"{band} (k*={optimal_k(n):.1f})",
        ))
        checks.append(check(
            red[best_k] > 0.05,
            f"Fig19 (N={n}): best grouping gives a real reduction",
            f"{red[best_k]:.1%}",
        ))
    return {"figure": "Fig19",
            "results": {str(k): v for k, v in out.items()}, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
