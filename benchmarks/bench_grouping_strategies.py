"""Paper Fig. 12: grouping cost vs communication efficiency, 12 & 15 nodes.

Strategies: GeoCoCo LP (MILP, +/- TIV), K-center, hierarchical agglomerative,
KMeans(2), KMeans(3), random, none.  Paper claims: LP best makespan
(16.46% @12n, 17.63% @15n over no grouping, beating the best baseline);
TIV adds an independent 7.6-12.4%.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    WANSimulator,
    agglomerative_grouping,
    all_to_all_schedule,
    best_plan,
    hierarchical_schedule,
    k_search_band,
    kcenter_grouping,
    kmeans_grouping,
    no_grouping,
    random_grouping,
)
from repro.core.latency import GeoClusterSpec, geo_clustered_matrix, jitter_trace

from .common import check


def _evaluate(n: int, rounds: int, seed: int) -> dict:
    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=4, congestion_frac=0.35),
        np.random.default_rng(seed),
    )
    trace = jitter_trace(lat, rounds, np.random.default_rng(seed + 1))
    payload = 100_000.0
    bw = 500.0
    ks = k_search_band(n)

    strategies = {
        # tolerance=0: the paper's narrowed k* band (Sec 4.2) — 2 solves
        "geococo_lp_tiv": lambda l: best_plan(l, tiv=True, method="milp",
                                              time_limit_s=4.0, tolerance=0),
        "geococo_lp": lambda l: best_plan(l, tiv=False, method="milp",
                                          time_limit_s=4.0, tolerance=0),
        "kcenter": lambda l: min(
            (kcenter_grouping(l, k) for k in ks),
            key=lambda p: p.objective,
        ),
        "agglomerative": lambda l: min(
            (agglomerative_grouping(l, k) for k in ks),
            key=lambda p: p.objective,
        ),
        "kmeans2": lambda l: kmeans_grouping(l, 2),
        "kmeans3": lambda l: kmeans_grouping(l, 3),
        "random": lambda l: random_grouping(l, max(ks), np.random.default_rng(0)),
    }

    out = {}
    # plan every 10 rounds (the paper's contour convention)
    replan_every = 10
    for name, fn in strategies.items():
        tiv = name.endswith("_tiv")
        makespans = []
        plan_times = []
        plan = None
        for i, f in enumerate(trace):
            if i % replan_every == 0:
                t0 = time.perf_counter()
                plan = fn(f)
                plan_times.append(time.perf_counter() - t0)
            sim = WANSimulator(f, bw)
            sched = hierarchical_schedule(plan, payload, lat=f, tiv=tiv)
            makespans.append(sim.run(sched).makespan_ms)
        out[name] = {
            "mean_makespan_ms": float(np.mean(makespans)),
            "mean_plan_time_ms": float(np.mean(plan_times) * 1e3),
        }
    # no-grouping baseline
    ms = [
        WANSimulator(f, bw).run(all_to_all_schedule(n, payload)).makespan_ms
        for f in trace
    ]
    out["none"] = {"mean_makespan_ms": float(np.mean(ms)),
                   "mean_plan_time_ms": 0.0}
    return out


def run(quick: bool = True) -> dict:
    rounds = 40 if quick else 150
    res = {12: _evaluate(12, rounds, seed=21), 15: _evaluate(15, rounds, seed=22)}

    checks = []
    for n, r in res.items():
        base = r["none"]["mean_makespan_ms"]
        lp = r["geococo_lp_tiv"]["mean_makespan_ms"]
        lp_notiv = r["geococo_lp"]["mean_makespan_ms"]
        best_other = min(
            v["mean_makespan_ms"]
            for k, v in r.items()
            if k not in ("geococo_lp_tiv", "geococo_lp", "none")
        )
        imp = 1.0 - lp / base
        tiv_gain = 1.0 - lp / lp_notiv
        checks.append(check(
            lp <= best_other + 1e-9,
            f"Fig12 ({n} nodes): LP grouping beats every baseline strategy",
            f"LP {lp:.0f} ms vs best-other {best_other:.0f} ms",
        ))
        checks.append(check(
            imp >= 0.10,
            f"Fig12 ({n} nodes): improvement over no-grouping in the paper band"
            f" (paper: {16.46 if n == 12 else 17.63}%)",
            f"{imp:.1%}",
        ))
        checks.append(check(
            tiv_gain >= 0.0,
            f"Fig12 ({n} nodes): TIV exploitation adds an independent benefit"
            " (paper: 7.6-12.4%)",
            f"{tiv_gain:+.1%}",
        ))
        checks.append(check(
            r["kcenter"]["mean_plan_time_ms"] < 100.0
            and r["geococo_lp_tiv"]["mean_plan_time_ms"] < 12_000.0,
            f"Fig12 ({n} nodes): planning amortizable — K-center (the Sec 5 "
            "scalable path) in <100 ms; open-source HiGHS LP bounded (the "
            "paper's Gurobi solves the same model in <10 ms) and run async",
            f"kcenter {r['kcenter']['mean_plan_time_ms']:.1f} ms, "
            f"LP {r['geococo_lp_tiv']['mean_plan_time_ms']:.0f} ms",
        ))
    return {"figure": "Fig12", "results": {str(k): v for k, v in res.items()},
            "checks": checks}


if __name__ == "__main__":
    run(quick=False)
