"""Makespan regression gate: event-driven DAG engine vs barrier phases.

Not a paper figure — a CI tripwire for the transmission-engine refactor.
On every benchmark topology (the AWS-style 10-region matrix and the two
geo-clustered deployments the other figures use), for every strategy
(flat all-to-all, dense hierarchical, geococo = hierarchical + TIV +
filtered payloads), the event-driven engine must never exceed the barrier
phase-sum makespan; and on the trace topologies the pipelined hier/geococo
rounds must be *strictly* faster — the whole point of dependency-tracked
transfers is that fast groups' exchanges overlap slow groups' gathers.

NOTE: ``event <= barrier`` is a theorem only for barrier-edged schedules
(tests/test_property_dag.py); for real dependency edges the greedy ASAP
start can lose NIC share on adversarial inputs (severely
bandwidth-starved links — observed around ~6 Mbps on 250 kB payloads).
This gate is therefore an *empirical* bound on these pinned topologies,
seeds and constants: every input here is deterministic, so a failure
means the engine (or this gate's inputs) changed, never run-to-run noise.
If you change PAYLOAD/BW_MBPS or the topologies, re-establish the bound.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    GeoClusterSpec,
    WANSimulator,
    all_to_all_schedule,
    aws_latency_matrix,
    geo_clustered_matrix,
    hierarchical_schedule,
    jitter_trace,
)
from repro.core.planner import kcenter_grouping, optimal_k

from .common import check

PAYLOAD = 250_000.0  # 250 kB epoch batch per node
BW_MBPS = 500.0
FILTER_KEEP = 0.4    # geococo consolidated payload after white-data filtering


def _topologies(rng_seed: int = 0) -> dict[str, np.ndarray]:
    lat_w, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=20, n_clusters=6, congestion_frac=0.22,
                       congestion_mult=(1.4, 2.5)),
        np.random.default_rng(1),
    )
    lat_a, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3, congestion_frac=0.3,
                       congestion_mult=(1.3, 2.5)),
        np.random.default_rng(3),
    )
    return {"aws": aws_latency_matrix(), "wondernet_like": lat_w,
            "alibaba_like": lat_a}


def _schedules(lat: np.ndarray, plan) -> dict[str, object]:
    n = lat.shape[0]
    gp = np.array([len(g) * PAYLOAD * FILTER_KEEP for g in plan.groups])
    return {
        "flat": all_to_all_schedule(n, PAYLOAD),
        "hier": hierarchical_schedule(plan, PAYLOAD),
        "geococo": hierarchical_schedule(
            plan, PAYLOAD, group_payload_bytes=gp, lat=lat, tiv=True
        ),
    }


def run(quick: bool = True) -> dict:
    rounds = 25 if quick else 120
    eps = 1e-6
    results: dict[str, dict] = {}
    violations: list[str] = []
    for topo, base in _topologies().items():
        trace = jitter_trace(base, rounds, np.random.default_rng(17))
        # a genuinely grouped k* plan: the gate compares *engines* on the
        # hierarchical schedule (best_plan may adaptively pick the flat
        # fallback, which has nothing to pipeline)
        plan = kcenter_grouping(base, max(2, int(round(optimal_k(base.shape[0])))))
        acc = {s: {"event": [], "barrier": []} for s in ("flat", "hier", "geococo")}
        for lat in trace:
            sim = WANSimulator(lat, BW_MBPS)
            for strat, sched in _schedules(lat, plan).items():
                ev = sim.run(sched).makespan_ms
                ba = sim.run(sched, barrier=True).makespan_ms
                if ev > ba + eps:
                    violations.append(
                        f"{topo}/{strat}: event {ev:.2f} > barrier {ba:.2f}"
                    )
                acc[strat]["event"].append(ev)
                acc[strat]["barrier"].append(ba)
        results[topo] = {
            strat: {
                "event_mean_ms": float(np.mean(v["event"])),
                "barrier_mean_ms": float(np.mean(v["barrier"])),
                "reduction": float(
                    1.0 - np.mean(v["event"]) / max(np.mean(v["barrier"]), 1e-9)
                ),
            }
            for strat, v in acc.items()
        }
        for strat in ("flat", "hier", "geococo"):
            r = results[topo][strat]
            print(f"  {topo:>15}/{strat:<8} barrier {r['barrier_mean_ms']:7.1f} ms"
                  f" -> event {r['event_mean_ms']:7.1f} ms"
                  f"  (-{r['reduction']:.1%})")

    strict = {
        topo: all(
            results[topo][s]["event_mean_ms"] < results[topo][s]["barrier_mean_ms"]
            for s in ("hier", "geococo")
        )
        for topo in results
    }
    checks = [
        check(not violations,
              "Regression: event-driven makespan never exceeds barrier "
              "makespan on any benchmark topology/strategy/round",
              "; ".join(violations[:3]) if violations
              else f"{3 * 3 * rounds} schedule runs compared"),
        check(sum(strict.values()) >= 2,
              "DAG pipelining: hier/geococo strictly faster than barrier "
              "phases on >=2 trace topologies",
              ", ".join(f"{t}={'strict' if v else 'tied'}"
                        for t, v in strict.items())),
    ]
    return {"figure": "makespan-regression", "topologies": results,
            "strict_reduction": strict, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
