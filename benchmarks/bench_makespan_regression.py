"""Makespan regression gate: event-driven DAG engine vs barrier phases,
plus the cross-epoch streaming gate.

Not a paper figure — a CI tripwire for the transmission-engine refactor.
On every benchmark topology (the AWS-style 10-region matrix and the two
geo-clustered deployments the other figures use), for every strategy
(flat all-to-all, dense hierarchical, geococo = hierarchical + TIV +
filtered payloads), the event-driven engine must never exceed the barrier
phase-sum makespan; and on the trace topologies the pipelined hier/geococo
rounds must be *strictly* faster — the whole point of dependency-tracked
transfers is that fast groups' exchanges overlap slow groups' gathers.

Since the bandwidth-admission fix, ``event <= barrier`` is a *theorem*
for every builder DAG (a ready hop defers while an earlier-phase flow
still occupies its NICs; hypothesis-tested over random matrices in
tests/test_property_dag.py, adversarial regression in
tests/test_dag_engine.py).  This gate stays as the deterministic CI
tripwire on the pinned topologies — a failure means the engine (or this
gate's inputs) changed, never run-to-run noise — and additionally checks
that admission did not eat the pipelining *gains* the refactor exists for.

The **streaming gate** runs the full replication engine on each topology
in both regimes: the stitched cross-epoch simulation
(``EngineConfig(streaming=True)``) must produce a total wall-clock no
worse than the ``max(epoch, exec, sync)`` formula on every topology, and
strictly better on at least one — epoch e+1 gathers streaming under epoch
e scatters is worth real wall-clock, not just accounting.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    WANSimulator,
    YCSBConfig,
    YCSBGenerator,
    all_to_all_schedule,
    aws_latency_matrix,
    geo_clustered_matrix,
    hierarchical_schedule,
    jitter_trace,
)
from repro.core.planner import kcenter_grouping, optimal_k

from .common import check

PAYLOAD = 250_000.0  # 250 kB epoch batch per node
BW_MBPS = 500.0
FILTER_KEEP = 0.4    # geococo consolidated payload after white-data filtering

# streaming-gate engine settings: WAN-bound rounds (sync >> cadence/exec)
STREAM_EPOCHS = 8
STREAM_BW_MBPS = 100.0
STREAM_EPOCH_MS = 2.0
STREAM_TXN_EXEC_US = 5.0
STREAM_TXNS_PER_NODE = 20


def _topologies(rng_seed: int = 0) -> dict[str, np.ndarray]:
    lat_w, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=20, n_clusters=6, congestion_frac=0.22,
                       congestion_mult=(1.4, 2.5)),
        np.random.default_rng(1),
    )
    lat_a, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3, congestion_frac=0.3,
                       congestion_mult=(1.3, 2.5)),
        np.random.default_rng(3),
    )
    return {"aws": aws_latency_matrix(), "wondernet_like": lat_w,
            "alibaba_like": lat_a}


def _schedules(lat: np.ndarray, plan) -> dict[str, object]:
    n = lat.shape[0]
    gp = np.array([len(g) * PAYLOAD * FILTER_KEEP for g in plan.groups])
    return {
        "flat": all_to_all_schedule(n, PAYLOAD),
        "hier": hierarchical_schedule(plan, PAYLOAD),
        "geococo": hierarchical_schedule(
            plan, PAYLOAD, group_payload_bytes=gp, lat=lat, tiv=True
        ),
    }


def _stream_wall_s(base: np.ndarray, streaming: bool) -> float:
    """Total simulated wall-clock of the replication engine on one topology
    (geococo strategy), streaming vs the formula regime.  Deterministic:
    fixed seeds, fixed trace."""
    n = base.shape[0]
    trace = jitter_trace(base, STREAM_EPOCHS, np.random.default_rng(17))
    cfg = EngineConfig(
        n_nodes=n, streaming=streaming, grouping=True, filtering=True,
        tiv=True, planner="kcenter", epoch_ms=STREAM_EPOCH_MS,
        txn_exec_us=STREAM_TXN_EXEC_US, verify_schedules=True,
    )
    eng = GeoCluster(cfg, bandwidth_mbps=STREAM_BW_MBPS, seed=7)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=400, theta=0.9, read_ratio=0.3, hot_write_frac=0.3),
        n, seed=3,
    )
    rs = eng.run(gen, trace, txns_per_node=STREAM_TXNS_PER_NODE,
                 n_epochs=STREAM_EPOCHS)
    return rs.wall_s


def run(quick: bool = True) -> dict:
    rounds = 25 if quick else 120
    eps = 1e-6
    results: dict[str, dict] = {}
    violations: list[str] = []
    for topo, base in _topologies().items():
        trace = jitter_trace(base, rounds, np.random.default_rng(17))
        # a genuinely grouped k* plan: the gate compares *engines* on the
        # hierarchical schedule (best_plan may adaptively pick the flat
        # fallback, which has nothing to pipeline)
        plan = kcenter_grouping(base, max(2, int(round(optimal_k(base.shape[0])))))
        acc = {s: {"event": [], "barrier": []} for s in ("flat", "hier", "geococo")}
        for lat in trace:
            # verify=True: every builder DAG passes the static invariant
            # checker (repro.analysis.schedule_check) before simulation
            sim = WANSimulator(lat, BW_MBPS, verify=True)
            for strat, sched in _schedules(lat, plan).items():
                ev = sim.run(sched).makespan_ms
                ba = sim.run(sched, barrier=True).makespan_ms
                if ev > ba + eps:
                    violations.append(
                        f"{topo}/{strat}: event {ev:.2f} > barrier {ba:.2f}"
                    )
                acc[strat]["event"].append(ev)
                acc[strat]["barrier"].append(ba)
        results[topo] = {
            strat: {
                "event_mean_ms": float(np.mean(v["event"])),
                "barrier_mean_ms": float(np.mean(v["barrier"])),
                "reduction": float(
                    1.0 - np.mean(v["event"]) / max(np.mean(v["barrier"]), 1e-9)
                ),
            }
            for strat, v in acc.items()
        }
        for strat in ("flat", "hier", "geococo"):
            r = results[topo][strat]
            print(f"  {topo:>15}/{strat:<8} barrier {r['barrier_mean_ms']:7.1f} ms"
                  f" -> event {r['event_mean_ms']:7.1f} ms"
                  f"  (-{r['reduction']:.1%})")

    strict = {
        topo: all(
            results[topo][s]["event_mean_ms"] < results[topo][s]["barrier_mean_ms"]
            for s in ("hier", "geococo")
        )
        for topo in results
    }

    # cross-epoch streaming gate: measured stitched pipeline vs the formula
    streaming: dict[str, dict] = {}
    for topo, base in _topologies().items():
        formula_s = _stream_wall_s(base, streaming=False)
        stream_s = _stream_wall_s(base, streaming=True)
        streaming[topo] = {
            "formula_wall_s": formula_s,
            "stream_wall_s": stream_s,
            "reduction": 1.0 - stream_s / max(formula_s, 1e-12),
        }
        print(f"  {topo:>15}/stream   formula {formula_s * 1e3:7.1f} ms"
              f" -> stream {stream_s * 1e3:7.1f} ms"
              f"  (-{streaming[topo]['reduction']:.2%})")
    stream_ok = {t: v["stream_wall_s"] <= v["formula_wall_s"] + 1e-9
                 for t, v in streaming.items()}
    stream_strict = {t: v["stream_wall_s"] < v["formula_wall_s"]
                     for t, v in streaming.items()}

    checks = [
        check(not violations,
              "Regression: event-driven makespan never exceeds barrier "
              "makespan on any benchmark topology/strategy/round "
              "(a theorem since the admission fix; gate kept as tripwire)",
              "; ".join(violations[:3]) if violations
              else f"{3 * 3 * rounds} schedule runs compared"),
        check(sum(strict.values()) >= 2,
              "DAG pipelining: hier/geococo strictly faster than barrier "
              "phases on >=2 trace topologies (admission kept the gains)",
              ", ".join(f"{t}={'strict' if v else 'tied'}"
                        for t, v in strict.items())),
        check(all(stream_ok.values()),
              "Streaming: stitched cross-epoch wall-clock never exceeds the "
              "max(epoch, exec, sync) formula on any trace topology",
              ", ".join(f"{t}={'ok' if v else 'WORSE'}"
                        for t, v in stream_ok.items())),
        check(sum(stream_strict.values()) >= 1,
              "Streaming: strict wall-clock reduction on >=1 trace topology "
              "(epoch e+1 gathers pipeline under epoch e scatters)",
              ", ".join(f"{t}=-{streaming[t]['reduction']:.2%}"
                        for t in streaming)),
    ]
    return {"figure": "makespan-regression", "topologies": results,
            "strict_reduction": strict, "streaming": streaming,
            "engine": {"event": "fluid-flow DAG + bandwidth admission",
                       "streaming": "stitched cross-epoch DAG"},
            "checks": checks}


if __name__ == "__main__":
    run(quick=False)
