"""Paper Fig. 13: planning cost vs cumulative benefit, 5-50 nodes.

One plan is computed per network state; its cost is the solver wall time.
The benefit accumulates over 1000 rounds at the 10 ms GeoGauss epoch cadence
(paper setting).  Paper claims: cost stays ~6.65-7.07% of the cumulative
benefit, enabled by the guided k* search band.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    WANSimulator,
    all_to_all_schedule,
    best_plan,
    hierarchical_schedule,
    k_search_band,
    optimal_k,
)
from repro.core.latency import GeoClusterSpec, geo_clustered_matrix, jitter_trace

from .common import check


def run(quick: bool = True) -> dict:
    sizes = [5, 10, 15, 25, 50] if quick else [5, 10, 15, 20, 25, 30, 40, 50]
    rounds = 200 if quick else 1000
    payload = 100_000.0
    bw = 100.0
    out = {}
    for n in sizes:
        lat, regions = geo_clustered_matrix(
            GeoClusterSpec(n_nodes=n, n_clusters=max(3, n // 6)),
            np.random.default_rng(n),
        )
        from .common import lan_wan_bandwidth

        bwm = lan_wan_bandwidth(regions, n, bw)
        trace = jitter_trace(lat, rounds, np.random.default_rng(n + 1))
        method = "milp" if n <= 15 else "kcenter"   # paper Sec 5: k-center at scale
        t0 = time.perf_counter()
        plan = best_plan(lat, tiv=True, method=method, time_limit_s=20.0,
                         payload_bytes=payload, bandwidth_mbps=bwm)
        plan_cost_s = time.perf_counter() - t0

        benefit_ms = 0.0
        for f in trace:
            sim = WANSimulator(f, bwm)
            m_base = sim.run(all_to_all_schedule(n, payload)).makespan_ms
            m_geo = sim.run(
                hierarchical_schedule(plan, payload, lat=f, tiv=True)
            ).makespan_ms
            benefit_ms += max(m_base - m_geo, 0.0)
        ratio = plan_cost_s * 1e3 / max(benefit_ms, 1e-9)
        out[n] = {
            "plan_cost_ms": plan_cost_s * 1e3,
            "cumulative_benefit_ms": benefit_ms,
            "cost_over_benefit": ratio,
            "method": method,
            "k": plan.k,
            "k_star": optimal_k(n),
            "k_band": k_search_band(n),
        }

    checks = [
        check(all(v["cumulative_benefit_ms"] > v["plan_cost_ms"] for v in out.values()),
              "Fig13: cumulative benefit exceeds planning cost at every scale"),
        check(all(v["cost_over_benefit"] < 0.25 for v in out.values()),
              "Fig13: planning cost a small fraction of benefit (paper ~7%)",
              ", ".join(f"N={n}:{v['cost_over_benefit']:.1%}" for n, v in out.items())),
        check(all(v["k"] in v["k_band"] or v["k"] == int(n_)
                  for n_, v in ((int(k), v) for k, v in out.items())),
              "Fig13: guided search keeps k inside the k* band "
              "(or adaptively flat)"),
    ]
    return {"figure": "Fig13", "results": {str(k): v for k, v in out.items()},
            "checks": checks}


if __name__ == "__main__":
    run(quick=False)
