"""Paper Fig. 5: prevalence of Triangle Inequality Violations on WAN data.

The paper reports 28-57% of node pairs violating the triangle inequality
across 3 real-world WAN datasets (Alibaba inter-region metrics, AWS network
manager, WonderNetwork pings).  We evaluate three analogous latency sources:
the AWS-style 10-region matrix (static + jittered) and two synthetic
geo-clustered deployments with realistic congestion.

Beyond the figure, the benchmark consumes latency through the
``repro.control`` :class:`NetworkView` interface: the TIV relay-order
search runs on *monitor-estimated* matrices (full-mesh EWMA probing and
Vivaldi coordinates), not just ground truth, and reports estimate-vs-truth
relay-order agreement alongside each view's probe cost — the operational
question behind Sec 6.4's "Cost of Delay Monitoring".
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.control import (
    MonitorView,
    NetworkView,
    TraceView,
    relay_ring_order,
    ring_cost,
    VivaldiView,
)
from repro.core import (
    GeoClusterSpec,
    aws_latency_matrix,
    geo_clustered_matrix,
    jitter_trace,
    tiv_fraction,
)

from .common import check


def _ring_edges(order: tuple[int, ...]) -> set[frozenset]:
    n = len(order)
    return {frozenset((order[i], order[(i + 1) % n])) for i in range(n)}


def relay_order_agreement(trace, view: NetworkView, *, rounds: int) -> dict:
    """Drive a NetworkView over a trace; per round, compare the relay ring
    computed from the view's *estimate* against the ground-truth ring.

    ``edge_agreement`` is the mean fraction of shared ring edges;
    ``cost_ratio`` evaluates the estimated ring on the true matrix against
    the true ring (>= 1.0; 1.0 = the estimate loses nothing).
    """
    agree, ratios = [], []
    for r in range(rounds):
        truth = trace[r % len(trace)]
        est = view.sample()
        o_true = relay_ring_order(truth)
        o_est = relay_ring_order(est)
        e_true, e_est = _ring_edges(o_true), _ring_edges(o_est)
        agree.append(len(e_true & e_est) / len(e_true))
        c_true = ring_cost(truth, o_true)
        c_est = ring_cost(truth, o_est)
        ratios.append(c_est[0] / max(c_true[0], 1e-9))
    return {
        "edge_agreement": float(np.mean(agree)),
        "cost_ratio": float(np.mean(ratios)),
        "probe_bytes": int(view.probe_bytes),
    }


def run(
    quick: bool = True,
    view_factory: Callable[..., NetworkView] | None = None,
) -> dict:
    """``view_factory(trace)`` supplies the NetworkView for the relay-order
    agreement section; the default compares MonitorView and VivaldiView."""
    n_rounds = 50 if quick else 300
    results = {}

    # dataset 1: AWS-style matrix, averaged over jittered rounds
    base = aws_latency_matrix()
    trace = jitter_trace(base, n_rounds, np.random.default_rng(0))
    fr = [tiv_fraction(f) for f in trace]
    results["aws"] = float(np.mean(fr))

    # dataset 2: WonderNetwork-like dense global deployment (more nodes,
    # heavier congestion asymmetry)
    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=20, n_clusters=6, congestion_frac=0.22,
                       congestion_mult=(1.4, 2.5)),
        np.random.default_rng(1),
    )
    tr2 = jitter_trace(lat, n_rounds, np.random.default_rng(2))
    results["wondernet_like"] = float(np.mean([tiv_fraction(f) for f in tr2]))

    # dataset 3: Alibaba-like regional deployment (fewer regions, moderate)
    lat3, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3, congestion_frac=0.3,
                       congestion_mult=(1.3, 2.5)),
        np.random.default_rng(3),
    )
    tr3 = jitter_trace(lat3, n_rounds, np.random.default_rng(4))
    results["alibaba_like"] = float(np.mean([tiv_fraction(f) for f in tr3]))

    # relay-order agreement: the ring computed from *estimated* matrices vs
    # ground truth, per NetworkView regime
    agree_rounds = min(n_rounds, 30 if quick else 100)
    if view_factory is not None:
        views = {"custom": view_factory(trace)}
    else:
        views = {
            "monitor": MonitorView(TraceView(trace), noise=0.10,
                                   rng=np.random.default_rng(7)),
            "vivaldi": VivaldiView(TraceView(trace), samples_per_node=3,
                                   verify_every=5, seed=7),
            # monitor-seeded warmup: the first K rounds measure the full
            # mesh directly and seed the coordinates (the small-n fix)
            "vivaldi-warm": VivaldiView(TraceView(trace), samples_per_node=3,
                                        verify_every=5, warmup_rounds=5,
                                        seed=7),
        }
    agreement = {
        name: relay_order_agreement(trace, v, rounds=agree_rounds)
        for name, v in views.items()
    }
    for name, a in agreement.items():
        print(f"  relay-order vs truth [{name}]: edge agreement "
              f"{a['edge_agreement']:.1%}, bottleneck cost ratio "
              f"{a['cost_ratio']:.3f}, probes {a['probe_bytes']/1e3:.1f} KB")

    checks = [
        check(
            all(0.20 <= v <= 0.65 for v in results.values()),
            "Fig5: TIV prevalence across 3 WAN datasets in/near the paper's 28-57% band",
            ", ".join(f"{k}={v:.1%}" for k, v in results.items()),
        ),
        check(
            max(results.values()) >= 0.28,
            "Fig5: at least one dataset reaches the paper's lower bound 28%",
            f"max={max(results.values()):.1%}",
        ),
    ]
    if view_factory is None:
        checks += [
            check(
                agreement["monitor"]["cost_ratio"] < 1.15,
                "Control: monitor-estimated relay rings lose <15% bottleneck "
                "latency vs ground-truth rings",
                f"cost_ratio={agreement['monitor']['cost_ratio']:.3f}",
            ),
            check(
                agreement["vivaldi"]["probe_bytes"]
                < 0.5 * agreement["monitor"]["probe_bytes"],
                "Control: Vivaldi view cuts probe traffic >2x vs full-mesh "
                "monitoring (Sec 6.4 regime)",
                f"{agreement['vivaldi']['probe_bytes']} vs "
                f"{agreement['monitor']['probe_bytes']} B",
            ),
            check(
                agreement["vivaldi-warm"]["edge_agreement"]
                > agreement["vivaldi"]["edge_agreement"]
                and agreement["vivaldi-warm"]["cost_ratio"]
                <= agreement["vivaldi"]["cost_ratio"] + 1e-9,
                "Control: monitor-seeded warmup improves Vivaldi relay-order "
                "agreement at small n (coordinates start near-correct)",
                f"agreement {agreement['vivaldi']['edge_agreement']:.1%} -> "
                f"{agreement['vivaldi-warm']['edge_agreement']:.1%}, "
                f"cost_ratio {agreement['vivaldi']['cost_ratio']:.3f} -> "
                f"{agreement['vivaldi-warm']['cost_ratio']:.3f}",
            ),
            check(
                agreement["vivaldi-warm"]["probe_bytes"]
                < agreement["monitor"]["probe_bytes"],
                "Control: warmup's K full-mesh rounds keep Vivaldi under the "
                "monitor's probe budget",
                f"{agreement['vivaldi-warm']['probe_bytes']} vs "
                f"{agreement['monitor']['probe_bytes']} B",
            ),
        ]
    return {"figure": "Fig5", "tiv_fraction": results,
            "relay_order_agreement": agreement, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
