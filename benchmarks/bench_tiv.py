"""Paper Fig. 5: prevalence of Triangle Inequality Violations on WAN data.

The paper reports 28-57% of node pairs violating the triangle inequality
across 3 real-world WAN datasets (Alibaba inter-region metrics, AWS network
manager, WonderNetwork pings).  We evaluate three analogous latency sources:
the AWS-style 10-region matrix (static + jittered) and two synthetic
geo-clustered deployments with realistic congestion.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    GeoClusterSpec,
    aws_latency_matrix,
    geo_clustered_matrix,
    jitter_trace,
    tiv_fraction,
)

from .common import check


def run(quick: bool = True) -> dict:
    n_rounds = 50 if quick else 300
    results = {}

    # dataset 1: AWS-style matrix, averaged over jittered rounds
    base = aws_latency_matrix()
    trace = jitter_trace(base, n_rounds, np.random.default_rng(0))
    fr = [tiv_fraction(f) for f in trace]
    results["aws"] = float(np.mean(fr))

    # dataset 2: WonderNetwork-like dense global deployment (more nodes,
    # heavier congestion asymmetry)
    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=20, n_clusters=6, congestion_frac=0.22,
                       congestion_mult=(1.4, 2.5)),
        np.random.default_rng(1),
    )
    tr2 = jitter_trace(lat, n_rounds, np.random.default_rng(2))
    results["wondernet_like"] = float(np.mean([tiv_fraction(f) for f in tr2]))

    # dataset 3: Alibaba-like regional deployment (fewer regions, moderate)
    lat3, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3, congestion_frac=0.3,
                       congestion_mult=(1.3, 2.5)),
        np.random.default_rng(3),
    )
    tr3 = jitter_trace(lat3, n_rounds, np.random.default_rng(4))
    results["alibaba_like"] = float(np.mean([tiv_fraction(f) for f in tr3]))

    checks = [
        check(
            all(0.20 <= v <= 0.65 for v in results.values()),
            "Fig5: TIV prevalence across 3 WAN datasets in/near the paper's 28-57% band",
            ", ".join(f"{k}={v:.1%}" for k, v in results.items()),
        ),
        check(
            max(results.values()) >= 0.28,
            "Fig5: at least one dataset reaches the paper's lower bound 28%",
            f"max={max(results.values()):.1%}",
        ),
    ]
    return {"figure": "Fig5", "tiv_fraction": results, "checks": checks}


if __name__ == "__main__":
    run(quick=False)
