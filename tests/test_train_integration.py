"""Training-plane integration: trainer loop, checkpoint restart, elastic
reshard, straggler mitigation, fault injection.  8 forced host devices."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    available_steps,
    gc_incomplete,
    latest_step,
    restore,
    save,
)
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.collectives import SyncConfig
from repro.launch.mesh import make_small_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import FaultInjected, StragglerMonitor, Trainer, TrainerConfig


def _mk_trainer(tmp_path, *, steps=8, sync="hier", mesh=None, seed=0):
    cfg = get_smoke_config("minitron-8b")
    mesh = mesh or make_small_mesh()
    tcfg = TrainConfig(
        sync=SyncConfig(strategy=sync, density=0.25, chunk=64, min_leaf_size=64),
        # fixed optimizer horizon: the LR schedule must not depend on how many
        # steps one particular (possibly interrupted) run executes
        optim=AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=2),
    )
    run_cfg = TrainerConfig(
        steps=steps, ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=4,
        ckpt_async=False, log_every=100, seed=seed,
    )
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, seed=seed)
    return Trainer(cfg, mesh, tcfg, run_cfg, data_cfg)


def test_loss_decreases_and_checkpoints(tmp_path):
    tr = _mk_trainer(tmp_path, steps=8)
    hist = tr.run()
    assert len(hist) == 8
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert available_steps(str(tmp_path / "ckpt")) == [4, 8]


def test_restart_resumes_identically(tmp_path):
    # run 8 steps straight through
    tr1 = _mk_trainer(tmp_path / "a", steps=8)
    h1 = tr1.run()
    # run 4 steps, "crash", resume a fresh trainer, run to 8
    tr2 = _mk_trainer(tmp_path / "b", steps=4)
    tr2.run()
    tr3 = _mk_trainer(tmp_path / "b", steps=8)
    assert tr3.maybe_resume()
    assert tr3.step_idx == 4
    h3 = tr3.run()
    # deterministic data + state restore => identical trajectory
    np.testing.assert_allclose(h1[-1]["loss"], h3[-1]["loss"], rtol=1e-4)


def test_fault_injection_rolls_back_and_replays(tmp_path):
    tr = _mk_trainer(tmp_path, steps=8)
    fired = {"n": 0}

    def injector(step):
        if step == 5 and fired["n"] == 0:
            fired["n"] += 1
            raise FaultInjected("simulated device loss")

    hist = tr.run(fault_injector=injector)
    assert fired["n"] == 1
    assert tr.step_idx == 8
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_elastic_reshard_across_meshes(tmp_path):
    """A checkpoint written on one mesh restores onto a different mesh and
    training continues — elastic scaling."""
    mesh_a = make_small_mesh((2, 2, 2))
    tr_a = _mk_trainer(tmp_path, steps=4, mesh=mesh_a)
    tr_a.run()
    # restore onto a single-pod 4-device mesh (different topology)
    mesh_b = make_small_mesh((2, 2), ("data", "model"))
    tr_b = _mk_trainer(tmp_path, steps=6, mesh=mesh_b)
    assert tr_b.maybe_resume()
    assert tr_b.step_idx == 4
    hist = tr_b.run()
    assert tr_b.step_idx == 6
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"w": jnp.arange(16.0).reshape(4, 4), "step": jnp.asarray(3)}
    save(d, 3, state)
    # leave a fake interrupted save behind
    os.makedirs(os.path.join(d, "step_9.tmp"))
    assert latest_step(d) == 3          # tmp never visible
    assert gc_incomplete(d) == 1
    back = restore(d, 3, state)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(state["w"]))
    # idempotent double-restore
    back2 = restore(d, 3, back)
    np.testing.assert_array_equal(np.asarray(back2["w"]), np.asarray(state["w"]))


def test_straggler_monitor_damping():
    m = StragglerMonitor(threshold=1.5, sustain=3)
    assert not m.observe(1.0)
    # transient spike: suppressed
    assert not m.observe(5.0)
    assert not m.observe(5.0)
    assert not m.observe(1.0)
    # sustained: trips once
    trips = [m.observe(10.0) for _ in range(3)]
    assert trips[-1] and m.trips == 1


def test_straggler_triggers_replan_hook(tmp_path):
    events = []
    tr = _mk_trainer(tmp_path, steps=6)
    tr.monitor = StragglerMonitor(threshold=0.0, sustain=1)  # trip every step
    tr.on_straggler = lambda t: events.append(t.step_idx)
    tr.run()
    assert len(events) >= 1


def test_synthetic_data_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=5)
    a = SyntheticLM(cfg).batch(7)
    b = SyntheticLM(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # learnable structure: copy probability leaves repeated tokens
    toks = a["tokens"]
    repeats = (toks[:, 1:] == toks[:, :-1]).mean()
    assert repeats > 0.02
