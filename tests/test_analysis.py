"""Static-analysis passes: schedule verifier sweep + seeded mutations,
config-compatibility rule table, determinism lint (fixtures + clean repo).

The verifier sweep is the static counterpart of the makespan gate: every
builder x every benchmark topology x the stitched streaming schedules must
satisfy every engine invariant — and each seeded mutation below must be
*caught*, so a refactor can neither break a builder silently nor lobotomize
the verifier silently.
"""

from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    ScheduleVerificationError,
    check_config,
    lint_file,
    lint_paths,
    reset_verified_schedule_count,
    validate_config,
    verified_schedule_count,
    verify_schedule,
)
from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    Transfer,
    TransmissionSchedule,
    WANSimulator,
    YCSBConfig,
    YCSBGenerator,
    all_to_all_schedule,
    aws_latency_matrix,
    geo_clustered_matrix,
    hierarchical_schedule,
    jitter_trace,
    leader_schedule,
    stitch_schedules,
)
from repro.core.planner import kcenter_grouping, optimal_k

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"

PAYLOAD = 250_000.0


def _topologies() -> dict[str, np.ndarray]:
    """The three benchmark topologies (mirrors bench_makespan_regression)."""
    lat_w, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=20, n_clusters=6, congestion_frac=0.22,
                       congestion_mult=(1.4, 2.5)),
        np.random.default_rng(1),
    )
    lat_a, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3, congestion_frac=0.3,
                       congestion_mult=(1.3, 2.5)),
        np.random.default_rng(3),
    )
    return {"aws": aws_latency_matrix(), "wondernet_like": lat_w,
            "alibaba_like": lat_a}


TOPOLOGIES = _topologies()


def _schedules(lat: np.ndarray) -> dict[str, TransmissionSchedule]:
    """Every builder variant on one topology."""
    n = lat.shape[0]
    plan = kcenter_grouping(lat, max(2, int(round(optimal_k(n)))))
    gp = np.array([len(g) * PAYLOAD * 0.4 for g in plan.groups])
    return {
        "flat": all_to_all_schedule(n, PAYLOAD),
        "hier": hierarchical_schedule(plan, PAYLOAD),
        "geococo": hierarchical_schedule(
            plan, PAYLOAD, group_payload_bytes=gp, lat=lat, tiv=True
        ),
        "leader": leader_schedule(n, 0, PAYLOAD),
        "leader_planned": leader_schedule(n, 0, PAYLOAD, plan),
    }


def _stitched(lat: np.ndarray, n_epochs: int = 8) -> TransmissionSchedule:
    """An 8-epoch streaming stitch of geococo rounds with per-node exec
    stages and a cadence clock — what EngineConfig(streaming=True) runs."""
    n = lat.shape[0]
    rng = np.random.default_rng(11)
    trace = jitter_trace(lat, n_epochs, rng)
    rounds = [_schedules(ep)["geococo"] for ep in trace]
    exec_ms = rng.uniform(0.05, 0.6, size=(n_epochs, n))
    return stitch_schedules(
        rounds, node_exec_ms=exec_ms.tolist(), epoch_ms=2.0, n=n
    )


# ---------------------------------------------------------------------------
# Schedule verifier: exhaustive zero-violation sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_all_builders_verify_clean(topo):
    lat = TOPOLOGIES[topo]
    n = lat.shape[0]
    for name, sched in _schedules(lat).items():
        violations = verify_schedule(sched, n_nodes=n)
        assert violations == [], f"{topo}/{name}: {violations}"


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_stitched_streaming_verifies_clean(topo):
    lat = TOPOLOGIES[topo]
    sched = _stitched(lat)
    assert sched.verify(n_nodes=lat.shape[0]) == []
    # the stitch really is multi-epoch with a clock chain
    assert max(t.epoch for t in sched.transfers) == 7
    assert sum(t.tag == "clock" for t in sched.transfers) == 7


def test_legacy_phase_form_verifies_clean():
    # the legacy list-of-phases constructor installs barrier edges
    sched = TransmissionSchedule([
        [Transfer(0, 1, 10.0), Transfer(1, 2, 10.0)],
        [Transfer(2, 0, 10.0)],
    ])
    assert verify_schedule(sched, n_nodes=3) == []


def test_verified_counter_counts_only_clean_schedules():
    reset_verified_schedule_count()
    sched = all_to_all_schedule(4, PAYLOAD)
    assert verify_schedule(sched, n_nodes=4) == []
    assert verified_schedule_count() == 1
    bad = all_to_all_schedule(4, PAYLOAD)
    bad.transfers[0] = dataclasses.replace(bad.transfers[0], nbytes=-1.0)
    assert verify_schedule(bad, n_nodes=4) != []
    assert verified_schedule_count() == 1


# ---------------------------------------------------------------------------
# Schedule verifier: seeded mutations must be caught
# ---------------------------------------------------------------------------
# TransmissionSchedule's constructor enforces only topological order, and
# these mutations bypass even that by editing the transfers list in place —
# exactly the hand-built / refactor-bug schedules the static pass exists for.


def _rules(violations) -> set[str]:
    return {v.rule for v in violations}


def test_mutation_cycle_caught():
    sched = _stitched(TOPOLOGIES["alibaba_like"], n_epochs=3)
    i, j = 10, 20
    sched.transfers[i] = dataclasses.replace(sched.transfers[i], deps=(j,))
    sched.transfers[j] = dataclasses.replace(sched.transfers[j], deps=(i,))
    assert "cycle" in _rules(verify_schedule(sched))


def test_mutation_dangling_dep_caught():
    sched = _schedules(TOPOLOGIES["aws"])["geococo"]
    m = len(sched.transfers)
    sched.transfers[5] = dataclasses.replace(
        sched.transfers[5], deps=(m + 7,)
    )
    assert "dep-bounds" in _rules(verify_schedule(sched))


def test_mutation_nonmonotone_phase_caught():
    sched = _schedules(TOPOLOGIES["aws"])["hier"]
    # find a transfer with a dependency and collapse the phase gap
    i = next(i for i, t in enumerate(sched.transfers) if t.deps)
    d = sched.transfers[i].deps[0]
    phase_of = list(sched.phase_of)
    phase_of[d] = phase_of[i]
    sched.phase_of = tuple(phase_of)
    assert "phase-monotone" in _rules(verify_schedule(sched))


def test_mutation_negative_payload_caught():
    sched = _schedules(TOPOLOGIES["aws"])["flat"]
    sched.transfers[3] = dataclasses.replace(
        sched.transfers[3], nbytes=-250_000.0
    )
    assert "negative-payload" in _rules(verify_schedule(sched))


def test_mutation_broken_clock_chain_caught():
    sched = _stitched(TOPOLOGIES["alibaba_like"], n_epochs=4)
    clocks = [i for i, t in enumerate(sched.transfers) if t.tag == "clock"]
    assert len(clocks) == 3
    # unhook the second clock from the first: the cadence chain is no
    # longer linear
    c = clocks[1]
    sched.transfers[c] = dataclasses.replace(sched.transfers[c], deps=())
    assert "clock-chain" in _rules(verify_schedule(sched))


def test_mutation_node_out_of_bounds_caught():
    sched = all_to_all_schedule(6, PAYLOAD)
    assert "node-bounds" in _rules(verify_schedule(sched, n_nodes=4))


def test_mutation_payload_on_local_stage_caught():
    sched = _stitched(TOPOLOGIES["alibaba_like"], n_epochs=2)
    i = next(i for i, t in enumerate(sched.transfers) if t.tag == "exec")
    sched.transfers[i] = dataclasses.replace(sched.transfers[i], nbytes=64.0)
    assert "local-stage" in _rules(verify_schedule(sched))


def test_mutation_epoch_gap_caught():
    sched = _stitched(TOPOLOGIES["alibaba_like"], n_epochs=3)
    i = len(sched.transfers) - 1
    sched.transfers[i] = dataclasses.replace(sched.transfers[i], epoch=5)
    assert "epoch-contiguity" in _rules(verify_schedule(sched))


def test_mutation_dep_on_later_epoch_caught():
    sched = _stitched(TOPOLOGIES["alibaba_like"], n_epochs=3)
    # retag an early transfer's dep target into the future
    i = next(i for i, t in enumerate(sched.transfers)
             if t.deps and t.epoch == 1)
    d = sched.transfers[i].deps[0]
    sched.transfers[d] = dataclasses.replace(sched.transfers[d], epoch=2)
    vs = verify_schedule(sched)
    assert "epoch-monotone" in _rules(vs)


# ---------------------------------------------------------------------------
# Schedule verifier: auto-generated mutation corpus
# ---------------------------------------------------------------------------
# repro.analysis.mutate generalizes the hand-seeded mutations above into one
# generator per rule; the gate is 100% catch rate and 0 false positives over
# every builder x topology base (plus the stitched streaming schedule).


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_mutation_corpus_catch_rate(topo):
    from repro.analysis.mutate import MUTATORS

    lat = TOPOLOGIES[topo]
    n = lat.shape[0]
    bases = dict(_schedules(lat))
    bases["stitched"] = _stitched(lat, n_epochs=4)
    rng = np.random.default_rng(20250807)
    applicable: set[str] = set()
    for base_name in sorted(bases):
        base = bases[base_name]
        assert verify_schedule(base, n_nodes=n) == []
        for rule in sorted(MUTATORS):
            for _ in range(3):
                mut = MUTATORS[rule](base, rng, n_nodes=n)
                if mut is None:
                    continue
                applicable.add(rule)
                caught = _rules(verify_schedule(mut, n_nodes=n))
                assert rule in caught, (
                    f"{topo}/{base_name}: generated {rule!r} mutant "
                    f"escaped the verifier (caught: {caught})"
                )
        # zero false positives: mutation clones, so the base stays clean
        assert verify_schedule(base, n_nodes=n) == []
    # every rule must be expressible somewhere in the base set
    assert applicable == set(MUTATORS)


def test_mutate_schedule_rejects_unknown_rule():
    from repro.analysis.mutate import mutate_schedule

    sched = all_to_all_schedule(4, PAYLOAD)
    with pytest.raises(ValueError, match="unknown rule"):
        mutate_schedule(sched, "no-such-rule", np.random.default_rng(0))


# ---------------------------------------------------------------------------
# Engine wiring: verify_schedules=True
# ---------------------------------------------------------------------------


def test_simulator_verify_rejects_corrupt_schedule():
    lat = aws_latency_matrix()
    sched = all_to_all_schedule(lat.shape[0], PAYLOAD)
    sched.transfers[0] = dataclasses.replace(
        sched.transfers[0], nbytes=-1.0
    )
    sim = WANSimulator(lat, 500.0, verify=True)
    with pytest.raises(ScheduleVerificationError, match="negative-payload"):
        sim.run(sched)
    # ScheduleVerificationError is a ValueError: existing callers that
    # catch config errors keep working
    assert issubclass(ScheduleVerificationError, ValueError)
    # verification off by default: the same corrupt schedule still runs
    WANSimulator(lat, 500.0).run(sched)


def test_streaming_engine_runs_with_verification():
    lat = TOPOLOGIES["alibaba_like"]
    n = lat.shape[0]
    reset_verified_schedule_count()
    cfg = EngineConfig(
        n_nodes=n, streaming=True, grouping=True, filtering=True,
        tiv=True, planner="kcenter", epoch_ms=2.0, txn_exec_us=5.0,
        verify_schedules=True,
    )
    eng = GeoCluster(cfg, bandwidth_mbps=100.0, seed=7)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=200, theta=0.9, read_ratio=0.3,
                   hot_write_frac=0.3),
        n, seed=3,
    )
    trace = jitter_trace(lat, 4, np.random.default_rng(17))
    rs = eng.run(gen, trace, txns_per_node=10, n_epochs=4)
    assert rs.wall_s > 0.0
    # every simulated schedule passed the static verifier
    assert verified_schedule_count() > 0


# ---------------------------------------------------------------------------
# Config compatibility: the declarative rule table
# ---------------------------------------------------------------------------
# Stub config classes (matching class *name*, which is how the stringly
# rule table dispatches) let us probe individual rules — including invalid
# states the real constructors refuse to build.


def _engine_stub(**overrides):
    fields = dict(
        streaming=False, barrier=False, staleness_feedback=False,
        serve=None, grouping=False, schedule_name=None,
        resolved_schedule_name="all_to_all", stream_mode="incremental",
        keep_epochs=True, stats_window=64,
    )
    fields.update(overrides)
    cfg = type("EngineConfig", (), {})()
    for k, v in fields.items():
        setattr(cfg, k, v)
    return cfg


def _serve_stub(**overrides):
    fields = dict(
        read_ratio=0.9, max_staleness_ms=150.0, ops_per_client_s=1.0,
        clients_per_node=1000.0, cache_keys=0, n_keys=1000,
        keep_epochs=True,
    )
    fields.update(overrides)
    cfg = type("ServeConfig", (), {})()
    for k, v in fields.items():
        setattr(cfg, k, v)
    return cfg


def test_check_config_clean():
    assert check_config(_engine_stub()) == []
    assert check_config(_engine_stub(), stage="cluster") == []
    assert check_config(_serve_stub()) == []


def test_check_config_structured_diagnostics():
    vs = check_config(_engine_stub(streaming=True, barrier=True))
    assert [v.rule for v in vs] == ["streaming-x-barrier"]
    assert "no barrier-phase semantics" in vs[0].message
    # multiple violations surface together, in rule-table order
    vs = check_config(_serve_stub(read_ratio=2.0, max_staleness_ms=-1.0))
    assert [v.rule for v in vs] == ["read-ratio-range",
                                    "staleness-bound-range"]
    vs = check_config(_engine_stub(stream_mode="eager"))
    assert [v.rule for v in vs] == ["stream-mode-value"]


def test_check_config_stage_gating():
    # a named schedule without grouping is fine at construction but
    # refused at engine attach (the historical raise location)
    cfg = _engine_stub(schedule_name="hierarchical")
    assert check_config(cfg) == []
    vs = check_config(cfg, stage="cluster")
    assert [v.rule for v in vs] == ["flat-engine-schedule"]
    with pytest.raises(ValueError, match="requires grouping=True"):
        validate_config(cfg, stage="cluster")
    with pytest.raises(ValueError, match="unknown stage"):
        check_config(cfg, stage="bogus")


def test_check_config_grouped_builder_contract():
    cfg = _engine_stub(grouping=True, resolved_schedule_name="all_to_all")
    vs = check_config(cfg, stage="cluster")
    assert [v.rule for v in vs] == ["grouped-schedule-contract"]
    assert "group_payload_bytes" in vs[0].message


def test_validate_config_raises_first_message():
    with pytest.raises(ValueError, match=r"read_ratio must be in \[0, 1\]"):
        validate_config(_serve_stub(read_ratio=-0.1, cache_keys=5000))


def test_real_configs_still_validate():
    # the migrated constructors route through validate_config
    with pytest.raises(ValueError, match="requires streaming=True"):
        EngineConfig(n_nodes=4, staleness_feedback=True)
    from repro.serve import ServeConfig

    with pytest.raises(ValueError, match="must be positive"):
        ServeConfig(ops_per_client_s=0.0)


# ---------------------------------------------------------------------------
# Determinism lint
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture,rule", [
    ("wallclock.py", "wallclock"),
    ("module_rng.py", "module-rng"),
    ("unordered_set.py", "unordered-set-iter"),
    ("dict_iter.py", "unordered-dict-iter"),
    ("float_sum.py", "float-sum-unordered"),
    ("mutable_default.py", "mutable-default"),
    ("float_eq.py", "float-time-eq"),
])
def test_lint_fixture_trips_rule_exactly_once(fixture, rule):
    violations = lint_file(FIXTURES / fixture)
    assert [v.rule for v in violations] == [rule], violations


def test_lint_clean_fixture():
    # sanctioned idioms + an inline pragma: zero violations
    assert lint_file(FIXTURES / "clean.py") == []


def test_repo_is_lint_clean():
    violations = lint_paths([REPO / "src", REPO / "benchmarks"])
    assert violations == [], "\n".join(str(v) for v in violations)


def test_lint_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.analysis.lint"]
    dirty = subprocess.run(
        cmd + [str(FIXTURES / "wallclock.py")],
        capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert dirty.returncode == 1
    assert "wallclock" in dirty.stdout
    clean = subprocess.run(
        cmd + [str(FIXTURES / "clean.py")],
        capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert clean.returncode == 0, clean.stdout + clean.stderr


# ---------------------------------------------------------------------------
# Incremental (per-epoch) schedule verifier
# ---------------------------------------------------------------------------


def _append_epochs(v, st, rounds, mutate=None):
    """Drive StitchState + StreamScheduleVerifier over ``rounds``; optional
    ``mutate(k, seg, ranks)`` corrupts the segment before verification."""
    out = []
    for k, sk in enumerate(rounds):
        seg, ranks = st.append(sk, [1.0] * st.n)
        if mutate is not None:
            seg = mutate(k, list(seg), ranks)
        out.append(v.check_epoch(seg, ranks, frontier=st.frontier()))
    return out


def test_stream_verifier_clean_per_epoch():
    """The incremental verifier accepts every epoch of a stitched stream
    built from the real builders, and counts each clean segment."""
    from repro.analysis import StreamScheduleVerifier
    from repro.core import StitchState

    n = 5
    rounds = [all_to_all_schedule(n, PAYLOAD),
              leader_schedule(n, 2, PAYLOAD),
              all_to_all_schedule(n, PAYLOAD)]
    reset_verified_schedule_count()
    v = StreamScheduleVerifier(n_nodes=n)
    st = StitchState(n, epoch_ms=2.0)
    for violations in _append_epochs(v, st, rounds):
        assert violations == []
    assert verified_schedule_count() == len(rounds)
    assert v.epoch == len(rounds) and v.size == st.size


def test_stream_verifier_catches_evicted_dependency():
    """A dependency on a pre-frontier transfer (whose finish time the
    timeline has evicted) trips the incremental-only stream-frontier rule."""
    from repro.analysis import StreamScheduleVerifier
    from repro.core import StitchState

    n = 4
    rounds = [all_to_all_schedule(n, PAYLOAD) for _ in range(3)]

    def mutate(k, seg, ranks):
        if k == 2:  # index 0 is epoch 0's clockless head: long evicted
            i = next(j for j, t in enumerate(seg) if t.tag == "exec")
            seg[i] = dataclasses.replace(seg[i], deps=seg[i].deps + (0,))
        return seg

    outs = _append_epochs(StreamScheduleVerifier(n_nodes=n),
                          StitchState(n, epoch_ms=2.0), rounds, mutate)
    assert outs[0] == [] and outs[1] == []
    assert "stream-frontier" in {vi.rule for vi in outs[2]}


def test_stream_verifier_catches_epoch_and_clock_mutations():
    from repro.analysis import StreamScheduleVerifier
    from repro.core import StitchState

    n = 4
    rounds = [all_to_all_schedule(n, PAYLOAD) for _ in range(3)]

    def wrong_epoch(k, seg, ranks):
        if k == 1:
            seg[-1] = dataclasses.replace(seg[-1], epoch=7)
        return seg

    outs = _append_epochs(StreamScheduleVerifier(n_nodes=n),
                          StitchState(n, epoch_ms=2.0), rounds, wrong_epoch)
    assert "epoch-contiguity" in {vi.rule for vi in outs[1]}

    def broken_clock(k, seg, ranks):
        if k == 2:
            i = next(j for j, t in enumerate(seg) if t.tag == "clock")
            seg[i] = dataclasses.replace(seg[i], deps=())
        return seg

    outs = _append_epochs(StreamScheduleVerifier(n_nodes=n),
                          StitchState(n, epoch_ms=2.0), rounds, broken_clock)
    assert "clock-chain" in {vi.rule for vi in outs[2]}

    def nonmonotone(k, seg, ranks):
        if k == 1:  # a wire depending on a same-rank wire
            ranks[-1] = ranks[-2]
        return seg

    # note: mutating ranks, not transfers — phase-monotone reads both
    v = StreamScheduleVerifier(n_nodes=n)
    st = StitchState(n, epoch_ms=2.0)
    seg, ranks = st.append(rounds[0], [1.0] * n)
    assert v.check_epoch(seg, ranks, frontier=st.frontier()) == []
    seg, ranks = st.append(rounds[1], [1.0] * n)
    bad = list(seg)
    bad[-1] = dataclasses.replace(bad[-1], deps=bad[-1].deps + (st.size - 2,))
    ranks2 = list(ranks)
    ranks2[-1] = ranks2[-2]
    out = v.check_epoch(bad, ranks2, frontier=st.frontier())
    assert "phase-monotone" in {vi.rule for vi in out}


def test_stream_verifier_engine_wiring():
    """EngineConfig(verify_schedules=True) routes the incremental engine
    through the per-epoch verifier: clean runs count segments."""
    from repro.analysis import (
        reset_verified_schedule_count as reset,
        verified_schedule_count as count,
    )

    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=5, n_clusters=2), np.random.default_rng(0)
    )
    trace = jitter_trace(lat, 4, np.random.default_rng(1))
    cfg = EngineConfig(n_nodes=5, streaming=True, epoch_ms=2.0,
                       verify_schedules=True)
    eng = GeoCluster(cfg, seed=5)
    gen = YCSBGenerator(YCSBConfig(n_keys=40), 5, seed=2)
    reset()
    eng.run(gen, trace, txns_per_node=3, n_epochs=4)
    assert count() >= 4  # one clean segment per appended epoch
