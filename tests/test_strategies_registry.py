"""Two-plane strategy registry + device-plane API coverage.

Covers the unified strategy surface: registry round-trips, EngineConfig's
named-strategy/boolean shims, GeoCluster resolving implementations through
the registry, SyncConfig validation, the analytic byte estimator against
bytes actually moved on the 8-host-device mesh, and the task-preservation
property of the filtered exchange.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

from repro.core import strategies
from repro.core.replication import EngineConfig, GeoCluster, RunStats
from repro.core.whitedata import no_filter


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    marker = object()
    strategies.register("test_kind", "thing", marker)
    assert strategies.get("test_kind", "thing") is marker
    assert "thing" in strategies.names("test_kind")
    assert "test_kind" in strategies.kinds()


def test_registry_unknown_name_lists_known():
    with pytest.raises(KeyError, match="milp"):
        strategies.get("planner", "definitely-not-registered")


def test_core_strategies_registered():
    assert {"milp", "kcenter", "agglomerative", "kmeans", "random", "none"} \
        <= set(strategies.names("planner"))
    assert {"all_to_all", "hierarchical", "leader"} \
        <= set(strategies.names("schedule"))
    assert {"whitedata", "none"} <= set(strategies.names("filter"))


def test_two_planes_share_strategy_names():
    """flat / hier / geococo mean the same thing to both planes."""
    import repro.dist.collectives  # noqa: F401  (registers device_sync)

    shared = {"flat", "hier", "geococo"}
    assert shared <= set(strategies.names("device_sync"))
    assert shared <= set(strategies.names("wan_sync"))


# ---------------------------------------------------------------------------
# EngineConfig: named strategies + boolean back-compat shims
# ---------------------------------------------------------------------------


def test_engineconfig_named_strategy_drives_stages():
    flat = EngineConfig(n_nodes=5, sync_strategy="flat")
    assert not flat.grouping and not flat.filtering and not flat.tiv
    assert flat.resolved_schedule_name == "all_to_all"
    assert flat.resolved_filter_name == "none"

    geo = EngineConfig(n_nodes=5, sync_strategy="geococo")
    assert geo.grouping and geo.filtering and geo.tiv and not geo.compression
    assert geo.resolved_schedule_name == "hierarchical"
    assert geo.resolved_filter_name == "whitedata"

    zl = EngineConfig(n_nodes=5, sync_strategy="geococo-zlib")
    assert zl.compression


def test_engineconfig_boolean_shim_derives_name():
    assert EngineConfig(n_nodes=4, grouping=False).resolved_sync_strategy == "flat"
    # faithful naming: the 'hier' preset has tiv=False, so a boolean config
    # with the relay stage on gets the +tiv modifier, never a wrong preset
    hier = EngineConfig(n_nodes=4, grouping=True, filtering=False)
    assert hier.resolved_sync_strategy == "hier+tiv"
    assert hier.resolved_filter_name == "none"
    no_tiv = EngineConfig(n_nodes=4, grouping=True, filtering=False, tiv=False)
    assert no_tiv.resolved_sync_strategy == "hier"
    assert EngineConfig(n_nodes=4).resolved_sync_strategy == "geococo"
    assert EngineConfig(n_nodes=4, tiv=False).resolved_sync_strategy == "geococo-tiv"
    # modified names are not registered presets: round-tripping fails loudly
    with pytest.raises(KeyError):
        EngineConfig(n_nodes=4, sync_strategy="hier+tiv")


def test_geocluster_rejects_schedule_without_grouping():
    cfg = EngineConfig(n_nodes=4, grouping=False, schedule_name="leader")
    with pytest.raises(ValueError, match="grouping=True"):
        GeoCluster(cfg)


def test_engineconfig_replace_respects_boolean_ablation():
    """dataclasses.replace on the stage booleans must not be silently
    reverted by a derived strategy name (ablation-sweep regression)."""
    import dataclasses

    base = EngineConfig(n_nodes=4)
    ablated = dataclasses.replace(base, filtering=False)
    assert not ablated.filtering
    assert ablated.resolved_sync_strategy == "hier+tiv"  # default tiv stays on
    assert ablated.resolved_filter_name == "none"


def test_geocluster_rejects_incompatible_schedule_early():
    """A registered builder that can't drive the grouping engine fails at
    construction, not mid-run."""
    cfg = EngineConfig(n_nodes=4, schedule_name="leader")
    with pytest.raises(ValueError, match="grouping engine"):
        GeoCluster(cfg)


def test_engineconfig_rejects_unknown_names():
    with pytest.raises(KeyError):
        EngineConfig(n_nodes=4, sync_strategy="warp-drive")
    with pytest.raises(KeyError):
        EngineConfig(n_nodes=4, planner="warp-drive")
    with pytest.raises(KeyError):
        EngineConfig(n_nodes=4, filter_name="warp-drive")


def test_geocluster_resolves_filter_via_registry():
    """A custom registered filter is picked up without touching the engine."""
    calls = {"n": 0}

    def counting_filter(txns, snapshot):
        calls["n"] += 1
        return no_filter(txns, snapshot)

    strategies.register("filter", "counting", counting_filter)
    from repro.core.workload import YCSBConfig, YCSBGenerator

    n = 4
    lat = np.full((n, n), 10.0)
    np.fill_diagonal(lat, 0.0)
    cfg = EngineConfig(n_nodes=n, planner="kcenter", filter_name="counting")
    eng = GeoCluster(cfg, seed=0)
    gen = YCSBGenerator(YCSBConfig(n_keys=50, value_bytes=16), n, seed=1)
    stats = eng.run(gen, [lat] * 3, txns_per_node=3)
    assert calls["n"] > 0
    assert stats.committed > 0


# ---------------------------------------------------------------------------
# RunStats empty-run regression (satellite fix)
# ---------------------------------------------------------------------------


def test_runstats_empty_run_does_not_raise():
    rs = RunStats(epochs=[], msg_matrix=np.zeros((2, 2), dtype=int),
                  plan_time_s=0.0, state_digest="", value_digest="")
    assert rs.p99_sync_ms == 0.0
    assert rs.makespans_ms.shape == (0,)
    assert rs.throughput_tps == 0.0
    assert rs.committed == 0 and rs.total_txns == 0
    assert rs.white_stats.total_updates == 0


# ---------------------------------------------------------------------------
# SyncConfig validation
# ---------------------------------------------------------------------------


def test_syncconfig_rejects_invalid_values():
    from repro.dist.collectives import SyncConfig

    with pytest.raises(ValueError, match="registered"):
        SyncConfig(strategy="warp-drive")
    with pytest.raises(ValueError, match="density"):
        SyncConfig(strategy="geococo", density=0.0)
    with pytest.raises(ValueError, match="density"):
        SyncConfig(strategy="geococo", density=1.5)
    with pytest.raises(ValueError, match="chunk"):
        SyncConfig(chunk=0)
    with pytest.raises(ValueError, match="min_leaf_size"):
        SyncConfig(min_leaf_size=-1)


def test_syncconfig_residual_requirements_come_from_registry():
    from repro.dist.collectives import SyncConfig

    assert SyncConfig(strategy="geococo").needs_residuals
    assert not SyncConfig(strategy="hier").needs_residuals
    assert not SyncConfig(strategy="flat").needs_residuals


# ---------------------------------------------------------------------------
# device plane: estimator vs bytes actually moved; task preservation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    import jax

    from repro.launch.mesh import make_small_mesh

    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return make_small_mesh()


def test_estimate_matches_bytes_actually_moved(mesh):
    """The analytic wire model and a real exchange agree value-for-value."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import SyncConfig, estimate_sync_bytes, sync_gradients

    cfg = SyncConfig(strategy="geococo", density=0.25, chunk=64,
                     min_leaf_size=64)
    rng = np.random.default_rng(3)
    tree = {
        "big": jnp.asarray(rng.normal(size=(4, 256)), jnp.float32),   # filtered
        "small": jnp.asarray(rng.normal(size=(8,)), jnp.float32),     # dense
    }
    res = jax.tree.map(lambda l: jnp.zeros_like(l), tree)

    def body(big, small):
        g = {"big": big * (1.0 + jax.lax.axis_index("pod").astype(jnp.float32)),
             "small": small}
        r = {"big": jnp.zeros_like(big), "small": jnp.zeros_like(small)}
        synced, new_r = sync_gradients(g, r, cfg, n_pods=2)
        # what this pod actually put on the wire, per leaf
        sent_big = (g["big"] + r["big"]) - new_r["big"]
        return synced["big"], sent_big

    f = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names={"pod"}, check_vma=False,
    ))
    _, sent_big = f(tree["big"], tree["small"])

    # measured wire content: nonzero filtered values + dense small leaf
    sparse_vals = int((np.asarray(sent_big) != 0.0).sum())
    dense_vals = tree["small"].size
    ring = 2.0 * (2 - 1) / 2
    measured_bytes = ring * (sparse_vals * (4 + 4) + dense_vals * 4)

    est = estimate_sync_bytes(tree, cfg, n_pods=2)
    assert est == pytest.approx(measured_bytes, rel=1e-6)
    # sanity: the filtered leaf kept exactly density * size values
    assert sparse_vals == int(0.25 * tree["big"].size)


def test_chunked_topk_preserves_topk_mass(mesh):
    """Task preservation: what crosses the wire is exactly the top-k mass,
    and nothing is lost — sent + residual reconstructs the accumulator."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import chunked_topk_exchange

    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    chunk, density = 64, 0.125

    def body(g, r):
        out, new_r = chunked_topk_exchange(
            g, r, axis="pod", density=density, chunk=chunk
        )
        return out, new_r

    f = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names={"pod"}, check_vma=False,
    ))
    _, new_r = f(g, r)

    acc = np.asarray(g) + np.asarray(r)
    sent = acc - np.asarray(new_r)
    # exact reconstruction: no mass is created or destroyed
    np.testing.assert_allclose(sent + np.asarray(new_r), acc, rtol=1e-6)
    k = int(round(density * chunk))
    for row in range(acc.shape[0]):
        s, res = np.abs(sent[row]), np.abs(acc[row] - sent[row])
        assert (s > 0).sum() == k
        # every transmitted value dominates every retained one: top-k mass
        assert s[s > 0].min() >= res[res > 0].max() - 1e-6
