"""The read serving plane (``repro.serve``).

Covers the three layers separately and wired together:

* ``ServeConfig`` validation and the streaming-only engine gate,
* ``simulate_serving`` on synthetic commit matrices — staleness-bound
  semantics, redirect/reject policies, cache-aside accounting, latency
  percentiles, and the exact monotonicity theorems the benchmark gates on,
* ``GeoCluster`` integration — ``RunStats.serve`` population and the
  digest-neutrality regression (the serving plane reads the measured
  ``node_commit_ms`` matrix post hoc; it must never perturb commits).
"""

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    TPCCConfig,
    TPCCGenerator,
    geo_clustered_matrix,
    jitter_trace,
)
from repro.core.workload import ZipfianSampler
from repro.serve import (
    ServeConfig,
    simulate_serving,
    view_epochs,
    view_staleness_ms,
    weighted_percentile,
)


# ---------------------------------------------------------------------------
# config / wiring
# ---------------------------------------------------------------------------


def test_serve_requires_streaming():
    """The serving plane reads the stitched simulation's per-node commit
    times; without streaming there is no such measurement."""
    with pytest.raises(ValueError, match="streaming"):
        EngineConfig(n_nodes=4, serve=ServeConfig())
    # streaming=True accepts it
    EngineConfig(n_nodes=4, streaming=True, serve=ServeConfig())


def test_unknown_policy_fails_fast():
    with pytest.raises(KeyError, match="serve_policy"):
        ServeConfig(policy="nope")


@pytest.mark.parametrize("kw", [
    dict(read_ratio=1.5),
    dict(max_staleness_ms=-1.0),
    dict(ops_per_client_s=0.0),
    dict(clients_per_node=-5.0),
    dict(cache_keys=200, n_keys=100),
])
def test_config_validation(kw):
    with pytest.raises(ValueError):
        ServeConfig(**kw)


def test_per_node_client_populations():
    cfg = ServeConfig(clients_per_node=[1e6, 2e6, 0.0], ops_per_client_s=2.0,
                      read_ratio=0.75)
    reads = cfg.reads_per_epoch(3, epoch_ms=10.0)
    # 1e6 clients * 2 ops/s * 10ms = 20_000 ops, 75% reads
    assert np.allclose(reads, [15_000.0, 30_000.0, 0.0])
    assert np.allclose(cfg.writes_per_epoch(3, 10.0), [5_000.0, 10_000.0, 0.0])
    with pytest.raises(ValueError, match="shape"):
        cfg.clients(4)


def test_weighted_percentile():
    v = np.array([1.0, 10.0, 100.0])
    w = np.array([98.0, 1.0, 1.0])
    assert weighted_percentile(v, w, 50.0) == 1.0
    assert weighted_percentile(v, w, 99.0) == pytest.approx(10.0)
    assert weighted_percentile(v, w, 100.0) == 100.0
    assert weighted_percentile(np.array([]), np.array([]), 50.0) == 0.0


# ---------------------------------------------------------------------------
# simulate_serving on synthetic commit matrices
# ---------------------------------------------------------------------------

# 3 nodes, 4 epochs, 10 ms cadence.  Node 0 commits almost immediately,
# node 1 lags ~1 epoch, node 2 lags several epochs — a WAN-backlogged tail.
_COMMIT = np.array([
    [1.0, 12.0, 40.0],
    [11.0, 22.0, 80.0],
    [21.0, 32.0, 120.0],
    [31.0, 42.0, 160.0],
])
_LAT = np.array([
    [0.0, 20.0, 80.0],
    [20.0, 0.0, 60.0],
    [80.0, 60.0, 0.0],
])


def _serve(bound, *, policy="redirect", cache_keys=0, epoch_ms=10.0,
           commit=_COMMIT, clients=1e6):
    cfg = ServeConfig(clients_per_node=clients, max_staleness_ms=bound,
                      policy=policy, cache_keys=cache_keys)
    return simulate_serving(cfg, commit, [_LAT] * commit.shape[0],
                            epoch_ms, wall_ms=commit.max())


def test_view_staleness_from_commit_matrix():
    # at t=30 (epoch 3's arrival): node0 merged epochs {0,1,2} -> fresh,
    # node1 merged {0,1} -> 10 ms behind, node2 merged nothing -> 30 ms
    assert list(view_epochs(_COMMIT, 30.0)) == [3, 2, 0]
    assert np.allclose(view_staleness_ms(_COMMIT, 30.0, 10.0), [0.0, 10.0, 30.0])
    # boundary convention matches _advance_views: commit at exactly `now`
    # counts as delivered
    assert list(view_epochs(np.array([[5.0]]), 5.0)) == [1]


def test_redirect_policy_routes_to_freshest_replica():
    s = _serve(5.0)
    # epoch 0: everyone fresh (staleness 0).  Epochs 1-3: node 0 is the only
    # one within the 5 ms bound; nodes 1,2 redirect to it and are served.
    assert s.rejected == 0.0
    assert s.redirected == pytest.approx(3 * 2 * 9500.0)  # 3 epochs, 2 nodes
    assert s.served_reads == s.reads_total
    # redirected reads pay the RTT: the tail is fatter than the local median
    assert s.read_latency_p99_ms > s.read_latency_p50_ms
    assert s.read_latency_p99_ms >= 2 * 60.0  # node2 -> node0 RTT is 160
    assert s.throughput_rps == pytest.approx(s.reads_total / (s.wall_ms / 1e3))


def test_redirect_rejects_when_no_replica_is_fresh_enough():
    # shift every commit late: at each arrival time *no* node has merged the
    # previous epoch, so even the freshest replica violates a 0-bound
    late = _COMMIT + 1000.0
    s = _serve(0.0, commit=late)
    assert s.epochs[0].rejected == 0.0  # epoch 0: empty prefix == fresh
    assert all(e.rejected == e.reads > 0 for e in s.epochs[1:])
    assert s.rejected == s.redirected  # reject set == attempted redirects


def test_reject_policy_never_redirects():
    s = _serve(5.0, policy="reject")
    assert s.redirected == 0.0
    assert s.rejected == pytest.approx(3 * 2 * 9500.0)
    assert s.served_reads == s.reads_total - s.rejected
    # only local latencies in the distribution
    assert s.read_latency_p99_ms == pytest.approx(ServeConfig().local_read_ms)


def test_zero_bound_zero_lag_serves_everything_locally():
    """The satellite-3 unit test: ``max_staleness_ms=0`` with zero view lag
    (every commit lands before the next arrival) serves every read locally —
    no redirects, no rejects, no stale serves."""
    # commit_ms[e, i] < (e+1)*epoch_ms for all nodes -> views always caught up
    commit = np.array([[1.0, 2.0, 3.0], [11.0, 12.0, 13.0], [21.0, 22.0, 23.0]])
    s = _serve(0.0, commit=commit, epoch_ms=10.0)
    assert s.redirected == 0.0
    assert s.rejected == 0.0
    assert s.stale_served == 0.0
    assert s.served_local == s.reads_total == s.served_reads
    assert s.redirect_rate == 0.0 and s.stale_serve_rate == 0.0


def test_cache_hit_rate_matches_zipf_top_mass():
    s = _serve(1e9, cache_keys=100)
    sampler = ZipfianSampler(ServeConfig().n_keys, ServeConfig().zipf_theta,
                             np.random.default_rng(0))
    assert s.cache_hit_rate == pytest.approx(sampler.top_mass(100))
    # hits are strictly cheaper than misses, so the median drops
    assert s.read_latency_p50_ms == ServeConfig().cache_hit_ms
    no_cache = _serve(1e9)
    assert no_cache.cache_hit_rate == 0.0
    assert no_cache.read_latency_p50_ms == ServeConfig().local_read_ms


def test_bound_monotonicity_exact():
    """The benchmark's gates as exact theorems on one commit matrix:
    loosening the staleness bound never decreases served reads or stale
    serves, never increases redirects or rejects."""
    grid = [0.0, 5.0, 10.0, 15.0, 25.0, 40.0, 1e9]
    for policy in ("redirect", "reject"):
        runs = [_serve(b, policy=policy) for b in grid]
        for a, b in zip(runs, runs[1:]):
            assert b.served_reads >= a.served_reads
            assert b.stale_served >= a.stale_served
            assert b.redirected <= a.redirected
            assert b.rejected <= a.rejected
        # conservation: every read is served or rejected
        for r in runs:
            assert r.served_reads + r.rejected == pytest.approx(r.reads_total)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _run_engine(serve=None, *, feedback=False, streaming=True, epoch_ms=2.0):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=5, n_clusters=2), np.random.default_rng(1)
    )
    trace = jitter_trace(lat, 8, np.random.default_rng(2))
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    bwm = np.where(wan, 20.0, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    cfg = EngineConfig(n_nodes=5, streaming=streaming,
                       staleness_feedback=feedback, grouping=True,
                       filtering=True, tiv=True, planner="kcenter",
                       epoch_ms=epoch_ms, serve=serve, modeled_cpu=True)
    eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=7)
    gen = TPCCGenerator(
        TPCCConfig(n_warehouses=20, mix="TPCC-A", remote_prob=0.25,
                   items_per_warehouse=20),
        5, seed=3,
    )
    return eng.run(gen, trace, txns_per_node=10, n_epochs=8)


def test_engine_populates_serve_stats_and_stays_digest_neutral():
    off = _run_engine()
    on = _run_engine(ServeConfig(clients_per_node=1e6, max_staleness_ms=50.0,
                                 cache_keys=100))
    assert off.serve is None
    assert on.serve is not None
    assert on.serve.reads_total > 0
    assert on.serve.epochs and len(on.serve.epochs) == 8
    # the serving plane is an observer of node_commit_ms: commit content,
    # byte accounting and timing are untouched
    assert on.state_digest == off.state_digest
    assert on.value_digest == off.value_digest
    assert on.committed == off.committed
    assert on.wan_bytes == off.wan_bytes
    assert [e.wall_ms for e in on.epochs] == [e.wall_ms for e in off.epochs]


def test_engine_serve_under_staleness_feedback():
    """Serving composes with the OCC feedback loop: same measured commit
    signal drives both read-abort staleness and serve-plane staleness."""
    rs = _run_engine(ServeConfig(clients_per_node=1e6, max_staleness_ms=50.0),
                     feedback=True)
    assert rs.serve is not None
    # the 2 ms cadence is far below the WAN makespan: views lag, so the
    # plane must observe nonzero staleness somewhere
    assert rs.serve.stale_served + rs.serve.redirected + rs.serve.rejected > 0
    assert max(e.view_staleness_ms_max for e in rs.serve.epochs) > 0


def test_engine_slack_cadence_serves_fresh():
    """At a cadence above the sync makespan every view is caught up by the
    next arrival: the plane serves everything locally and fresh even at a
    zero staleness bound (the engine-level satellite-3 check)."""
    rs = _run_engine(ServeConfig(clients_per_node=1e6, max_staleness_ms=0.0),
                     epoch_ms=2_000.0)
    s = rs.serve
    assert s.redirected == 0.0 and s.rejected == 0.0 and s.stale_served == 0.0
    assert s.served_local == s.reads_total


def test_non_streaming_engines_never_serve():
    rs = _run_engine(None, streaming=False)
    assert rs.serve is None
