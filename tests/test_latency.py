import numpy as np
import pytest

from repro.core.latency import (
    GeoClusterSpec,
    all_pairs_shortest,
    aws_latency_matrix,
    bandwidth_matrix,
    geo_clustered_matrix,
    jitter_trace,
    one_relay_effective,
    tiv_fraction,
    tiv_pairs,
    validate_latency_matrix,
)


def test_aws_matrix_valid():
    lat = aws_latency_matrix()
    validate_latency_matrix(lat)
    assert lat.shape == (10, 10)
    assert np.allclose(lat, lat.T)
    # paper-quoted pairs
    assert lat[5, 6] == pytest.approx(26.0)      # Stockholm-Frankfurt
    assert lat[3, 7] == pytest.approx(337.0)     # Sao Paulo-Cape Town
    assert lat[1, 2] == pytest.approx(81.1)      # N.California-Central Canada
    assert lat[1, 7] == pytest.approx(288.5)     # N.California-Cape Town


def test_geo_clustered_structure():
    rng = np.random.default_rng(0)
    spec = GeoClusterSpec(n_nodes=12, n_clusters=3)
    lat, cid = geo_clustered_matrix(spec, rng)
    validate_latency_matrix(lat)
    assert len(np.unique(cid)) == 3
    same = cid[:, None] == cid[None, :]
    off = ~np.eye(12, dtype=bool)
    intra = lat[same & off]
    inter = lat[~same]
    # clusters exist: intra-cluster latency well below inter-cluster
    assert intra.mean() * 3 < inter.mean()


def test_tiv_detection_matches_bruteforce():
    rng = np.random.default_rng(1)
    lat, _ = geo_clustered_matrix(GeoClusterSpec(n_nodes=8, n_clusters=3), rng)
    v = tiv_pairs(lat)
    n = 8
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            best = min(
                lat[i, r] + lat[r, j] for r in range(n) if r != i and r != j
            )
            assert v[i, j] == (best < lat[i, j])


def test_one_relay_effective_improves_and_is_consistent():
    rng = np.random.default_rng(2)
    lat, _ = geo_clustered_matrix(GeoClusterSpec(n_nodes=10, n_clusters=3), rng)
    eff, relay = one_relay_effective(lat)
    assert (eff <= lat + 1e-9).all()
    n = 10
    for i in range(n):
        for j in range(n):
            r = relay[i, j]
            if r >= 0:
                assert eff[i, j] == pytest.approx(lat[i, r] + lat[r, j])
                assert eff[i, j] < lat[i, j]
            elif i != j:
                assert eff[i, j] == pytest.approx(lat[i, j])


def test_all_pairs_shortest_lower_bounds_one_relay():
    rng = np.random.default_rng(3)
    lat, _ = geo_clustered_matrix(GeoClusterSpec(n_nodes=9, n_clusters=3), rng)
    eff, _ = one_relay_effective(lat)
    sp = all_pairs_shortest(lat)
    assert (sp <= eff + 1e-9).all()


def test_jitter_trace_shape_and_positivity():
    rng = np.random.default_rng(4)
    base = aws_latency_matrix()
    tr = jitter_trace(base, 50, rng)
    assert len(tr) == 50
    for f in [tr[0], tr[25], tr[49]]:
        validate_latency_matrix(f)
        assert np.allclose(f, f.T)
    # jitter stays within sane multiplicative bounds
    ratio = tr.frames / np.where(base > 0, base, 1.0)
    off = ~np.eye(10, dtype=bool)
    assert ratio[:, off].max() < 20.0
    assert ratio[:, off].min() > 0.2


def test_wan_tiv_prevalence_in_paper_band():
    """Fig 5: 28-57% of pairs violate the triangle inequality on WAN data."""
    fracs = []
    fracs.append(tiv_fraction(aws_latency_matrix()))
    rng = np.random.default_rng(5)
    for seed in range(3):
        lat, _ = geo_clustered_matrix(
            GeoClusterSpec(n_nodes=15, n_clusters=4, congestion_frac=0.35),
            np.random.default_rng(seed),
        )
        fracs.append(tiv_fraction(lat))
    assert max(fracs) > 0.15  # violations are common
    assert all(f < 0.8 for f in fracs)


def test_bandwidth_matrix_lan_wan_gap():
    rng = np.random.default_rng(6)
    cid = np.array([0, 0, 1, 1, 2, 2])
    bw = bandwidth_matrix(cid, 6, rng)
    same = cid[:, None] == cid[None, :]
    off = ~np.eye(6, dtype=bool)
    assert (bw[same & off] == 10000.0).all()
    assert bw[~same].max() <= 1000.0


def test_validate_rejects_bad_matrices():
    with pytest.raises(ValueError):
        validate_latency_matrix(np.ones((3, 4)))
    m = np.ones((3, 3))
    with pytest.raises(ValueError):
        validate_latency_matrix(m)  # nonzero diagonal
    m = np.zeros((3, 3))
    m[0, 1] = -1
    with pytest.raises(ValueError):
        validate_latency_matrix(m)
