"""Hypothesis property tests for the CRDT merge (paper Sec 4.4 ACI claims).

System invariants respected by the generators (as guaranteed by OCC version
assignment and the epoch barrier):

* per (key, version) the full payload is unique — versions are the writing
  transaction's (epoch, seq, node), and a transaction writes a key once;
* a payload-stripped (meta-only) delivery of an update only occurs in a
  multiset that also contains (or whose receiver already merged) the full
  payload for that (key, version) — null-effect filtering strips payloads
  the receiver provably holds.

Under these invariants we verify the paper's invariance equation: for any
permutation pi and any multiplicity vector k,

    S ⊕ ⊕_i ⊕_{j=1..k_i} u_{pi(i)}  ==  S ⊕ u_1 ⊕ ... ⊕ u_m
"""

import hashlib

import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.crdt import DeltaCRDTStore, Update, Version, merge_updates

_keys = st.sampled_from(["a", "b", "c", "d"])
_versions = st.builds(
    Version,
    epoch=st.integers(0, 2),
    seq=st.integers(0, 5),
    node=st.integers(0, 2),
)


def _val(key: str, ver: Version) -> bytes:
    return hashlib.sha1(f"{key}:{ver.epoch}:{ver.seq}:{ver.node}".encode()).digest()[:4]


@st.composite
def update_sets(draw):
    """A set of unique-version updates (the epoch's logical update set U)."""
    n = draw(st.integers(0, 12))
    seen = set()
    base = []
    for _ in range(n):
        key = draw(_keys)
        ver = draw(_versions)
        if (key, ver) in seen:
            continue
        seen.add((key, ver))
        base.append(Update(key, _val(key, ver), ver))
    return base


@st.composite
def deliveries(draw):
    """(base set U, delivered multiset with duplicated deliveries).

    Null-effect payload stripping happens at the wire layer (the receiver
    reconstructs the full update), so stores only ever see full updates.
    """
    base = draw(update_sets())
    delivered = []
    for u in base:
        delivered.extend([u] * draw(st.integers(1, 3)))
    return base, delivered


def _apply(store, ups):
    for u in ups:
        store.apply(u)
    return store


@given(deliveries(), st.randoms())
@settings(max_examples=300, deadline=None)
def test_invariance_permutation_and_multiplicity(pair, rnd):
    base, delivered = pair
    reference = _apply(DeltaCRDTStore(), base)
    shuffled = list(delivered)
    rnd.shuffle(shuffled)
    merged = _apply(DeltaCRDTStore(), shuffled)
    assert merged.full_state() == reference.full_state()
    assert merged.digest() == reference.digest()


@given(update_sets(), update_sets())
@settings(max_examples=200, deadline=None)
def test_associativity_via_grouped_merge(a, b):
    """(S ⊕ A) ⊕ B == S ⊕ (A ∪ B) — delayed batches merge identically."""
    s1 = _apply(_apply(DeltaCRDTStore(), a), b)
    s2 = _apply(DeltaCRDTStore(), a + b)
    assert s1.full_state() == s2.full_state()


@given(update_sets())
@settings(max_examples=200, deadline=None)
def test_merge_store_equals_apply(ups):
    """Merging two replicas' stores == applying the union of their deltas."""
    half = len(ups) // 2
    ra = _apply(DeltaCRDTStore(), ups[:half])
    rb = _apply(DeltaCRDTStore(), ups[half:])
    ra.merge_store(rb)
    s = _apply(DeltaCRDTStore(), ups)
    assert ra.full_state() == s.full_state()


@given(update_sets())
@settings(max_examples=200, deadline=None)
def test_pure_merge_matches_store(ups):
    m = merge_updates(ups)
    s = _apply(DeltaCRDTStore(), ups)
    assert set(m) == set(s.keys())
    for k, u in m.items():
        assert s.version_of(k) == u.version


@given(deliveries(), st.randoms())
@settings(max_examples=150, deadline=None)
def test_epoch_boundary_buffering(pair, rnd):
    """Delayed updates merged one epoch late converge to the same state
    (Sec 4.4: delayed visibility, unchanged correctness)."""
    base, delivered = pair
    on_time = [u for u in delivered if u.version.epoch <= 1]
    delayed = [u for u in delivered if u.version.epoch > 1]
    s_prompt = _apply(DeltaCRDTStore(), delivered)
    s_late = _apply(DeltaCRDTStore(), on_time)
    rnd.shuffle(delayed)
    _apply(s_late, delayed)
    assert s_prompt.full_state() == s_late.full_state()


@given(deliveries())
@settings(max_examples=150, deadline=None)
def test_partition_heal_convergence(pair):
    """Partitioned replicas that buffered different subsets converge after
    exchanging stores (Sec 4.4: partitions affect progress, not correctness)."""
    base, delivered = pair
    side_a = _apply(DeltaCRDTStore(), delivered[::2])
    side_b = _apply(DeltaCRDTStore(), delivered[1::2])
    side_a.merge_store(side_b)
    side_b.merge_store(side_a)
    assert side_a.full_state() == side_b.full_state()
