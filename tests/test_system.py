"""End-to-end behaviour tests for the full GeoCoCo system.

Covers the complete paper pipeline on the database plane (monitor -> planner
-> filter -> communicator -> replication engine) including fault injection.
The JAX training-plane integration lives in test_train_integration.py.
"""

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    LatencyMonitor,
    VivaldiSystem,
    WANSimulator,
    YCSBConfig,
    YCSBGenerator,
    best_plan,
    geo_clustered_matrix,
    hierarchical_schedule,
    jitter_trace,
)


def test_full_pipeline_monitor_to_engine():
    """Monitor feeds the planner; the engine synchronizes losslessly and
    beats the flat baseline on makespan, WAN bytes and throughput."""
    n = 8
    rng = np.random.default_rng(0)
    lat, regions = geo_clustered_matrix(GeoClusterSpec(n_nodes=n, n_clusters=3), rng)
    trace = jitter_trace(lat, 25, np.random.default_rng(1))

    # 1) monitoring: EWMA estimates track the truth
    mon = LatencyMonitor(n)
    est = None
    for f in trace.frames[:10]:
        est = mon.probe_all(f, rng, noise=0.02)
    off = ~np.eye(n, dtype=bool)
    rel = np.abs(est[off] - trace[9][off]) / trace[9][off]
    assert np.median(rel) < 0.25

    # 2) end-to-end: the engine with everything on vs everything off
    results = {}
    for name, (grp, filt) in {
        "origin": (False, False),
        "geococo": (True, True),
    }.items():
        same = regions[:, None] == regions[None, :]
        bw = np.where(same, 10_000.0, 200.0).astype(float)
        np.fill_diagonal(bw, np.inf)
        eng = GeoCluster(
            EngineConfig(n_nodes=n, grouping=grp, filtering=filt, tiv=True,
                         planner="kcenter"),
            bandwidth_mbps=bw,
            wan_mask=~same,
            seed=3,
        )
        gen = YCSBGenerator(
            YCSBConfig(n_keys=2000, theta=0.8, read_ratio=0.4,
                       hot_write_frac=0.35, hot_locality=True,
                       rewrite_frac=0.15),
            n, seed=5, node_region=regions,
        )
        results[name] = eng.run(gen, trace, txns_per_node=6)

    a, b = results["origin"], results["geococo"]
    assert a.state_digest == b.state_digest                 # consistency preserved
    assert a.committed == b.committed
    assert b.makespans_ms.mean() < a.makespans_ms.mean()    # faster rounds
    assert b.wan_bytes < a.wan_bytes                        # fewer WAN bytes
    assert b.throughput_tps > a.throughput_tps              # higher throughput


def test_aggregator_failover_round_still_correct():
    """Sec 4.4: aggregator failure -> drop + promote -> surviving nodes
    still complete a correct round; failed node moves no bytes."""
    n = 6
    rng = np.random.default_rng(2)
    lat, _ = geo_clustered_matrix(GeoClusterSpec(n_nodes=n, n_clusters=2), rng)
    plan = best_plan(lat, method="kcenter")
    victim = plan.aggregators[0]
    fallback = plan.drop_node(victim)
    fallback.validate(None)
    sim = WANSimulator(lat)
    sched = hierarchical_schedule(fallback, 1000.0)
    res = sim.run(sched)
    assert res.makespan_ms > 0
    assert res.bytes_out[victim] == 0 and res.bytes_in[victim] == 0


def test_vivaldi_scales_monitoring():
    """Sec 6.4: network coordinates slash probing cost while keeping
    actionable accuracy; verification sampling never hurts."""
    n = 48
    rng = np.random.default_rng(3)
    lat, _ = geo_clustered_matrix(GeoClusterSpec(n_nodes=n, n_clusters=5), rng)
    viv = VivaldiSystem(n, seed=1)
    viv.fit(lat, rounds=60, samples_per_node=6, rng=rng)
    full_mesh_probes = 60 * n * (n - 1)
    assert viv.probe_count <= 60 * n * 6          # ~13% of full-mesh probing
    assert viv.probe_count < 0.15 * full_mesh_probes
    err = viv.median_rel_error(lat)
    assert err < 0.60                              # approximate but informative
    est = viv.verify_and_correct(lat, sample_frac=0.1, rng=rng)
    off = ~np.eye(n, dtype=bool)
    rel = np.abs(est[off] - lat[off]) / lat[off]
    assert np.median(rel) <= err + 1e-9
