import itertools

import numpy as np
import pytest

from repro.core.latency import GeoClusterSpec, geo_clustered_matrix
from repro.core.planner import (
    GroupPlan,
    Replanner,
    agglomerative_grouping,
    best_plan,
    hierarchical_comm_cost,
    k_search_band,
    kcenter_grouping,
    kmeans_grouping,
    milp_grouping,
    no_grouping,
    optimal_k,
    plan_cost,
    random_grouping,
)


def _random_lat(n, seed):
    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=max(2, n // 3)),
        np.random.default_rng(seed),
    )
    return lat


def _brute_force_optimum(lat, k):
    """Exhaustive search over all (partition, aggregator) choices."""
    n = lat.shape[0]
    best = np.inf
    # assign each node a group label; enforce canonical labeling via first-occurrence
    for labels in itertools.product(range(k), repeat=n):
        if len(set(labels)) != k:
            continue
        groups = [tuple(i for i in range(n) if labels[i] == j) for j in range(k)]
        for aggs in itertools.product(*groups):
            plan = GroupPlan(tuple(groups), tuple(aggs))
            best = min(best, plan_cost(lat, plan))
    return best


@pytest.mark.parametrize("n,k,seed", [(6, 2, 0), (6, 3, 1), (7, 2, 2)])
def test_milp_matches_bruteforce_optimum(n, k, seed):
    lat = _random_lat(n, seed)
    plan = milp_grouping(lat, k)
    plan.validate(n)
    opt = _brute_force_optimum(lat, k)
    assert plan_cost(lat, plan) == pytest.approx(opt, rel=1e-6)


def test_milp_valid_and_beats_heuristics():
    lat = _random_lat(12, 3)
    k = 4
    p_milp = milp_grouping(lat, k)
    p_milp.validate(12)
    for p in [
        kcenter_grouping(lat, k),
        agglomerative_grouping(lat, k),
        kmeans_grouping(lat, k),
        random_grouping(lat, k, np.random.default_rng(0)),
    ]:
        p.validate(12)
        assert plan_cost(lat, p_milp) <= plan_cost(lat, p) + 1e-9


def test_milp_tiv_never_worse():
    lat = _random_lat(10, 4)
    k = 3
    p = milp_grouping(lat, k)
    p_tiv = milp_grouping(lat, k, tiv=True)
    # with relays available the achievable objective can only improve
    assert plan_cost(lat, p_tiv, tiv=True) <= plan_cost(lat, p) + 1e-9


def test_kcenter_two_approximation():
    """Gonzalez guarantees max intra-group radius <= 2 * optimum."""
    for seed in range(5):
        lat = _random_lat(10, 10 + seed)
        effs = np.maximum(lat, lat.T)
        k = 3
        plan = kcenter_grouping(lat, k)
        radius = 0.0
        for g, a in zip(plan.groups, plan.aggregators):
            for i in g:
                radius = max(radius, effs[i, a])
        # brute-force optimal k-center radius
        n = 10
        best = np.inf
        for centers in itertools.combinations(range(n), k):
            r = effs[:, centers].min(axis=1).max()
            best = min(best, r)
        assert radius <= 2.0 * best + 1e-9


def test_optimal_k_formula_minimizes_cost_model():
    for n in [10, 15, 25, 50]:
        ks = optimal_k(n)
        costs = {k: hierarchical_comm_cost(n, k) for k in range(1, n + 1)}
        k_best = min(costs, key=costs.get)
        # continuous optimum within 1 of the discrete minimizer
        assert abs(ks - k_best) <= 1.5
        # paper: for N<=25, k* falls in [N/5, N/2]
        if n <= 25:
            assert n / 5 <= ks <= n / 2


def test_k_search_band_contains_kstar():
    for n in [6, 10, 15, 25, 50]:
        band = k_search_band(n)
        ks = optimal_k(n)
        assert any(abs(k - ks) <= 1.5 for k in band)
        assert all(2 <= k <= n - 1 for k in band)


def test_best_plan_runs_and_validates():
    lat = _random_lat(12, 7)
    plan = best_plan(lat, method="kcenter")
    plan.validate(12)
    # either a grouped plan from the guided band or the flat fallback
    # (adaptive: hierarchy only wins when intra latency << inter)
    assert plan.k in k_search_band(12) or plan.k == 12


def test_best_plan_bandwidth_aware_prefers_grouping():
    """With a payload hint and LAN >> WAN bandwidth, NIC contention makes the
    flat all-to-all expensive and the planner groups; with a flat/uniform
    network it correctly stays flat (no free lunch from aggregation when the
    aggregator's NIC is the same as everyone else's)."""
    from repro.core.latency import geo_clustered_matrix, GeoClusterSpec

    rng = np.random.default_rng(7)
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=4), rng
    )
    same = regions[:, None] == regions[None, :]
    bw = np.where(same, 10_000.0, 100.0)
    np.fill_diagonal(bw, np.inf)
    p = best_plan(lat, method="kcenter", payload_bytes=500_000.0,
                  bandwidth_mbps=bw)
    p.validate(12)
    assert p.k < 12


def test_no_grouping_is_singletons():
    lat = _random_lat(5, 8)
    p = no_grouping(lat)
    assert p.k == 5
    assert all(len(g) == 1 for g in p.groups)


def test_plan_failover_and_drop():
    lat = _random_lat(8, 9)
    p = milp_grouping(lat, 3)
    # failover: promote another member in the largest group
    j = max(range(p.k), key=lambda j: len(p.groups[j]))
    if len(p.groups[j]) > 1:
        other = next(i for i in p.groups[j] if i != p.aggregators[j])
        p2 = p.replace_aggregator(j, other)
        p2.validate(8)
        assert p2.aggregators[j] == other
    # drop a node entirely
    victim = p.aggregators[0]
    p3 = p.drop_node(victim)
    p3.validate(None)
    assert victim not in [i for g in p3.groups for i in g]
    assert p3.n == 7


def test_replanner_damping():
    lat = _random_lat(8, 10)
    calls = []

    def plan_fn(l):
        calls.append(1)
        return kcenter_grouping(l, 3)

    rp = Replanner(plan_fn, threshold=0.2, sustain=3)
    p0 = rp.observe(lat)
    assert len(calls) == 1
    # small noise: no replan ever
    for _ in range(10):
        rp.observe(lat * 1.05)
    assert len(calls) == 1
    # transient big spike (shorter than sustain): suppressed
    rp.observe(lat * 2.0)
    rp.observe(lat * 2.0)
    rp.observe(lat * 1.01)
    assert len(calls) == 1
    # sustained deviation: replan fires
    for _ in range(3):
        rp.observe(lat * 2.0)
    assert len(calls) == 2


def test_replanner_node_failure_forces_replan():
    lat = _random_lat(8, 11)
    rp = Replanner(lambda l: kcenter_grouping(l, 3), sustain=2)
    rp.observe(lat)
    p = rp.on_node_failure(0)
    assert 0 not in [i for g in p.groups for i in g]
    rp.observe(lat)  # forced replan
    assert rp.replan_count == 2
