"""Hypothesis property tests for the event-driven transfer-DAG simulator.

The load-bearing invariant of the transmission-engine refactor:
``event <= barrier`` — the event-driven fluid-flow engine can only *remove*
waiting relative to the barrier phase-sum.  With **bandwidth admission**
(a ready hop defers while an earlier-phase flow still occupies its src
out-NIC or dst in-NIC) this is a theorem for *every* schedule whose
dependencies point at strictly earlier phases: at any instant a directed
NIC carries flows of one phase rank only, never more than that phase's
static degree, so every flow runs at least at its barrier-static rate and
every phase-``p`` hop starts by the barrier phase-``p`` start time.

That covers both the legacy list-of-phases constructor (full barrier
edges) *and* all real builder DAGs (gather -> exchange -> scatter
dependency edges, relays, filtered payloads) — the builder-DAG half used
to hold only empirically on the benchmark topologies, because greedy ASAP
starts could steal NIC bandwidth from another group's still-running
gathers (the admission bugfix; the concrete adversarial matrix is
regression-tested in ``tests/test_dag_engine.py``).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.planner import kcenter_grouping
from repro.core.schedule import (
    Transfer,
    TransmissionSchedule,
    all_to_all_schedule,
    hierarchical_schedule,
    leader_schedule,
    stitch_schedules,
)
from repro.core.simulator import WANSimulator


def _lat_matrix(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.uniform(1.0, 200.0, size=(n, n))
    lat = (a + a.T) / 2.0
    np.fill_diagonal(lat, 0.0)
    return lat


@st.composite
def phased_schedules(draw):
    """A random legacy (list-of-phases) schedule + matching network."""
    n = draw(st.integers(3, 8))
    seed = draw(st.integers(0, 10_000))
    n_phases = draw(st.integers(1, 4))
    single = draw(st.booleans())  # single-transfer phases -> equality case
    phases = []
    for _ in range(n_phases):
        k = 1 if single else draw(st.integers(1, 6))
        phase = []
        for _ in range(k):
            src = draw(st.integers(0, n - 1))
            dst = draw(st.integers(0, n - 2))
            if dst >= src:
                dst += 1
            via = -1
            if draw(st.booleans()):
                via = draw(st.integers(0, n - 1))
                if via in (src, dst):
                    via = -1
            nbytes = draw(st.sampled_from([0.0, 10_000.0, 250_000.0, 1e6]))
            phase.append(Transfer(src, dst, nbytes, via=via))
        phases.append(phase)
    bw = draw(st.sampled_from([np.inf, 100.0, 500.0]))
    return _lat_matrix(n, seed), bw, TransmissionSchedule(phases), single


@given(phased_schedules())
@settings(max_examples=80, deadline=None)
def test_event_makespan_bounded_by_barrier(case):
    lat, bw, sched, single = case
    sim = WANSimulator(lat, bw)
    ev = sim.run(sched)
    ba = sim.run(sched, barrier=True)
    assert ev.makespan_ms <= ba.makespan_ms + 1e-6
    if single:
        # one transfer per phase: a pure chain, nothing overlaps
        assert ev.makespan_ms == pytest.approx(ba.makespan_ms, rel=1e-9)
    # byte/message accounting is engine-independent
    np.testing.assert_allclose(ev.bytes_out, ba.bytes_out)
    np.testing.assert_allclose(ev.bytes_in, ba.bytes_in)
    np.testing.assert_array_equal(ev.msg_matrix, ba.msg_matrix)
    np.testing.assert_allclose(ev.link_bytes, ba.link_bytes)


@given(phased_schedules())
@settings(max_examples=40, deadline=None)
def test_event_timeline_is_consistent(case):
    lat, bw, sched, _ = case
    res = WANSimulator(lat, bw).run(sched)
    assert np.isfinite(res.finish_ms).all()
    assert (res.finish_ms >= res.start_ms - 1e-9).all()
    assert res.makespan_ms == pytest.approx(float(res.finish_ms.max()))
    # every transfer starts only after its dependencies were delivered
    for i, t in enumerate(sched.transfers):
        for d in t.deps:
            assert res.start_ms[i] >= res.finish_ms[d] - 1e-9
    # the critical path is a dependency chain ending at the makespan
    cp = res.critical_path
    assert cp, "non-empty schedule must report a critical path"
    assert res.finish_ms[cp[-1]] == pytest.approx(res.makespan_ms)
    for a, b in zip(cp, cp[1:]):
        assert a in sched.transfers[b].deps


@st.composite
def builder_dags(draw):
    """A random *builder* schedule (real dependency edges, no barrier
    chain) + matching network — the promoted domain of the event <= barrier
    property now that bandwidth admission makes it a theorem for dep-edged
    DAGs too.  Bandwidths deliberately include the severely starved band
    (~2-10 Mbps on 250 kB payloads) where the greedy pre-fix engine loses."""
    n = draw(st.integers(3, 9))
    seed = draw(st.integers(0, 10_000))
    lat = _lat_matrix(n, seed)
    bw = draw(st.sampled_from([np.inf, 500.0, 100.0, 10.0, 6.0, 2.0]))
    pay = draw(st.sampled_from([10_000.0, 250_000.0, 1e6]))
    kind = draw(st.sampled_from(["a2a", "hier", "geococo", "leader", "leader+plan"]))
    if kind == "a2a":
        return lat, bw, all_to_all_schedule(n, pay)
    if kind in ("leader", "leader+plan"):
        leader = draw(st.integers(0, n - 1))
        plan = None
        if kind == "leader+plan":
            plan = kcenter_grouping(lat, min(draw(st.integers(2, 4)), n))
        return lat, bw, leader_schedule(n, leader, pay, plan)
    plan = kcenter_grouping(lat, min(draw(st.integers(2, 4)), n))
    keep = 0.4 if kind == "geococo" else 1.0
    gp = np.array([len(g) * pay * keep for g in plan.groups])
    return lat, bw, hierarchical_schedule(
        plan, pay, group_payload_bytes=gp,
        lat=lat if kind == "geococo" else None, tiv=(kind == "geococo"),
    )


@given(builder_dags())
@settings(max_examples=80, deadline=None)
def test_event_bounded_by_barrier_on_builder_dags(case):
    """The promoted invariant: with admission, event <= barrier holds for
    every builder DAG (real dependency edges), not just barrier-edged
    schedules — including the bandwidth-starved adversarial band."""
    lat, bw, sched = case
    sim = WANSimulator(lat, bw)
    ev = sim.run(sched)
    ba = sim.run(sched, barrier=True)
    assert ev.makespan_ms <= ba.makespan_ms + 1e-6
    np.testing.assert_allclose(ev.bytes_out, ba.bytes_out)
    np.testing.assert_array_equal(ev.msg_matrix, ba.msg_matrix)


@given(builder_dags(), st.integers(0, 1_000))
@settings(max_examples=40, deadline=None)
def test_event_bounded_with_compute_stages(case, seed):
    """With per-transfer CPU stages the bound weakens by at most the total
    modeled compute (each phase can add at most its max compute stage):
    event <= barrier + sum(compute)."""
    lat, bw, sched = case
    rng = np.random.default_rng(seed)
    import dataclasses

    transfers = [
        dataclasses.replace(t, compute_ms=float(rng.uniform(0.0, 30.0)))
        for t in sched.transfers
    ]
    sched = TransmissionSchedule(transfers, label=sched.label,
                                 phase_of=sched.phase_of)
    total_cpu = sum(t.compute_ms for t in sched.transfers)
    sim = WANSimulator(lat, bw)
    ev = sim.run(sched).makespan_ms
    ba = sim.run(sched, barrier=True).makespan_ms
    assert ev <= ba + total_cpu + 1e-6


@given(builder_dags(), st.integers(2, 3))
@settings(max_examples=25, deadline=None)
def test_stitched_stream_timeline_is_consistent(case, n_epochs):
    """Stitched multi-epoch schedules keep every event-engine invariant:
    deps respected, per-epoch commits monotone, byte accounting scales."""
    lat, bw, sched = case
    n = lat.shape[0]
    stitched = stitch_schedules([sched] * n_epochs, epoch_ms=5.0, n=n)
    res = WANSimulator(lat, bw).run(stitched, lats=[lat] * n_epochs)
    one = WANSimulator(lat, bw).run(sched)
    assert np.isfinite(res.finish_ms).all()
    for i, t in enumerate(stitched.transfers):
        for d in t.deps:
            assert res.start_ms[i] >= res.finish_ms[d] - 1e-9
    ep = np.array([t.epoch for t in stitched.transfers])
    commits = [float(res.finish_ms[ep == k].max()) for k in range(n_epochs)]
    assert all(b >= a - 1e-9 for a, b in zip(commits, commits[1:]))
    # wire accounting is exactly n_epochs x one round (local stages add none)
    np.testing.assert_allclose(res.bytes_out, n_epochs * one.bytes_out)
    np.testing.assert_allclose(res.link_bytes, n_epochs * one.link_bytes)


@given(st.integers(4, 10), st.integers(2, 4), st.integers(0, 5_000))
@settings(max_examples=40, deadline=None)
def test_builder_dag_dependency_structure(n, k, seed):
    """The dep-edged hierarchical DAG is structurally sound on random WANs."""
    lat = _lat_matrix(n, seed)
    plan = kcenter_grouping(lat, min(k, n))
    sched = hierarchical_schedule(plan, 250_000.0, lat=lat, tiv=True)
    res = WANSimulator(lat, 500.0).run(sched)
    tags = [t.tag for t in sched.transfers]
    for i, t in enumerate(sched.transfers):
        if t.tag == "exchange":
            # exchanges wait for exactly the gathers into their own source
            assert all(tags[d] == "gather" and sched.transfers[d].dst == t.src
                       for d in t.deps)
        elif t.tag == "scatter":
            assert t.deps, "scatter must wait for inbound exchanges/gathers"
            assert all(sched.transfers[d].dst == t.src for d in t.deps)
            assert res.start_ms[i] >= max(
                res.finish_ms[d] for d in t.deps) - 1e-9


@given(builder_dags(), st.integers(1, 4),
       st.sampled_from([0.0, 5.0]), st.integers(0, 1_000))
@settings(max_examples=40, deadline=None)
def test_incremental_append_equals_stitched_resimulation(case, n_epochs,
                                                         epoch_ms, seed):
    """Appending epochs one at a time onto a StreamingTimeline yields
    times *byte-identical* (exact float ==, no tolerance) to stitching all
    epochs up front and running one full event simulation — the O(E)
    soundness contract of the incremental engine (bandwidth admission
    makes prefix times final; the lazy per-flow engine replays the same
    float ops in the same canonical event order)."""
    from repro.core.simulator import node_commit_ms
    from repro.core.stream import StreamingTimeline

    lat, bw, sched = case
    n = lat.shape[0]
    rng = np.random.default_rng(seed)
    exec_rows = [rng.uniform(0.0, 8.0, size=n) for _ in range(n_epochs)]
    lats = [lat * float(rng.uniform(0.8, 1.25)) for _ in range(n_epochs)]
    for l in lats:
        np.fill_diagonal(l, 0.0)

    stitched = stitch_schedules([sched] * n_epochs,
                                node_exec_ms=np.array(exec_rows),
                                epoch_ms=epoch_ms, n=n)
    full = WANSimulator(lat, bw).run(stitched, lats=lats)
    want_commit = node_commit_ms(stitched, full, n, n_epochs)

    tl = StreamingTimeline(n, bandwidth_mbps=bw, epoch_ms=epoch_ms,
                          verify=True)
    fins = [
        tl.append_epoch(sched, lats[k], node_exec_ms=exec_rows[k]).finish_ms
        for k in range(n_epochs)
    ]
    assert np.array_equal(np.concatenate(fins), full.finish_ms)
    assert np.array_equal(tl.commit_ms, want_commit)
    assert tl.finish_max_ms == [
        float(full.finish_ms[np.array([t.epoch for t in stitched.transfers])
                             == k].max())
        for k in range(n_epochs)
    ]
