"""Hypothesis property tests for the event-driven transfer-DAG simulator.

The load-bearing invariant of the transmission-engine refactor: on any
schedule whose dependencies encode the barrier semantics (the legacy
list-of-phases constructor installs full barrier edges), the event-driven
fluid-flow engine can only *remove* waiting — contention degrees shrink as
flows drain, phases never start later than the barrier — so its makespan is
bounded above by the barrier phase-sum, with equality when every phase holds
a single transfer (nothing to overlap, contention 1 throughout).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.planner import kcenter_grouping
from repro.core.schedule import Transfer, TransmissionSchedule, hierarchical_schedule
from repro.core.simulator import WANSimulator


def _lat_matrix(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.uniform(1.0, 200.0, size=(n, n))
    lat = (a + a.T) / 2.0
    np.fill_diagonal(lat, 0.0)
    return lat


@st.composite
def phased_schedules(draw):
    """A random legacy (list-of-phases) schedule + matching network."""
    n = draw(st.integers(3, 8))
    seed = draw(st.integers(0, 10_000))
    n_phases = draw(st.integers(1, 4))
    single = draw(st.booleans())  # single-transfer phases -> equality case
    phases = []
    for _ in range(n_phases):
        k = 1 if single else draw(st.integers(1, 6))
        phase = []
        for _ in range(k):
            src = draw(st.integers(0, n - 1))
            dst = draw(st.integers(0, n - 2))
            if dst >= src:
                dst += 1
            via = -1
            if draw(st.booleans()):
                via = draw(st.integers(0, n - 1))
                if via in (src, dst):
                    via = -1
            nbytes = draw(st.sampled_from([0.0, 10_000.0, 250_000.0, 1e6]))
            phase.append(Transfer(src, dst, nbytes, via=via))
        phases.append(phase)
    bw = draw(st.sampled_from([np.inf, 100.0, 500.0]))
    return _lat_matrix(n, seed), bw, TransmissionSchedule(phases), single


@given(phased_schedules())
@settings(max_examples=80, deadline=None)
def test_event_makespan_bounded_by_barrier(case):
    lat, bw, sched, single = case
    sim = WANSimulator(lat, bw)
    ev = sim.run(sched)
    ba = sim.run(sched, barrier=True)
    assert ev.makespan_ms <= ba.makespan_ms + 1e-6
    if single:
        # one transfer per phase: a pure chain, nothing overlaps
        assert ev.makespan_ms == pytest.approx(ba.makespan_ms, rel=1e-9)
    # byte/message accounting is engine-independent
    np.testing.assert_allclose(ev.bytes_out, ba.bytes_out)
    np.testing.assert_allclose(ev.bytes_in, ba.bytes_in)
    np.testing.assert_array_equal(ev.msg_matrix, ba.msg_matrix)
    np.testing.assert_allclose(ev.link_bytes, ba.link_bytes)


@given(phased_schedules())
@settings(max_examples=40, deadline=None)
def test_event_timeline_is_consistent(case):
    lat, bw, sched, _ = case
    res = WANSimulator(lat, bw).run(sched)
    assert np.isfinite(res.finish_ms).all()
    assert (res.finish_ms >= res.start_ms - 1e-9).all()
    assert res.makespan_ms == pytest.approx(float(res.finish_ms.max()))
    # every transfer starts only after its dependencies were delivered
    for i, t in enumerate(sched.transfers):
        for d in t.deps:
            assert res.start_ms[i] >= res.finish_ms[d] - 1e-9
    # the critical path is a dependency chain ending at the makespan
    cp = res.critical_path
    assert cp, "non-empty schedule must report a critical path"
    assert res.finish_ms[cp[-1]] == pytest.approx(res.makespan_ms)
    for a, b in zip(cp, cp[1:]):
        assert a in sched.transfers[b].deps


@given(st.integers(4, 10), st.integers(2, 4), st.integers(0, 5_000))
@settings(max_examples=40, deadline=None)
def test_builder_dag_dependency_structure(n, k, seed):
    """The dep-edged hierarchical DAG is structurally sound on random WANs.

    (Unlike the barrier-dep case above, ``event <= barrier`` is NOT a
    theorem for real dependency edges — an early exchange can steal NIC
    bandwidth from another group's still-running gathers — so the makespan
    comparison for builder DAGs is a deterministic gate on the benchmark
    topologies, in benchmarks/bench_makespan_regression.py and
    tests/test_dag_engine.py, not a random-input property.)"""
    lat = _lat_matrix(n, seed)
    plan = kcenter_grouping(lat, min(k, n))
    sched = hierarchical_schedule(plan, 250_000.0, lat=lat, tiv=True)
    res = WANSimulator(lat, 500.0).run(sched)
    tags = [t.tag for t in sched.transfers]
    for i, t in enumerate(sched.transfers):
        if t.tag == "exchange":
            # exchanges wait for exactly the gathers into their own source
            assert all(tags[d] == "gather" and sched.transfers[d].dst == t.src
                       for d in t.deps)
        elif t.tag == "scatter":
            assert t.deps, "scatter must wait for inbound exchanges/gathers"
            assert all(sched.transfers[d].dst == t.src for d in t.deps)
            assert res.start_ms[i] >= max(
                res.finish_ms[d] for d in t.deps) - 1e-9
