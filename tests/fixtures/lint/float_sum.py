"""Fixture: trips ``float-sum-unordered`` exactly once — ``sum()`` over a
set of simulated-time quantities (sorted accumulation and ordered
sources are fine, as are sums of order-insensitive values)."""


def total(delays):
    bad = sum(d_ms for d_ms in {round(d, 3) for d in delays})
    ok = sum(d_ms for d_ms in sorted({round(d, 3) for d in delays}))
    also_ok = sum(d_ms for d_ms in delays)  # ordered source: allowed
    counts = sum(len(str(d)) for d in {round(d, 3) for d in delays})
    return bad, ok, also_ok, counts
