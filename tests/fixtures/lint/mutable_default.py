"""Fixture: trips ``mutable-default`` exactly once."""


def accumulate(x, acc=[]):
    acc.append(x)
    return acc


def fine(x, acc=None):
    acc = [] if acc is None else acc
    acc.append(x)
    return acc
