"""Fixture: trips ``unordered-dict-iter`` exactly once — dict-view
iteration in a determinism-critical function (the sorted one below is
fine, as is dict iteration outside critical functions)."""


def merge_store(data):
    acc = []
    for k, v in data.items():
        acc.append((k, v))
    for k, v in sorted(data.items()):  # ordered: allowed
        acc.append((k, v))
    return acc


def helper(data):
    return [k for k in data.keys()]  # non-critical function: allowed
