"""Fixture: trips the ``wallclock`` rule exactly once."""

import time


def simulated_epoch_ms():
    return time.perf_counter() * 1e3
