"""Fixture: zero violations — the sanctioned idioms for each rule, plus an
inline pragma suppressing an otherwise-tripping line."""

import time

import numpy as np


def plan_cost_s():
    return time.perf_counter()  # lint: allow[wallclock] measured plan cost


def digest(keys, rng=None):
    rng = rng or np.random.default_rng(0)
    order = sorted(set(keys))
    jitter_ms = float(rng.random())
    return order, jitter_ms


def close_enough(a_ms, b_ms, tol=1e-9):
    return abs(a_ms - b_ms) <= tol
