"""Fixture: trips ``float-time-eq`` exactly once (exact-zero checks and
non-time comparisons are allowed)."""


def same_commit(a_ms, b_ms, count):
    if a_ms == 0.0:        # exact-zero: allowed
        return True
    if count == 3:         # not a time: allowed
        return False
    return a_ms == b_ms
