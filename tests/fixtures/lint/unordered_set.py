"""Fixture: trips ``unordered-set-iter`` exactly once — set iteration in a
determinism-critical function (the sorted one below is fine, as is set
iteration outside critical functions)."""


def digest(keys):
    acc = []
    for k in set(keys):
        acc.append(k)
    for k in sorted(set(keys)):  # ordered: allowed
        acc.append(k)
    return acc


def helper(keys):
    return [k for k in set(keys)]  # non-critical function: allowed
