"""Fixture: trips the ``module-rng`` rule exactly once (the constructor
call below is allowed; the module-global draw is not)."""

import numpy as np

rng = np.random.default_rng(0)  # allowed: seeded Generator constructor


def draw():
    return np.random.rand(3)
