"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes per the deliverable spec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.crdt_merge import ops as crdt_ops
from repro.kernels.rglru_scan import ops as rglru_ops
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.kernels.whitedata_filter import ops as wd_ops


# ---------------------------------------------------------------------------
# whitedata_filter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(256, 256), (512, 384), (8, 128), (1024,),
                                   (3, 5, 7), (1000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_whitedata_filter_matches_ref(shape, dtype):
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, size=shape), dtype)
    r = jnp.asarray(rng.normal(0, 0.1, size=shape), dtype)
    tau = 0.5
    s_k, r_k, k_k = wd_ops.whitedata_filter(g, r, tau, use_kernel=True)
    s_r, r_r, k_r = wd_ops.whitedata_filter_ref(g, r, tau)
    np.testing.assert_allclose(np.asarray(s_k, np.float32),
                               np.asarray(s_r, np.float32), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_k, np.float32),
                               np.asarray(r_r, np.float32), rtol=1e-5, atol=1e-5)
    assert int(k_k) == int(k_r)


def test_whitedata_filter_conserves_mass():
    """send + new_r == g + r: filtering defers, never destroys."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 1, size=(128, 256)), jnp.float32)
    r = jnp.asarray(rng.normal(0, 1, size=(128, 256)), jnp.float32)
    s, nr, _ = wd_ops.whitedata_filter(g, r, 0.7)
    np.testing.assert_allclose(np.asarray(s + nr), np.asarray(g + r), rtol=1e-6)


def test_whitedata_filter_tau_extremes():
    g = jnp.ones((64, 128))
    r = jnp.zeros((64, 128))
    s, nr, k = wd_ops.whitedata_filter(g, r, 0.0)
    assert int(k) == g.size and float(jnp.abs(nr).sum()) == 0.0
    s, nr, k = wd_ops.whitedata_filter(g, r, 1e9)
    assert int(k) == 0 and float(jnp.abs(s).sum()) == 0.0


def test_filter_gradient_pytree():
    rng = np.random.default_rng(2)
    grads = {
        "a": jnp.asarray(rng.normal(size=(32, 64)), jnp.float32),
        "b": {"w": jnp.asarray(rng.normal(size=(129,)), jnp.float32)},
    }
    res = jax.tree.map(jnp.zeros_like, grads)
    send, new_r, stats = wd_ops.filter_gradient(grads, res, 1.0)
    assert jax.tree.structure(send) == jax.tree.structure(grads)
    assert 0.0 <= float(stats["density"]) <= 1.0
    total = sum(g.size for g in jax.tree.leaves(grads))
    assert int(stats["total"]) == total


# ---------------------------------------------------------------------------
# crdt_merge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(256, 256), (128, 512), (64, 100), (7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_crdt_merge_matches_ref(m, n, dtype):
    rng = np.random.default_rng(3)
    if dtype == jnp.int32:
        va = jnp.asarray(rng.integers(0, 100, size=(m, n)), dtype)
        vb = jnp.asarray(rng.integers(0, 100, size=(m, n)), dtype)
    else:
        va = jnp.asarray(rng.normal(size=(m, n)), dtype)
        vb = jnp.asarray(rng.normal(size=(m, n)), dtype)
    ra = jnp.asarray(rng.integers(0, 50, size=(m,)), jnp.int32)
    rb = jnp.asarray(rng.integers(0, 50, size=(m,)), jnp.int32)
    ov_k, or_k = crdt_ops.crdt_merge(va, ra, vb, rb, use_kernel=True)
    ov_r, or_r = crdt_ops.crdt_merge_ref(va, ra, vb, rb)
    np.testing.assert_array_equal(np.asarray(ov_k), np.asarray(ov_r))
    np.testing.assert_array_equal(np.asarray(or_k), np.asarray(or_r))


def test_crdt_merge_is_aci():
    """Kernel-level ACI: commutative on value-identical ties, associative,
    idempotent — the properties the paper's Sec 4.4 proof needs."""
    rng = np.random.default_rng(4)
    m, n = 64, 128
    batches = []
    for i in range(4):
        vals = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        vers = jnp.asarray(rng.integers(0, 20, size=(m,)), jnp.int32)
        batches.append((vals, vers))
    v1, r1 = crdt_ops.crdt_merge_many(batches)
    v2, r2 = crdt_ops.crdt_merge_many(batches[::-1])
    # versions agree in any order; values agree where versions were unique
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    vers = np.stack([np.asarray(b[1]) for b in batches])
    unique = (vers == vers.max(axis=0)).sum(axis=0) == 1
    np.testing.assert_array_equal(np.asarray(v1)[unique], np.asarray(v2)[unique])
    # idempotence: re-merging the result is a no-op
    v3, r3 = crdt_ops.crdt_merge(v1, r1, v1, r1)
    np.testing.assert_array_equal(np.asarray(v3), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(r3), np.asarray(r1))
    # duplicated delivery of one batch changes nothing
    v4, r4 = crdt_ops.crdt_merge_many(batches + [batches[0]])
    np.testing.assert_array_equal(np.asarray(r4), np.asarray(r1))


# ---------------------------------------------------------------------------
# rwkv6_wkv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,t,h,n", [(2, 64, 2, 16), (1, 128, 4, 32),
                                     (2, 37, 1, 8), (1, 256, 2, 64)])
def test_wkv6_matches_ref(b, t, h, n):
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.normal(0, 1, size=(b, t, h, n)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, size=(b, t, h, n)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, size=(b, t, h, n)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 0.99, size=(b, t, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.2, size=(h, n)), jnp.float32)
    s0 = jnp.asarray(rng.normal(0, 0.1, size=(b, h, n, n)), jnp.float32)
    y_k, s_k = wkv_ops.wkv6(r, k, v, w, u, s0, use_kernel=True)
    y_r, s_r = wkv_ops.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=2e-5, atol=2e-5)


def test_wkv6_chunking_invariance():
    """Different time chunk sizes give identical results (state carried
    correctly across chunk boundaries)."""
    rng = np.random.default_rng(6)
    b, t, h, n = 1, 96, 2, 16
    args = [jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32) for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.6, 0.99, size=(b, t, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    outs = []
    for tc in (96, 48, 32, 16):
        y, s = wkv_ops.wkv6(*args[:3], w, u, s0, time_chunk=tc)
        outs.append((np.asarray(y), np.asarray(s)))
    for y, s in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(s, outs[0][1], rtol=1e-5, atol=1e-5)


def test_wkv6_state_continuation():
    """Processing [0:T1] then [T1:T] with carried state == one pass."""
    rng = np.random.default_rng(7)
    b, t, h, n = 2, 64, 2, 16
    mk = lambda: jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.6, 0.99, size=(b, t, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    y_full, s_full = wkv_ops.wkv6(r, k, v, w, u, s0)
    t1 = 24
    y1, s1 = wkv_ops.wkv6(r[:, :t1], k[:, :t1], v[:, :t1], w[:, :t1], u, s0)
    y2, s2 = wkv_ops.wkv6(r[:, t1:], k[:, t1:], v[:, t1:], w[:, t1:], u, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-5, atol=1e-5)


def test_wkv6_model_integration():
    """models.rwkv6 scan == kernel path."""
    from repro.models.rwkv6 import wkv6_scan

    rng = np.random.default_rng(8)
    b, t, h, n = 2, 32, 2, 16
    mk = lambda: jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.6, 0.99, size=(b, t, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    y_m, s_m = wkv6_scan(r, k, v, w, u, s0)
    y_k, s_k = wkv_ops.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_k), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_m), np.asarray(s_k), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,t,d", [(2, 64, 128), (1, 100, 64), (3, 256, 512),
                                   (2, 37, 100)])
def test_rglru_matches_ref(b, t, d):
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.uniform(0.5, 0.999, size=(b, t, d)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 0.5, size=(b, t, d)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    h_k, f_k = rglru_ops.rglru_scan(a, bb, h0, use_kernel=True)
    h_r, f_r = rglru_ops.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r), rtol=1e-5, atol=1e-5)


def test_rglru_matches_associative_scan_in_model():
    """The model's associative-scan path == the kernel's sequential sweep."""
    from repro.models.rglru import _rglru_scan

    rng = np.random.default_rng(10)
    b, t, d = 2, 64, 32
    a = jnp.asarray(rng.uniform(0.5, 0.99, size=(b, t, d)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    h_m, last_m = _rglru_scan(a, bb, h0)
    h_k, last_k = rglru_ops.rglru_scan(a, bb, h0)
    np.testing.assert_allclose(np.asarray(h_m), np.asarray(h_k), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(last_m), np.asarray(last_k), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked WKV6 (the §Perf iteration-3 path) — property-swept vs the oracle
# ---------------------------------------------------------------------------


def test_wkv6_chunked_property_sweep():
    pytest.importorskip(
        "hypothesis", reason="dev-only dependency; see requirements-dev.txt"
    )
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from repro.models.rwkv6 import wkv6_chunked, wkv6_scan

    @given(
        st.integers(1, 2), st.integers(2, 48), st.integers(1, 2),
        st.integers(4, 16), st.integers(0, 1000),
    )
    @settings(max_examples=25, deadline=None)
    def prop(b, t, h, n, seed):
        rng = np.random.default_rng(seed)
        r, k, v = (
            jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32)
            for _ in range(3)
        )
        w = jnp.asarray(rng.uniform(0.4, 0.999, size=(b, t, h, n)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
        s0 = jnp.asarray(rng.normal(0, 0.2, size=(b, h, n, n)), jnp.float32)
        y1, s1 = wkv6_scan(r, k, v, w, u, s0)
        y2, s2 = wkv6_chunked(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=5e-4, atol=5e-4)

    prop()
