"""Cross-epoch streaming engine: digest equality vs the non-streaming
engine, pipeline overlap bounds, and the barrier/streaming compat contract.
"""

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    YCSBConfig,
    YCSBGenerator,
    aws_latency_matrix,
    geo_clustered_matrix,
    jitter_trace,
    stitch_schedules,
)
from repro.core.planner import best_plan, kcenter_grouping
from repro.core.schedule import hierarchical_schedule
from repro.core.simulator import WANSimulator


def _run(streaming: bool, *, n=5, epochs=8, epoch_ms=2.0, bw=200.0, seed=7):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=2), np.random.default_rng(1)
    )
    trace = jitter_trace(lat, epochs, np.random.default_rng(2))
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    bwm = np.where(wan, bw, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    cfg = EngineConfig(n_nodes=n, streaming=streaming, grouping=True,
                       filtering=True, tiv=True, planner="kcenter",
                       epoch_ms=epoch_ms)
    eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=seed)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=400, theta=0.9, read_ratio=0.3, hot_write_frac=0.3,
                   hot_locality=True),
        n, seed=3, node_region=regions,
    )
    return eng.run(gen, trace, txns_per_node=8, n_epochs=epochs)


def test_streaming_commits_byte_identical_state():
    """Acceptance: streaming changes *when* epochs commit, never what —
    validation still waits for every epoch write set, so the committed
    state is byte-identical to the non-streaming engine."""
    ns = _run(False)
    st = _run(True)
    assert st.state_digest == ns.state_digest
    assert st.value_digest == ns.value_digest
    assert st.committed == ns.committed
    assert st.total_txns == ns.total_txns


def test_streaming_overlap_bounds():
    """max of epochs <= streaming makespan <= sum of epochs: the stitched
    pipeline cannot finish before its slowest epoch would in isolation, and
    cross-epoch dependencies only ever remove serialization.  The per-epoch
    reference is the streaming run's *own* isolated formula wall
    (max(epoch_ms, exec, sync) over the same schedules the stream stitched).
    The upper bound carries one honest correction: the formula assumes
    execution hides under the previous epoch's sync entirely, while the
    measured commit chain pays commit -> exec -> gather serially per node —
    so the stream may exceed the formula sum by at most the summed exec."""
    st = _run(True)
    formula_walls = np.array([
        max(2.0, e.exec_ms, e.sync_ms) for e in st.epochs  # epoch_ms = 2.0
    ])
    exec_total = sum(e.exec_ms for e in st.epochs)
    total = sum(e.wall_ms for e in st.epochs)
    assert formula_walls.max() - 1e-6 <= total
    assert total <= formula_walls.sum() + exec_total + 1e-6
    # per-epoch accounting closes: walls are inter-commit gaps and
    # pipeline_overlap_ms is the formula's charge minus the measured wall
    for e, f in zip(st.epochs, formula_walls):
        assert e.pipeline_overlap_ms == pytest.approx(f - e.wall_ms, abs=1e-9)
    commits = [e.stream_commit_ms for e in st.epochs]
    assert all(b >= a - 1e-9 for a, b in zip(commits, commits[1:]))
    assert commits[-1] == pytest.approx(total)


def test_streaming_respects_epoch_cadence():
    """Transactions arrive at the epoch cadence: the stream can never
    commit the last epoch before (n_epochs - 1) * epoch_ms."""
    st = _run(True, epoch_ms=50.0)
    commits = [e.stream_commit_ms for e in st.epochs]
    assert commits[-1] >= (len(st.epochs) - 1) * 50.0 - 1e-6


def test_streaming_reduces_wall_clock_on_trace_topology():
    """Acceptance: on a trace topology with epoch_ms < makespan, the
    measured stitched pipeline beats the max(epoch, exec, sync) formula —
    epoch e+1 gathers genuinely stream under epoch e scatters."""
    base, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3, congestion_frac=0.3,
                       congestion_mult=(1.3, 2.5)),
        np.random.default_rng(3),
    )
    trace = jitter_trace(base, 8, np.random.default_rng(17))
    walls = {}
    for streaming in (False, True):
        cfg = EngineConfig(n_nodes=12, streaming=streaming, grouping=True,
                           filtering=True, tiv=True, planner="kcenter",
                           epoch_ms=2.0, txn_exec_us=5.0)
        eng = GeoCluster(cfg, bandwidth_mbps=100.0, seed=7)
        gen = YCSBGenerator(
            YCSBConfig(n_keys=400, theta=0.9, read_ratio=0.3,
                       hot_write_frac=0.3),
            12, seed=3,
        )
        rs = eng.run(gen, trace, txns_per_node=20, n_epochs=8)
        walls[streaming] = rs.wall_s
        if streaming:
            assert rs.pipeline_overlap_ms > 0.0
            assert all(e.sync_ms > cfg.epoch_ms for e in rs.epochs)
    assert walls[True] < walls[False]


def test_streaming_barrier_rejected():
    """Compat contract: the stitched DAG has no barrier-phase semantics —
    the config, the planner ranking and the simulator all refuse."""
    with pytest.raises(ValueError, match="streaming"):
        EngineConfig(n_nodes=4, streaming=True, barrier=True)
    lat = aws_latency_matrix()
    with pytest.raises(ValueError, match="event engine"):
        best_plan(lat, payload_bytes=1e5, streaming=True, barrier=True,
                  method="kcenter")
    plan = kcenter_grouping(lat, 3)
    sched = hierarchical_schedule(plan, 250_000.0)
    stitched = stitch_schedules([sched, sched], n=10)
    with pytest.raises(ValueError, match="event engine"):
        WANSimulator(lat, 500.0).run(stitched, barrier=True, lats=[lat, lat])


def test_streaming_flag_reaches_plan_ranking():
    """best_plan(streaming=True) ranks by two stitched epochs and still
    returns a valid plan; the flat fallback remains a candidate."""
    lat = aws_latency_matrix()
    plan = best_plan(lat, payload_bytes=250_000.0, bandwidth_mbps=500.0,
                     streaming=True, method="kcenter")
    plan.validate(lat.shape[0])


# ---------------------------------------------------------------------------
# incremental appendable timeline (stream_mode="incremental")
# ---------------------------------------------------------------------------


def test_streaming_timeline_append_matches_stitch():
    """Byte-identity contract of the O(E) incremental engine: appending
    epochs one at a time onto a StreamingTimeline reproduces the stitched
    full re-simulation exactly — float ``==`` on every transfer finish
    time and on the per-node commit matrix, across builders, cadences and
    bandwidth regimes (the deterministic pin; the hypothesis sweep lives
    in test_property_dag.py)."""
    from repro.core import NicState, StreamingTimeline, node_commit_ms
    from repro.core.schedule import all_to_all_schedule, leader_schedule

    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=6, n_clusters=2), np.random.default_rng(1)
    )
    plan = kcenter_grouping(lat, 2)
    scheds = [
        all_to_all_schedule(6, 120_000.0),
        hierarchical_schedule(plan, 120_000.0),
        leader_schedule(6, 2, 300_000.0),
        hierarchical_schedule(plan, 40_000.0),
        all_to_all_schedule(6, 500_000.0),
    ]
    rng = np.random.default_rng(9)
    lats = []
    for _ in scheds:
        l = lat * float(rng.uniform(0.8, 1.3))
        np.fill_diagonal(l, 0.0)
        lats.append(l)
    exec_rows = [rng.uniform(0.0, 4.0, size=6) for _ in scheds]
    for bw in (np.inf, 200.0, 8.0):
        for epoch_ms in (0.0, 25.0):
            stitched = stitch_schedules(scheds, node_exec_ms=np.array(exec_rows),
                                        epoch_ms=epoch_ms, n=6)
            full = WANSimulator(lat, bw).run(stitched, lats=lats)
            tl = StreamingTimeline(6, bandwidth_mbps=bw, epoch_ms=epoch_ms,
                                   verify=True)
            fins = [
                tl.append_epoch(s, lats[k], node_exec_ms=exec_rows[k]).finish_ms
                for k, s in enumerate(scheds)
            ]
            assert np.array_equal(np.concatenate(fins), full.finish_ms)
            assert np.array_equal(
                tl.commit_ms, node_commit_ms(stitched, full, 6, len(scheds))
            )


def test_incremental_engine_matches_resim_oracle():
    """GeoCluster streaming with stream_mode='incremental' (the default)
    is observably identical to the O(E²) stitch-and-rerun oracle — same
    per-epoch stream commits, walls, abort breakdowns, view lags and
    final digests, with and without the staleness feedback loop."""
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=5, n_clusters=2), np.random.default_rng(1)
    )
    trace = jitter_trace(lat, 8, np.random.default_rng(2))

    def run(mode, feedback):
        cfg = EngineConfig(n_nodes=5, streaming=True, epoch_ms=2.0,
                           staleness_feedback=feedback, planner="kcenter",
                           stream_mode=mode, modeled_cpu=True,
                           verify_schedules=True)
        eng = GeoCluster(cfg, bandwidth_mbps=200.0, seed=7)
        gen = YCSBGenerator(
            YCSBConfig(n_keys=400, theta=0.9, read_ratio=0.3),
            5, seed=3, node_region=regions,
        )
        return eng.run(gen, trace, txns_per_node=8, n_epochs=8)

    for feedback in (False, True):
        inc = run("incremental", feedback)
        ref = run("resim", feedback)
        assert inc.state_digest == ref.state_digest
        assert inc.value_digest == ref.value_digest
        for a, b in zip(inc.epochs, ref.epochs):
            assert a.stream_commit_ms == b.stream_commit_ms
            assert a.wall_ms == b.wall_ms
            assert a.read_aborts == b.read_aborts
            assert a.ww_aborts == b.ww_aborts
            assert a.view_lag_mean == b.view_lag_mean
            assert a.view_lag_max == b.view_lag_max


def test_timeline_rejects_unsound_modes():
    """Incremental segment simulation is only sound where the finality
    argument holds: event engine, bandwidth admission, deterministic
    loss.  Each unsound switch is refused loudly."""
    from repro.core import StreamingTimeline
    from repro.core.schedule import all_to_all_schedule
    from repro.core.simulator import NicState

    lat = aws_latency_matrix()[:4, :4]
    sched = all_to_all_schedule(4, 1e5)
    rank = np.zeros(sched.n_transfers, dtype=int)
    deps = [()] * sched.n_transfers
    ready = [0.0] * sched.n_transfers
    for kw, msg in (
        (dict(barrier=True), "event engine"),
        (dict(admission=False), "bandwidth admission"),
        (dict(stochastic_loss=True, loss=0.01), "stochastic_loss"),
    ):
        sim = WANSimulator(lat, 100.0, **kw)
        with pytest.raises(ValueError, match=msg):
            sim.simulate_segment(sched.transfers, rank=rank, deps=deps,
                                 ext_ready=ready, nic=NicState.zeros(4))
    with pytest.raises(ValueError, match="stream_mode"):
        EngineConfig(n_nodes=4, streaming=True, stream_mode="eager")
