"""Epoch-sink pipeline: bounded-memory runs are byte-identical to retained
runs.

The refactor's contract is exact equality, not approximation: the online
``RunSummary`` left-folds the same floats in the same epoch order the old
``RunStats`` properties folded, the evicting ``StreamingTimeline`` window
returns the same rows the unbounded history returned, and the incremental
``ServingSink`` prefix pointers reproduce the batch ``view_epochs`` count
wherever staleness is nonzero (prefix sufficiency — see the class
docstring).  Every test here pins ``==`` / ``array_equal``, never approx.
"""

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    EpochStats,
    GeoCluster,
    GeoClusterSpec,
    RunAggregator,
    StreamingTimeline,
    YCSBConfig,
    YCSBGenerator,
    geo_clustered_matrix,
    jitter_trace,
    node_commit_ms,
)
from repro.analysis import check_config
from repro.core.whitedata import FilterStats
from repro.serve import (
    ServeConfig,
    ServingSink,
    simulate_serving,
    view_staleness_ms,
)


# ---------------------------------------------------------------------------
# end-to-end: bounded run == retained run
# ---------------------------------------------------------------------------


def _run(*, keep_epochs, stats_window=64, feedback=False,
         stream_mode="incremental", streaming=True, serve=False,
         n=4, epochs=6, epoch_ms=2.0, seed=7):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=2), np.random.default_rng(1)
    )
    trace = jitter_trace(lat, epochs, np.random.default_rng(2))
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    bwm = np.where(wan, 200.0, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    serve_cfg = None
    if serve:
        serve_cfg = ServeConfig(clients_per_node=50_000.0,
                                max_staleness_ms=3 * epoch_ms,
                                cache_keys=50, keep_epochs=keep_epochs)
    cfg = EngineConfig(n_nodes=n, streaming=streaming, grouping=True,
                       filtering=True, tiv=True, planner="kcenter",
                       epoch_ms=epoch_ms, staleness_feedback=feedback,
                       stream_mode=stream_mode, serve=serve_cfg,
                       keep_epochs=keep_epochs, stats_window=stats_window,
                       # modeled (deterministic) CPU costs: measured filter
                       # wall-clock would differ between the paired runs
                       modeled_cpu=True)
    eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=seed)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=200, theta=0.9, read_ratio=0.3, hot_write_frac=0.3,
                   hot_locality=True),
        n, seed=3, node_region=regions,
    )
    return eng.run(gen, trace, txns_per_node=4, n_epochs=epochs)


def _assert_equivalent(bounded, retained, *, window):
    # the online summary is the same left-fold the retained run performs
    assert bounded.summary == retained.summary
    assert bounded.state_digest == retained.state_digest
    assert bounded.value_digest == retained.value_digest
    # derived properties route through the summary on both sides
    assert bounded.committed == retained.committed
    assert bounded.wall_s == retained.wall_s
    assert bounded.wan_bytes == retained.wan_bytes
    # the trailing window is a suffix of the full history
    kept = bounded.epochs
    assert len(kept) == min(window, len(retained.epochs))
    assert kept == retained.epochs[len(retained.epochs) - len(kept):]
    if retained.serve is not None:
        b, r = bounded.serve, retained.serve
        assert b.totals == r.totals
        assert b.summary() == r.summary()
        assert np.array_equal(b.latency_values_ms, r.latency_values_ms)
        assert np.array_equal(b.latency_weights, r.latency_weights)
        assert b.epochs == []  # the O(E) list is actually dropped
        assert len(r.epochs) == len(retained.epochs)


@pytest.mark.parametrize("stream_mode", ["incremental", "resim"])
@pytest.mark.parametrize("feedback,window", [(False, 1), (False, 4),
                                             (True, 2), (True, 64)])
def test_bounded_run_equivalent_to_retained(feedback, stream_mode, window):
    retained = _run(keep_epochs=True, feedback=feedback,
                    stream_mode=stream_mode, serve=True)
    bounded = _run(keep_epochs=False, stats_window=window, feedback=feedback,
                   stream_mode=stream_mode, serve=True)
    _assert_equivalent(bounded, retained, window=window)


def test_bounded_run_equivalent_nonstreaming():
    retained = _run(keep_epochs=True, streaming=False)
    bounded = _run(keep_epochs=False, stats_window=3, streaming=False)
    _assert_equivalent(bounded, retained, window=3)


def test_window_zero_keeps_no_epochs():
    rs = _run(keep_epochs=False, stats_window=0)
    assert rs.epochs == []
    assert rs.summary is not None and rs.summary.n_epochs == 6


# ---------------------------------------------------------------------------
# RunAggregator: the online fold on synthetic stats
# ---------------------------------------------------------------------------


def _stats(e, *, sync=3.0, wall=2.0, committed=5):
    return EpochStats(
        epoch=e, n_txns=8, committed=committed, aborted=8 - committed,
        sync_ms=sync + 0.1 * e, exec_ms=1.0, wall_ms=wall + 0.01 * e,
        wan_bytes=100.0 * (e + 1),
        filter_stats=FilterStats(total_updates=4, kept_updates=3),
        filter_cpu_ms=0.25, plan_method="kcenter",
        sync_overlap_ms=0.5, pipeline_overlap_ms=0.125,
        read_aborts=e % 2, ww_aborts=1, view_lag_mean=float(e % 3),
        view_lag_max=e % 3,
    )


def test_aggregator_summary_matches_epoch_folds():
    epochs = [_stats(e) for e in range(7)]
    agg = RunAggregator(keep_epochs=True)
    for s in epochs:
        agg.on_epoch(s)
    m = agg.summary
    assert m.n_epochs == 7
    assert m.n_txns == sum(s.n_txns for s in epochs)
    assert m.committed == sum(s.committed for s in epochs)
    assert m.read_aborts == sum(s.read_aborts for s in epochs)
    # float folds accumulate in epoch order: byte-identical to sum()
    wall = 0.0
    for s in epochs:
        wall += s.wall_ms
    assert m.wall_ms == wall
    assert m.sync_ms_max == max(s.sync_ms for s in epochs)
    assert m.view_lag_max == max(s.view_lag_max for s in epochs)
    assert m.filter_stats.kept_updates == 7 * 3
    assert agg.epochs == epochs


def test_aggregator_window_is_trailing_suffix():
    epochs = [_stats(e) for e in range(9)]
    full = RunAggregator(keep_epochs=True)
    windowed = RunAggregator(keep_epochs=False, window=4)
    for s in epochs:
        full.on_epoch(s)
        windowed.on_epoch(s)
    assert windowed.epochs == epochs[-4:]
    # the summary is over ALL epochs, not just the window
    assert windowed.summary == full.summary


# ---------------------------------------------------------------------------
# StreamingTimeline: eviction never changes surviving surfaces
# ---------------------------------------------------------------------------


def _timeline_pair(epochs=12, n=3, seed=0):
    """Build two identical timelines from random all-to-all epochs; evict
    aggressively on one, never on the other."""
    from repro.core import all_to_all_schedule

    rng = np.random.default_rng(seed)
    keep = StreamingTimeline(n, epoch_ms=1.0)
    evict = StreamingTimeline(n, epoch_ms=1.0)
    for e in range(epochs):
        lat = rng.uniform(1.0, 5.0, size=(n, n))
        np.fill_diagonal(lat, 0.0)
        sched = all_to_all_schedule(n, payload_bytes=64.0)
        keep.append_epoch(sched, lat)
        evict.append_epoch(sched, lat)
        evict.evict_commit_rows(max(e - 1, 0))  # retain a 2-row tail
    return keep, evict


def test_timeline_eviction_preserves_live_surfaces():
    keep, evict = _timeline_pair()
    e = evict.n_epochs
    assert evict.evicted_epochs == e - 2
    # live rows and finish marks are identical to the unbounded history
    assert np.array_equal(evict.commit_ms, keep.commit_ms[e - 2:])
    assert evict.finish_max_ms == keep.finish_max_ms[e - 2:]
    for k in range(e - 2, e):
        for i in range(keep.n):
            assert evict.commit_at(k, i) == keep.commit_at(k, i)
    # evicted rows are gone: reading below the frontier is an error
    with pytest.raises(IndexError):
        evict.commit_at(e - 3, 0)
    with pytest.raises(IndexError):
        evict.commit_row(0)


def test_timeline_eviction_bounds_physical_storage():
    _, evict = _timeline_pair(epochs=200)
    # a 2-row retention tail must not grow O(E) physical storage: the
    # compact-or-grow policy keeps capacity proportional to the live span
    assert evict._commit.shape[0] <= 16
    assert evict.commit_ms.shape == (2, evict.n)


def test_timeline_eviction_is_monotone_and_clamped():
    _, evict = _timeline_pair(epochs=5)
    evict.evict_commit_rows(2)          # below current frontier: no-op
    assert evict.evicted_epochs == 3
    evict.evict_commit_rows(100)        # clamped to the appended horizon
    assert evict.evicted_epochs == 5
    assert evict.commit_ms.shape == (0, evict.n)


def test_timeline_frontier_boundary_reads():
    keep, evict = _timeline_pair(epochs=8)
    f = evict.evicted_epochs
    assert f == 6
    # reads AT the frontier are the live boundary: exact and allowed
    for i in range(evict.n):
        assert evict.commit_at(f, i) == keep.commit_at(f, i)
    assert np.array_equal(evict.commit_row(f), keep.commit_row(f))
    # one below the frontier: evicted, every read form raises
    with pytest.raises(IndexError, match="evicted"):
        evict.commit_at(f - 1, 0)
    with pytest.raises(IndexError):
        evict.commit_row(f - 1)
    # past the appended horizon is equally out of range
    with pytest.raises(IndexError, match="not yet appended"):
        evict.commit_at(evict.n_epochs, 0)


# ---------------------------------------------------------------------------
# EpochLatencyCycle: lazy cyclic trace view
# ---------------------------------------------------------------------------


def test_epoch_latency_cycle_wraps_and_bounds():
    from repro.core.simulator import EpochLatencyCycle

    trace = [np.full((2, 2), float(k)) for k in range(3)]
    lats = EpochLatencyCycle(trace, n_epochs=8)
    assert len(lats) == 8
    for e in range(8):
        assert np.array_equal(lats[e], trace[e % 3])
    # the consumer idiom lats[min(e, len - 1)] stays in range past the end
    assert np.array_equal(lats[min(11, len(lats) - 1)], trace[7 % 3])
    with pytest.raises(IndexError):
        lats[8]
    with pytest.raises(IndexError):
        lats[-1]


def test_epoch_latency_cycle_rejects_empty_trace():
    from repro.core.simulator import EpochLatencyCycle

    with pytest.raises(ValueError, match="non-empty"):
        EpochLatencyCycle([], n_epochs=4)


# ---------------------------------------------------------------------------
# node_commit_ms windowing
# ---------------------------------------------------------------------------


def test_node_commit_ms_windowed_equals_full_slice():
    from repro.core import WANSimulator, all_to_all_schedule, stitch_schedules

    rng = np.random.default_rng(3)
    n, epochs = 3, 6
    scheds = [all_to_all_schedule(n, payload_bytes=64.0)
              for _ in range(epochs)]
    stitched = stitch_schedules(scheds, epoch_ms=1.0, n=n)
    lat = rng.uniform(1.0, 4.0, size=(n, n))
    np.fill_diagonal(lat, 0.0)
    res = WANSimulator(lat, 1000.0).run(stitched)
    full = node_commit_ms(stitched, res, n, epochs)
    for start in range(epochs):
        windowed = node_commit_ms(
            stitched, res, n, epochs, start_epoch=start,
            base_row=full[start - 1] if start else None,
        )
        assert np.array_equal(windowed, full[start:])


def test_node_commit_ms_single_epoch_window_equals_full_row():
    from repro.core import WANSimulator, all_to_all_schedule, stitch_schedules

    rng = np.random.default_rng(9)
    n, epochs = 3, 5
    scheds = [all_to_all_schedule(n, payload_bytes=64.0)
              for _ in range(epochs)]
    stitched = stitch_schedules(scheds, epoch_ms=1.0, n=n)
    lat = rng.uniform(1.0, 4.0, size=(n, n))
    np.fill_diagonal(lat, 0.0)
    res = WANSimulator(lat, 1000.0).run(stitched)
    full = node_commit_ms(stitched, res, n, epochs)
    # a one-row window anywhere equals the corresponding full-matrix row
    for start in range(1, epochs):
        one = node_commit_ms(
            stitched, res, n, start + 1, start_epoch=start,
            base_row=full[start - 1],
        )
        assert one.shape == (1, n)
        assert np.array_equal(one[0], full[start])
    # an empty window (start at the horizon) is a well-formed empty matrix
    empty = node_commit_ms(
        stitched, res, n, epochs, start_epoch=epochs,
        base_row=full[-1],
    )
    assert empty.shape == (0, n)


# ---------------------------------------------------------------------------
# ServingSink vs a hand-written full-matrix reference
# ---------------------------------------------------------------------------


def _monotone_commit_matrix(rng, epochs, n, epoch_ms):
    steps = rng.uniform(0.0, 2.5 * epoch_ms, size=(epochs, n))
    return np.cumsum(steps, axis=0)


@pytest.mark.parametrize("seed,epochs",
                         [(0, 1), (1, 4), (2, 7), (3, 12), (4, 9), (5, 2)])
def test_serving_sink_matches_batch_replay(seed, epochs):
    rng = np.random.default_rng(seed)
    n, epoch_ms = 3, 2.0
    commit = _monotone_commit_matrix(rng, epochs, n, epoch_ms)
    lats = [rng.uniform(1.0, 30.0, size=(n, n)) for _ in range(epochs)]
    cfg = ServeConfig(clients_per_node=10_000.0, max_staleness_ms=5.0,
                      cache_keys=20)
    batch = simulate_serving(cfg, commit, lats, epoch_ms,
                             wall_ms=epochs * epoch_ms)
    sink = ServingSink(cfg, n, epoch_ms)
    for e in range(epochs):
        sink.push(e, commit[e], lats[e])
    inc = sink.finish(wall_ms=epochs * epoch_ms)
    assert inc.totals == batch.totals
    assert inc.epochs == batch.epochs
    assert np.array_equal(inc.latency_values_ms, batch.latency_values_ms)
    assert np.array_equal(inc.latency_weights, batch.latency_weights)
    # prefix sufficiency: the sink (which only ever saw rows [0, e]) equals
    # the historical batch form evaluated against the FULL matrix — future
    # rows delivered "early" can only change the view count where staleness
    # clamps to 0.0 on both sides
    for e, se in enumerate(inc.epochs):
        ref = view_staleness_ms(commit, e * epoch_ms, epoch_ms)
        assert se.view_staleness_ms_mean == float(ref.mean())
        assert se.view_staleness_ms_max == float(ref.max())


def test_serving_sink_rejects_out_of_order_pushes():
    cfg = ServeConfig(clients_per_node=1_000.0)
    sink = ServingSink(cfg, 2, 1.0)
    sink.push(0, np.zeros(2), np.zeros((2, 2)))
    with pytest.raises(ValueError):
        sink.push(0, np.zeros(2), np.zeros((2, 2)))
    with pytest.raises(ValueError):
        sink.push(2, np.zeros(2), np.zeros((2, 2)))


def test_serving_sink_requires_context():
    cfg = ServeConfig(clients_per_node=1_000.0)
    sink = ServingSink(cfg, 2, 1.0)
    with pytest.raises(ValueError):
        sink.on_epoch(_stats(0), None)


# ---------------------------------------------------------------------------
# config rules
# ---------------------------------------------------------------------------


def test_config_rules_for_bounded_runs():
    # EngineConfig.__post_init__ runs validate_config, so incompatible
    # configs are rejected at construction
    with pytest.raises(ValueError, match="stats_window"):
        EngineConfig(n_nodes=3, stats_window=-1)
    with pytest.raises(ValueError, match="keep_epochs"):
        EngineConfig(n_nodes=3, streaming=True, serve=ServeConfig(),
                     keep_epochs=False)
    ok = EngineConfig(n_nodes=3, streaming=True,
                      serve=ServeConfig(keep_epochs=False), keep_epochs=False)
    assert check_config(ok) == []
