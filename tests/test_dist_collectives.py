"""Distribution-plane unit tests: sync strategies, relay ring, filter math.

Uses 8 forced host devices, mesh (2, 2, 2) = (pod, data, model).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import (
    SyncConfig,
    chunked_topk_exchange,
    estimate_sync_bytes,
    relay_psum,
    sync_gradients,
)
from repro.launch.mesh import make_small_mesh


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return make_small_mesh()


def _podmap(mesh, fn, n_in=1):
    return jax.jit(
        jax.shard_map(
            fn, mesh=mesh,
            in_specs=tuple([P()] * n_in), out_specs=P(),
            axis_names={"pod"}, check_vma=False,
        )
    )


def test_relay_psum_matches_psum(mesh):
    x = jnp.arange(8.0)

    def body(x):
        per_pod = x + jax.lax.axis_index("pod").astype(jnp.float32)
        a = jax.lax.psum(per_pod, "pod")
        b = relay_psum(per_pod, "pod", order=(1, 0))
        return jnp.stack([a, b])

    out = _podmap(mesh, body)(x)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]), rtol=1e-6)


def test_chunked_topk_exchange_mean_semantics(mesh):
    """With density=1.0 the exchange equals a plain pmean; residual zero."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)

    def body(g):
        local = g * (1.0 + jax.lax.axis_index("pod").astype(jnp.float32))
        dense = jax.lax.pmean(local, "pod")
        out, res = chunked_topk_exchange(
            local, jnp.zeros_like(local), axis="pod", density=1.0, chunk=64
        )
        return dense, out, res

    dense, out, res = _podmap(mesh, body)(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=1e-5)
    assert float(jnp.abs(res).max()) == 0.0


def test_chunked_topk_error_feedback_conserves(mesh):
    """sent + residual' == grad + residual per pod (mass conservation)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)

    def body(g, r):
        local = g * (1.0 + jax.lax.axis_index("pod").astype(jnp.float32))
        out, new_r = chunked_topk_exchange(
            local, r, axis="pod", density=0.25, chunk=32
        )
        # reconstruct this pod's sent values: (acc - new_r)
        sent = (local + r) - new_r
        # out is mean over pods of all sent: check via psum
        mean_sent = jax.lax.pmean(sent, "pod")
        return out, mean_sent, new_r, local + r

    out, mean_sent, new_r, acc = _podmap(mesh, lambda g, r: body(g, r), 2)(g, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mean_sent), rtol=1e-5)
    # conservation on pod 0's view: sent + residual == acc
    np.testing.assert_allclose(
        np.asarray(mean_sent * 0 + (acc - new_r) + new_r), np.asarray(acc), rtol=1e-6
    )


def test_sync_gradients_strategies_agree_at_density_1(mesh):
    rng = np.random.default_rng(2)
    tree = {"a": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}

    def body(a, b):
        grads = {"a": a * (1.0 + jax.lax.axis_index("pod").astype(jnp.float32)),
                 "b": b}
        res = jax.tree.map(jnp.zeros_like, grads)
        hier, _ = sync_gradients(grads, None, SyncConfig(strategy="hier"),
                                 n_pods=2)
        geo, _ = sync_gradients(
            grads, res,
            SyncConfig(strategy="geococo", density=1.0, chunk=64,
                       min_leaf_size=8),
            n_pods=2,
        )
        return hier["a"], geo["a"], hier["b"], geo["b"]

    ha, ga, hb, gb = _podmap(mesh, body, 2)(tree["a"], tree["b"])
    np.testing.assert_allclose(np.asarray(ha), np.asarray(ga), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hb), np.asarray(gb), rtol=1e-5)


def test_sync_gradients_ring_order_matches_pmean(mesh):
    """A control-plane-fed ring_order routes the exchange through
    relay_psum; the result equals the stock pmean path (up to float
    reassociation) for both dense and filtered strategies."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)

    def body(g):
        local = g * (1.0 + jax.lax.axis_index("pod").astype(jnp.float32))
        grads = {"w": local}
        res = jax.tree.map(jnp.zeros_like, grads)
        h0, _ = sync_gradients(grads, None, SyncConfig(strategy="hier"),
                               n_pods=2)
        h1, _ = sync_gradients(
            grads, None, SyncConfig(strategy="hier", ring_order=(1, 0)),
            n_pods=2,
        )
        geo_cfg = SyncConfig(strategy="geococo", density=0.25, chunk=32,
                             min_leaf_size=8)
        g0, r0 = sync_gradients(grads, res, geo_cfg, n_pods=2)
        g1, r1 = sync_gradients(
            grads, res,
            SyncConfig(strategy="geococo", density=0.25, chunk=32,
                       min_leaf_size=8, ring_order=(1, 0)),
            n_pods=2,
        )
        return h0["w"], h1["w"], g0["w"], g1["w"], r0["w"], r1["w"]

    h0, h1, g0, g1, r0, r1 = _podmap(mesh, body)(g)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1), rtol=1e-6)


def test_sync_config_ring_order_validation():
    assert SyncConfig(ring_order=(2, 0, 1)).ring_order == (2, 0, 1)
    with pytest.raises(ValueError, match="permutation"):
        SyncConfig(ring_order=(0, 2))
    with pytest.raises(ValueError, match="does not cover"):
        sync_gradients({"w": jnp.ones((4,))}, None,
                       SyncConfig(strategy="hier", ring_order=(0, 1, 2)),
                       n_pods=2)


def test_estimate_sync_bytes_ordering():
    n = 10_000_000
    flat = estimate_sync_bytes(n, SyncConfig(strategy="flat"), 2)
    geo = estimate_sync_bytes(n, SyncConfig(strategy="geococo", density=0.05), 2)
    assert geo < flat * 0.2


def test_single_pod_noop():
    g = {"w": jnp.ones((8, 8))}
    out, res = sync_gradients(g, None, SyncConfig(strategy="hier"), n_pods=1)
    assert out is g and res is None
