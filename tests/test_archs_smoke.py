"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and absence of NaNs; decode parity for
autoregressive archs (prefill+decode == full forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, applicable_shapes
from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.models.model import (
    active_param_count,
    forward,
    init_cache,
    init_params,
    param_count,
)

B, S = 2, 32


def _batch(cfg, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.frontend == "token":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32
        )
    else:
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 1, size=(b, s, cfg.d_model)), jnp.float32
        )
    if cfg.n_img_tokens:
        batch["img"] = jnp.asarray(
            rng.normal(0, 1, size=(b, cfg.n_img_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_direction(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, seed=1)

    if cfg.frontend == "token":
        labels = jnp.roll(batch["tokens"], -1, axis=1)
    else:
        labels = jnp.zeros((B, S), jnp.int32)

    def loss_fn(p):
        logits, _ = forward(cfg, p, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    # one SGD step lowers the loss on the same batch
    lr = 0.05
    new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(loss_fn)(new_params)
    assert float(loss2) < float(loss) + 1e-6


@pytest.mark.parametrize(
    "arch",
    [a for a in ARCHS if not get_config(a).is_encoder_only],
)
def test_decode_matches_full_forward(arch):
    """Prefill + stepwise decode reproduces the full-sequence logits."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, seed=2)
    full_logits, _ = forward(cfg, params, batch, compute_dtype=jnp.float32)

    cache = init_cache(cfg, B, max_len=S, dtype=jnp.float32)
    s_pre = S // 2
    toks = batch["tokens"]
    pre_batch = {k: (v[:, :s_pre] if k == "tokens" else v) for k, v in batch.items()}
    logits_pre, cache = forward(
        cfg, params, pre_batch, cache=cache, compute_dtype=jnp.float32
    )
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]),
        np.asarray(full_logits[:, s_pre - 1]),
        rtol=2e-2, atol=2e-2,
    )
    # two decode steps
    for t in range(s_pre, s_pre + 2):
        step_batch = {"tokens": toks[:, t : t + 1]}
        if "img" in batch:
            step_batch["img"] = batch["img"]
        logits_t, cache = forward(
            cfg, params, step_batch, cache=cache, compute_dtype=jnp.float32
        )
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]),
            np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2,
        )


def test_applicable_shape_skips():
    """DESIGN.md §4: exactly 31 runnable cells with documented skips."""
    from repro.configs.registry import cells

    cs = cells()
    assert len(cs) == 31
    names = {(a, s.name) for a, s in cs}
    assert ("hubert-xlarge", "decode_32k") not in names
    assert ("hubert-xlarge", "long_500k") not in names
    assert ("minitron-8b", "long_500k") not in names
    assert ("deepseek-v3-671b", "long_500k") not in names
    assert ("rwkv6-7b", "long_500k") in names
    assert ("recurrentgemma-9b", "long_500k") in names


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks for every arch)."""
    expect = {
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
        assert cfg.d_ff == ff and cfg.vocab_size == v
    # MoE extras
    dsv3 = get_config("deepseek-v3-671b")
    assert dsv3.moe.n_experts == 256 and dsv3.moe.top_k == 8
    assert dsv3.moe.n_shared == 1 and dsv3.moe.d_expert == 2048
    gran = get_config("granite-moe-3b-a800m")
    assert gran.moe.n_experts == 40 and gran.moe.top_k == 8
    assert get_config("qwen2.5-32b").qkv_bias
    assert not get_config("hubert-xlarge").causal


def test_param_counts_plausible():
    assert abs(param_count(get_config("deepseek-v3-671b")) / 1e9 - 671) < 5
    assert abs(active_param_count(get_config("deepseek-v3-671b")) / 1e9 - 37) < 3
    assert abs(param_count(get_config("deepseek-7b")) / 1e9 - 7) < 1
    assert abs(param_count(get_config("deepseek-coder-33b")) / 1e9 - 33) < 2
