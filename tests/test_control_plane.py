"""repro.control: NetworkView estimation, ControlPlane events, and the
two-plane subscription wiring (WAN engine + device-plane trainer observing
one plane).  The trainer integration uses 8 forced host devices."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

from repro.control import (
    ControlPlane,
    LinkDegraded,
    LinkRecovered,
    MonitorView,
    PlanChanged,
    RelayOrderChanged,
    TraceView,
    VivaldiView,
    relay_ring_order,
    ring_cost,
)
from repro.core import EngineConfig, GeoCluster, YCSBConfig, YCSBGenerator
from repro.core.latency import aws_latency_matrix, jitter_trace
from repro.core.monitor import PROBE_BYTES, LatencyMonitor, VivaldiSystem
from repro.core.planner import Replanner, kcenter_grouping


# a 4-node "square": perimeter links 10 ms, diagonals 14 ms.  The bottleneck
# relay ring is the perimeter (0,1,2,3).  Spiking the (0,1) and (2,3) edges
# makes (0,2,1,3) the best ring even under TIV relays — a genuine
# order-changing degradation, not just noise.
SQUARE = np.array(
    [
        [0.0, 10.0, 14.0, 10.0],
        [10.0, 0.0, 10.0, 14.0],
        [14.0, 10.0, 0.0, 10.0],
        [10.0, 14.0, 10.0, 0.0],
    ]
)


def _spiked_square() -> np.ndarray:
    spk = SQUARE.copy()
    spk[0, 1] = spk[1, 0] = 100.0
    spk[2, 3] = spk[3, 2] = 100.0
    return spk


# ---------------------------------------------------------------------------
# NetworkView implementations
# ---------------------------------------------------------------------------


def test_trace_view_playback_and_zero_probe_cost():
    frames = [SQUARE, _spiked_square()]
    v = TraceView(frames, loop=False)
    assert v.n == 4 and v.rounds == 2
    np.testing.assert_array_equal(v.sample(), SQUARE)
    np.testing.assert_array_equal(v.sample(), _spiked_square())
    np.testing.assert_array_equal(v.sample(), _spiked_square())  # tail repeats
    assert v.probe_bytes == 0
    looped = TraceView(frames)  # loop=True default
    looped.sample(), looped.sample()
    np.testing.assert_array_equal(looped.sample(), SQUARE)
    # a single static matrix is a 1-frame trace
    assert TraceView(SQUARE).rounds == 1


def test_monitor_view_symmetry_diag_and_probe_accounting():
    base = aws_latency_matrix()
    trace = jitter_trace(base, 12, np.random.default_rng(0))
    v = MonitorView(TraceView(trace), noise=0.2, rng=np.random.default_rng(1))
    n = v.n
    for r in range(1, 9):
        est = v.sample()
        # noisy probes stay symmetric with a zero diagonal
        np.testing.assert_allclose(est, est.T, rtol=1e-12)
        np.testing.assert_array_equal(np.diag(est), np.zeros(n))
        assert (est >= 0).all()
        # probe-byte accounting is exact: full mesh, n*(n-1) probes/round
        assert v.probe_bytes == r * n * (n - 1) * PROBE_BYTES
    # estimate() pays nothing
    before = v.probe_bytes
    v.estimate()
    assert v.probe_bytes == before


def test_latency_monitor_noise_symmetry_direct():
    truth = aws_latency_matrix()
    mon = LatencyMonitor(10, alpha=0.5)
    rng = np.random.default_rng(2)
    for _ in range(5):
        est = mon.probe_all(truth, rng, noise=0.3)
    np.testing.assert_allclose(est, est.T, rtol=1e-12)
    np.testing.assert_array_equal(np.diag(est), np.zeros(10))
    assert mon.probe_bytes == 5 * 10 * 9 * PROBE_BYTES
    # EWMA converges near truth despite noise
    off = ~np.eye(10, dtype=bool)
    rel = np.abs(est[off] - truth[off]) / truth[off]
    assert np.median(rel) < 0.3


def test_vivaldi_drift_correction():
    """Verification sampling (Sec 5) pins drifting entries: after the truth
    shifts, verify_and_correct beats the raw coordinate estimate."""
    truth = aws_latency_matrix()
    sys = VivaldiSystem(10, seed=0)
    sys.fit(truth, rounds=120, samples_per_node=8, rng=np.random.default_rng(0))
    assert sys.median_rel_error(truth) < 0.25
    # sustained drift: a congestion episode inflates one region's links 3x
    drifted = truth.copy()
    drifted[7, :] *= 3.0
    drifted[:, 7] *= 3.0
    np.fill_diagonal(drifted, 0.0)
    raw = sys.estimate()
    corrected = sys.verify_and_correct(
        drifted, sample_frac=0.5, rng=np.random.default_rng(1), tol=0.25
    )
    iu = np.triu_indices(10, k=1)
    err_raw = np.abs(raw[iu] - drifted[iu]) / drifted[iu]
    err_cor = np.abs(corrected[iu] - drifted[iu]) / drifted[iu]
    assert np.median(err_cor) < np.median(err_raw)
    # corrected entries are pinned to the measurement exactly
    assert (np.abs(corrected[iu] - drifted[iu]) < 1e-9).sum() > 0


def test_vivaldi_view_contract_and_probe_accounting():
    base = aws_latency_matrix()
    v = VivaldiView(TraceView(base), samples_per_node=4, verify_every=3, seed=0)
    n = v.n
    probes = 0
    for r in range(1, 7):
        est = v.sample()
        np.testing.assert_allclose(est, est.T, rtol=1e-12)
        np.testing.assert_array_equal(np.diag(est), np.zeros(n))
        assert (est >= 0).all()
        probes += n * 4  # one sparse round
        if r % 3 == 0:  # plus the verification sample
            n_pairs = n * (n - 1) // 2
            probes += max(1, int(0.05 * n_pairs))
        assert v.probe_bytes == probes * PROBE_BYTES
    # the large-scale regime probes far less than the full mesh
    full = 6 * n * (n - 1) * PROBE_BYTES
    assert v.probe_bytes < full / 2


# ---------------------------------------------------------------------------
# relay-order search
# ---------------------------------------------------------------------------


def test_relay_ring_order_is_canonical_permutation():
    rng = np.random.default_rng(3)
    for n in (2, 3, 5, 8):
        lat = rng.uniform(5.0, 50.0, size=(n, n))
        lat = (lat + lat.T) / 2.0
        np.fill_diagonal(lat, 0.0)
        order = relay_ring_order(lat)
        assert sorted(order) == list(range(n))
        assert order[0] == 0  # canonical start
        if n > 2:
            assert order[1] < order[-1]  # canonical direction


def test_relay_ring_order_bottleneck_objective():
    # line topology 0-1-2-3: any ring must close the long 0..3 loop, but the
    # bottleneck-optimal ring avoids pairing the two far endpoints adjacently
    pos = np.array([0.0, 10.0, 20.0, 30.0])
    lat = np.abs(pos[:, None] - pos[None, :])
    order = relay_ring_order(lat, tiv=False)
    best = min(
        ((0, 1, 2, 3), (0, 1, 3, 2), (0, 2, 1, 3)),
        key=lambda o: ring_cost(lat, o),
    )
    assert order == best
    assert ring_cost(lat, order) <= ring_cost(lat, (0, 1, 2, 3))


def test_relay_ring_order_changes_under_degradation():
    assert relay_ring_order(SQUARE) == (0, 1, 2, 3)
    assert relay_ring_order(_spiked_square()) == (0, 2, 1, 3)


def test_relay_ring_order_scores_direct_hops_by_default():
    """relay_psum executes direct ppermute hops, so the default search must
    score direct latencies: a relay-only-cheap pair (200 ms direct, 2+2 ms
    via a relay) is not a cheap ring hop and must not be ring-adjacent."""
    import itertools

    lat = np.array(
        [
            [0.0, 200.0, 2.0, 8.0],
            [200.0, 0.0, 2.0, 8.0],
            [2.0, 2.0, 0.0, 8.0],
            [8.0, 8.0, 8.0, 0.0],
        ]
    )
    order = relay_ring_order(lat)  # default: direct scoring
    n = len(order)
    edges = {frozenset((order[i], order[(i + 1) % n])) for i in range(n)}
    assert frozenset((0, 1)) not in edges
    # the executed (direct) bottleneck is the optimum over all 4-node rings
    best = min(
        ring_cost(lat, (0,) + p) for p in itertools.permutations((1, 2, 3))
    )
    assert ring_cost(lat, order) == best
    # and the ControlPlane's ring search defaults to direct scoring too
    assert ControlPlane().ring_tiv is False


def test_vivaldi_warmup_seeds_from_direct_rtts():
    """Monitor-seeded warmup: the first K rounds pay the full mesh, return
    the direct measurement, and seed the coordinates — after warmup the
    sparse rounds start near-correct instead of untangling random points."""
    truth = aws_latency_matrix()
    warm = VivaldiView(TraceView(truth), samples_per_node=4, verify_every=100,
                       warmup_rounds=2, seed=0)
    n = warm.n
    est = warm.sample()
    np.testing.assert_allclose(est, truth)          # warmup = direct RTTs
    assert warm.probe_bytes == n * (n - 1) * PROBE_BYTES
    warm.sample()
    assert warm.probe_bytes == 2 * n * (n - 1) * PROBE_BYTES
    # post-warmup: sparse probing only, and the seeded coordinates are
    # already accurate (no 100-round fit needed)
    warm.sample()
    assert warm.probe_bytes == 2 * n * (n - 1) * PROBE_BYTES \
        + n * 4 * PROBE_BYTES
    assert warm.system.median_rel_error(truth) < 0.25
    # a cold view with the same budget of sparse rounds is strictly worse
    cold = VivaldiView(TraceView(truth), samples_per_node=4, verify_every=100,
                       seed=0)
    for _ in range(3):
        cold.sample()
    assert warm.system.median_rel_error(truth) < \
        cold.system.median_rel_error(truth)


# ---------------------------------------------------------------------------
# ControlPlane: damping, events, force contract
# ---------------------------------------------------------------------------


def _square_plane(frames, **kw):
    kw.setdefault("replan_sustain", 2)
    kw.setdefault("degrade_sustain", 2)
    cp = ControlPlane(TraceView(frames, loop=False), **kw)
    events = []
    cp.subscribe(events.append)
    return cp, events


def test_control_plane_damps_transient_spikes():
    spk = _spiked_square()
    # one-round spike between healthy rounds: no replan, no link events
    frames = [SQUARE, SQUARE, spk, SQUARE, SQUARE, SQUARE]
    cp, events = _square_plane(frames, replan_sustain=2, degrade_sustain=2)
    for _ in range(len(frames)):
        cp.step()
    assert cp.replan_count == 1  # only the initial plan
    assert not [e for e in events if isinstance(e, (LinkDegraded, LinkRecovered))]
    assert len([e for e in events if isinstance(e, PlanChanged)]) == 1


def test_control_plane_emits_typed_events_on_sustained_degradation():
    spk = _spiked_square()
    frames = [SQUARE] * 3 + [spk] * 4 + [SQUARE] * 4
    cp, events = _square_plane(frames)
    for _ in range(len(frames)):
        cp.step()
    deg = [e for e in events if isinstance(e, LinkDegraded)]
    rec = [e for e in events if isinstance(e, LinkRecovered)]
    plans = [e for e in events if isinstance(e, PlanChanged)]
    orders = [e for e in events if isinstance(e, RelayOrderChanged)]
    assert {(e.i, e.j) for e in deg} == {(0, 1), (2, 3)}
    assert {(e.i, e.j) for e in rec} == {(0, 1), (2, 3)}
    assert all(e.observed_ms > e.baseline_ms for e in deg)
    assert len(plans) >= 2  # initial + sustained-deviation replan
    assert orders[0].order == (0, 1, 2, 3)
    assert (0, 2, 1, 3) in [e.order for e in orders]
    # event history and counters agree
    assert cp.event_counts()["LinkDegraded"] == 2
    assert cp.events == events


def test_control_plane_subscription_filters_and_unsubscribe():
    frames = [SQUARE] * 3 + [_spiked_square()] * 4
    cp = ControlPlane(TraceView(frames, loop=False), replan_sustain=2,
                      degrade_sustain=2)
    only_plans, everything = [], []
    cp.subscribe(only_plans.append, events=(PlanChanged,))
    fn = cp.subscribe(everything.append)
    for _ in range(4):
        cp.step()
    cp.unsubscribe(fn)
    for _ in range(3):
        cp.step()
    assert all(isinstance(e, PlanChanged) for e in only_plans)
    assert len(only_plans) >= 2
    # the unsubscribed listener missed the tail
    assert len(everything) < len(cp.events)


def test_force_replan_fires_immediately_regression():
    """Regression for the Replanner.force() contract: an event-driven replan
    (straggler signal, operator action) must not wait for the next
    observation."""
    cp, events = _square_plane([SQUARE] * 4)
    cp.step()
    n_before = cp.replan_count
    plan = cp.force_replan(reason="straggler@step7")
    assert plan is not None
    assert cp.replan_count == n_before + 1  # replanned NOW, no observe needed
    forced = [e for e in events if isinstance(e, PlanChanged)
              and e.reason == "straggler@step7"]
    assert len(forced) == 1 and forced[0].plan is plan


def test_bare_replanner_force_without_matrix_waits_for_observe():
    """The documented no-matrix arm: force() alone only flags; the replan
    happens at the next observe()."""
    rp = Replanner(lambda l: kcenter_grouping(l, 2), sustain=2)
    rp.observe(SQUARE)
    assert rp.replan_count == 1
    assert rp.force() is None
    assert rp.replan_count == 1          # nothing happened yet
    rp.observe(SQUARE)                   # matrix unchanged, but force pending
    assert rp.replan_count == 2
    # with a matrix, force is immediate
    assert rp.force(SQUARE) is not None
    assert rp.replan_count == 3


def test_force_replan_with_no_observation_is_noop_without_view():
    cp = ControlPlane(plan_fn=lambda lat: kcenter_grouping(lat, 2))
    assert cp.force_replan() is None
    assert cp.events == []


def _mild_square() -> np.ndarray:
    """(0,1) inflated to 18 ms: trips the per-link detector (>1.5x the 10 ms
    baseline) but stays under the 20% mean-deviation replan threshold —
    a link-only signal, no plan change."""
    mild = SQUARE.copy()
    mild[0, 1] = mild[1, 0] = 18.0
    return mild


def test_link_only_signal_takes_incremental_2opt_path():
    mild = _mild_square()
    frames = [SQUARE] * 2 + [mild] * 3 + [SQUARE] * 3
    cp, events = _square_plane(frames, replan_sustain=3)
    for _ in range(len(frames)):
        cp.step()
    # the mild spike never replanned (damping contract intact)...
    assert cp.replan_count == 1
    assert cp.relay_full_searches == 1        # only the initial global search
    # ...but the sustained link signal repaired the ring incrementally:
    # degraded (0,1) pushed it off the perimeter, recovery restored it
    assert cp.relay_incremental_searches == 2
    assert cp.relay_incremental_evals > 0
    orders = [e.order for e in events if isinstance(e, RelayOrderChanged)]
    assert orders == [(0, 1, 2, 3), (0, 2, 1, 3), (0, 1, 2, 3)]
    assert all(e.reason == "link-event" for e in events
               if isinstance(e, RelayOrderChanged) and e.previous is not None)


def test_incremental_2opt_skips_moves_off_the_signalled_edge():
    """The per-edge contract: only moves touching the degraded edge are
    evaluated.  On an 8-node ring with one off-ring edge degraded, the
    incremental pass evaluates a strict subset of the full 2-opt
    neighborhood and leaves the ring unchanged."""
    rng = np.random.default_rng(5)
    pos = np.arange(8) * 10.0
    lat = np.abs(pos[:, None] - pos[None, :])  # line: ring is 0..7
    lat = lat + rng.uniform(0.0, 1.0, size=lat.shape)
    lat = (lat + lat.T) / 2.0
    np.fill_diagonal(lat, 0.0)
    spiked = lat.copy()
    spiked[0, 7] = spiked[7, 0] = lat[0, 7] * 1.8   # already the worst hop's
    frames = [lat] * 2 + [spiked] * 3               # antipodal chord
    cp, events = _square_plane(frames, replan_sustain=10)
    for _ in range(len(frames)):
        cp.step()
    n = 8
    full_neighborhood = n * (n - 3) // 2  # all 2-opt moves on an 8-ring
    assert cp.relay_incremental_searches >= 1
    per_sweep = cp.relay_incremental_evals / cp.relay_incremental_searches
    assert per_sweep < full_neighborhood
    assert cp.relay_full_searches == 1


def test_node_failure_flows_through_the_plane():
    cp, events = _square_plane([SQUARE] * 4)
    cp.step()
    victim = cp.plan.aggregators[0]
    plan = cp.on_node_failure(victim)
    assert victim not in [a for g in plan.groups for a in g]
    fails = [e for e in events if isinstance(e, PlanChanged)
             and e.reason.startswith("node-failure")]
    assert len(fails) == 1
    # full regroup at the next observation (the no-matrix force arm)
    n = cp.replan_count
    cp.step()
    assert cp.replan_count == n + 1


# ---------------------------------------------------------------------------
# two-plane wiring
# ---------------------------------------------------------------------------


def _tiny_cluster(control=None, n=4, seed=0):
    eng = GeoCluster(
        EngineConfig(n_nodes=n, sync_strategy="geococo", planner="kcenter"),
        control=control, bandwidth_mbps=200.0, seed=seed,
    )
    gen = YCSBGenerator(YCSBConfig(n_keys=300, theta=0.8), n, seed=seed)
    return eng, gen


def test_engine_owns_no_private_replanner():
    eng, gen = _tiny_cluster()
    frames = np.stack([SQUARE] * 2 + [_spiked_square()] * 4)
    rs = eng.run(gen, frames, txns_per_node=4)
    # the plan came from the control plane, not a private replanner
    assert eng.control.replan_count >= 1
    assert eng.control.plan is not None
    assert rs.committed > 0
    # the deprecated accessor warns but still reaches the same machinery
    with pytest.warns(DeprecationWarning):
        assert eng._replanner is eng.control.replanner


def test_engine_binds_payload_planner_only_on_default_plane():
    cp = ControlPlane(plan_fn=lambda lat: kcenter_grouping(lat, 2))
    eng, _ = _tiny_cluster(control=cp)
    # an explicit planner on a shared plane is kept
    assert cp.replanner.plan_fn != eng._plan_fn
    cp2 = ControlPlane()
    eng2, _ = _tiny_cluster(control=cp2)
    assert cp2.replanner.plan_fn == eng2._plan_fn


def test_both_planes_observe_the_same_event_instances():
    """Acceptance: one ControlPlane; the WAN engine drives observations and
    a device-plane-style subscriber receives the *same* PlanChanged events."""
    cp = ControlPlane(replan_sustain=2, degrade_sustain=2)
    device_side = []
    cp.subscribe(device_side.append, events=(PlanChanged, RelayOrderChanged))
    eng, gen = _tiny_cluster(control=cp)
    assert eng.control is cp
    frames = np.stack([SQUARE] * 3 + [_spiked_square()] * 4)
    eng.run(gen, frames, txns_per_node=4)
    plans = [e for e in device_side if isinstance(e, PlanChanged)]
    assert len(plans) >= 2  # initial + sustained-deviation
    # identity: the device side holds the exact event objects in history
    for e in plans:
        assert any(e is h for h in cp.events)
    # and the engine's current plan is the last PlanChanged payload
    assert plans[-1].plan is cp.plan


# ---------------------------------------------------------------------------
# trainer integration (device plane) — 8 forced host devices
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pod4_mesh():
    import jax

    from repro.launch.mesh import make_small_mesh

    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return make_small_mesh((4, 2), ("pod", "data"))


def _mk_trainer(mesh, control, steps=8, strategy="geococo"):
    from repro.configs.registry import get_smoke_config
    from repro.data.pipeline import DataConfig
    from repro.dist.collectives import SyncConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config("minitron-8b")
    tcfg = TrainConfig(
        sync=SyncConfig(strategy=strategy, density=0.25, chunk=64,
                        min_leaf_size=64),
        optim=AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2),
    )
    run_cfg = TrainerConfig(steps=steps, log_every=100)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    return Trainer(cfg, mesh, tcfg, run_cfg, data_cfg, control=control)


def test_trainer_relay_order_follows_control_events(pod4_mesh):
    """Acceptance: a geococo Trainer under an injected latency-spike trace
    changes relay_psum's ring order via a ControlPlane RelayOrderChanged
    event, rebuilds its step, and keeps training."""
    frames = [SQUARE] * 2 + [_spiked_square()] * 8
    cp = ControlPlane(TraceView(frames, loop=False), replan_sustain=2,
                      degrade_sustain=2)
    tr = _mk_trainer(pod4_mesh, cp)
    hist = tr.run()
    orders = [e.order for e in tr.network_events
              if isinstance(e, RelayOrderChanged)]
    assert orders[0] == (0, 1, 2, 3)          # measured pre-spike ring
    assert tr.tcfg.sync.ring_order == relay_ring_order(_spiked_square())
    assert len(set(orders)) >= 2              # the order demonstrably changed
    assert tr.sync_rebuilds >= 2              # each change rebuilt the step
    assert len(hist) == 8
    assert np.isfinite(hist[-1]["loss"]) and hist[-1]["loss"] < hist[0]["loss"]


def test_trainer_straggler_trip_forces_immediate_replan(pod4_mesh):
    frames = [SQUARE] * 12
    cp = ControlPlane(TraceView(frames, loop=False), replan_sustain=3)
    tr = _mk_trainer(pod4_mesh, cp, steps=4)
    tr.monitor.threshold = 0.0  # trip on every observed step
    tr.monitor.sustain = 1
    tr.run()
    forced = [e for e in cp.events if isinstance(e, PlanChanged)
              and e.reason.startswith("straggler@")]
    assert len(forced) >= 1  # the trip replanned without waiting a round


def test_trainer_on_straggler_callback_is_deprecated(pod4_mesh):
    from repro.train.trainer import Trainer

    with pytest.warns(DeprecationWarning, match="on_straggler"):
        tr = _mk_trainer(pod4_mesh, None)
        Trainer(
            tr.model_cfg, pod4_mesh, tr.tcfg, tr.run_cfg, tr.data_cfg,
            on_straggler=lambda t: None,
        )
