import numpy as np
import pytest

from repro.core.latency import GeoClusterSpec, geo_clustered_matrix
from repro.core.planner import kcenter_grouping, milp_grouping, no_grouping
from repro.core.schedule import (
    all_to_all_schedule,
    hierarchical_schedule,
    leader_schedule,
    max_messages_per_node,
    messages_per_node,
)
from repro.core.simulator import WANSimulator


def _lat(n, seed=0):
    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=max(2, n // 3)),
        np.random.default_rng(seed),
    )
    return lat


def test_all_to_all_counts():
    n = 7
    s = all_to_all_schedule(n, 100.0)
    assert s.n_transfers == n * (n - 1)
    cnt = messages_per_node(s, n)
    assert (cnt == 2 * (n - 1)).all()


def test_round_guarantee_eq6_eq7():
    """Paper Eq. 6-7: C_geococo <= C_baseline = 2(N-1) per node."""
    for seed in range(5):
        n = 10
        lat = _lat(n, seed)
        plan = kcenter_grouping(lat, 3)
        s = hierarchical_schedule(plan, 100.0)
        assert max_messages_per_node(s, n) <= 2 * (n - 1)


def test_hierarchical_phases_and_payloads():
    n = 6
    lat = _lat(n, 1)
    plan = milp_grouping(lat, 2)
    pay = np.arange(1.0, n + 1.0) * 10
    s = hierarchical_schedule(plan, pay)
    assert len(s.phases) == 3
    gathers = s.phases[0]
    exchanges = s.phases[1]
    scatters = s.phases[2]
    # every non-aggregator sends exactly once in phase 1
    simple = set(range(n)) - set(plan.aggregators)
    assert {t.src for t in gathers} == simple
    # phase 2 is a full mesh among aggregators
    assert len(exchanges) == plan.k * (plan.k - 1)
    # exchange payload = consolidated group payload
    g0 = plan.groups[0]
    expect = sum(pay[i] for i in g0)
    t0 = next(t for t in exchanges if t.src == plan.aggregators[0])
    assert t0.nbytes == pytest.approx(expect)
    # scatter payload = total minus the member's own contribution
    total = pay.sum()
    for t in scatters:
        assert t.nbytes == pytest.approx(total - pay[t.dst])


def test_tiv_relay_reduces_makespan():
    rng = np.random.default_rng(3)
    lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=9, n_clusters=3, congestion_frac=0.5,
                       congestion_mult=(2.0, 4.0)),
        rng,
    )
    plan = milp_grouping(lat, 3)
    sim = WANSimulator(lat)
    s_direct = hierarchical_schedule(plan, 100.0)
    s_tiv = hierarchical_schedule(plan, 100.0, lat=lat, tiv=True)
    m_direct = sim.run(s_direct).makespan_ms
    m_tiv = sim.run(s_tiv).makespan_ms
    assert m_tiv <= m_direct + 1e-9


def test_simulator_transfer_math():
    lat = np.array([[0.0, 50.0], [50.0, 0.0]])
    bw = 100.0  # Mbps
    sim = WANSimulator(lat, bw)
    s = all_to_all_schedule(2, 1_000_000.0)  # 1 MB each way
    r = sim.run(s)
    # 1 MB over 100 Mbps = 80 ms + 50 ms propagation
    assert r.makespan_ms == pytest.approx(130.0, rel=1e-6)
    assert r.bytes_out.tolist() == [1_000_000.0, 1_000_000.0]
    assert r.total_bytes == pytest.approx(2_000_000.0)


def test_simulator_loss_penalty():
    lat = np.array([[0.0, 10.0], [10.0, 0.0]])
    sim0 = WANSimulator(lat, np.inf, loss=0.0)
    sim5 = WANSimulator(lat, np.inf, loss=0.05, retx_timeout_ms=100.0)
    s = all_to_all_schedule(2, 0.0)
    assert sim5.run(s).makespan_ms > sim0.run(s).makespan_ms


def test_relay_accounting():
    lat = np.array(
        [[0.0, 100.0, 10.0], [100.0, 0.0, 10.0], [10.0, 10.0, 0.0]]
    )
    from repro.core.schedule import Transfer, TransmissionSchedule

    s = TransmissionSchedule([[Transfer(0, 1, 500.0, via=2)]])
    sim = WANSimulator(lat)
    r = sim.run(s)
    assert r.makespan_ms == pytest.approx(20.0)  # two 10ms hops
    assert r.bytes_out[0] == 500.0 and r.bytes_out[2] == 500.0
    assert r.bytes_in[2] == 500.0 and r.bytes_in[1] == 500.0
    assert r.msg_matrix[0, 2] == 1 and r.msg_matrix[2, 1] == 1


def test_lower_bound_below_any_schedule():
    for seed in range(4):
        n = 8
        lat = _lat(n, seed + 20)
        sim = WANSimulator(lat)
        lb = sim.lower_bound_ms()
        m_flat = sim.run(all_to_all_schedule(n, 0.0)).makespan_ms
        plan = kcenter_grouping(lat, 3, tiv=True)
        m_hier = sim.run(
            hierarchical_schedule(plan, 0.0, lat=lat, tiv=True)
        ).makespan_ms
        assert lb <= m_flat + 1e-9
        assert lb <= m_hier + 1e-9


def test_leader_schedule_grouped_vs_flat():
    n = 9
    lat = _lat(n, 30)
    plan = kcenter_grouping(lat, 3)
    s_flat = leader_schedule(n, 0, 1000.0)
    s_grp = leader_schedule(n, 0, 1000.0, plan)
    assert s_flat.n_transfers == n - 1
    # leader sends at most k messages in phase 1 under grouping
    assert len(s_grp.phases[0]) <= plan.k
    # every node still receives the payload
    received = {t.dst for p in s_grp.phases for t in p} | {0}
    assert received == set(range(n))
