"""Hypothesis property tests for the serving plane's bound monotonicity.

The benchmark gates in ``benchmarks/bench_serving.py`` rely on these being
theorems of the model, not empirical luck: for ANY commit-time matrix,
cadence and pair of bounds ``S1 <= S2``,

* tightening the bound (S2 -> S1) never *increases* the stale-serve count
  (a read served stale under a tight bound is served stale under any
  looser one),
* tightening never *decreases* the redirect or reject counts (the redirect
  set is ``{stal_i > S}`` and the reject set ``{min_j stal_j > S}`` — both
  shrink as S grows),
* served reads are monotone non-decreasing in the bound, and every read is
  either served or rejected (conservation).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.serve import ServeConfig, simulate_serving


@st.composite
def serving_instance(draw):
    """A random (commit matrix, latency matrix, cadence, bound pair)."""
    n = draw(st.integers(2, 5))
    n_epochs = draw(st.integers(1, 6))
    epoch_ms = draw(st.floats(1.0, 50.0))
    # per-(epoch, node) delivery delays; cumulative over epochs so each
    # node's commit column is monotone, as node_commit_ms guarantees
    gaps = np.array([
        [draw(st.floats(0.0, 120.0)) for _ in range(n)]
        for _ in range(n_epochs)
    ])
    commit = np.cumsum(gaps + 0.1, axis=0)
    lat = np.array([
        [0.0 if i == j else draw(st.floats(1.0, 100.0)) for j in range(n)]
        for i in range(n)
    ])
    lat = (lat + lat.T) / 2.0
    b1 = draw(st.floats(0.0, 300.0))
    b2 = draw(st.floats(0.0, 300.0))
    policy = draw(st.sampled_from(["redirect", "reject"]))
    return commit, lat, epoch_ms, min(b1, b2), max(b1, b2), policy


@given(serving_instance())
@settings(max_examples=60, deadline=None)
def test_tightening_bound_is_monotone(inst):
    commit, lat, epoch_ms, s1, s2, policy = inst
    runs = {}
    for bound in (s1, s2):
        cfg = ServeConfig(clients_per_node=1e6, max_staleness_ms=bound,
                          policy=policy)
        runs[bound] = simulate_serving(
            cfg, commit, [lat] * commit.shape[0], epoch_ms,
            wall_ms=float(commit.max()),
        )
    tight, loose = runs[s1], runs[s2]
    # tightening never increases stale serves...
    assert tight.stale_served <= loose.stale_served + 1e-6
    # ...and never decreases redirects or rejects
    assert tight.redirected >= loose.redirected - 1e-6
    assert tight.rejected >= loose.rejected - 1e-6
    # served reads are monotone non-decreasing in the bound
    assert tight.served_reads <= loose.served_reads + 1e-6
    # conservation + reject ⊆ redirect, per epoch
    for r in runs.values():
        for e in r.epochs:
            assert e.served + e.rejected == pytest.approx(e.reads)
            if policy == "redirect":
                assert e.rejected <= e.redirected + 1e-9
            else:
                assert e.redirected == 0.0
