"""Staleness-aware OCC feedback loop (``EngineConfig(staleness_feedback=True)``).

The loop under test: the stitched streaming simulation measures per-node
commit times -> each node's snapshot view advances only when its inbound
epoch transfers have delivered -> the workload generators version reads
against *their node's* view -> read-set validation aborts become a function
of network conditions.  Default off: digests stay byte-identical across all
three engines (barrier / event / streaming).
"""

import numpy as np
import pytest

from repro.core import (
    DeltaCRDTStore,
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    TPCCConfig,
    TPCCGenerator,
    Update,
    Version,
    YCSBConfig,
    YCSBGenerator,
    geo_clustered_matrix,
    jitter_trace,
)


def _setup(n=5, epochs=8, seed=1):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=2), np.random.default_rng(seed)
    )
    trace = jitter_trace(lat, epochs, np.random.default_rng(seed + 1))
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    return lat, regions, trace, wan


def _run(*, barrier=False, streaming=False, feedback=False, epoch_ms=2.0,
         bw=20.0, n=5, epochs=8, txns=10, seed=7):
    """TPC-C run on a WAN-constrained 2-region topology.  bw=20 Mbps keeps
    sync makespan above the 2 ms cadence, so feedback mode accrues a real
    backlog (the regime the paper's abort-vs-latency coupling lives in)."""
    _, regions, trace, wan = _setup(n=n, epochs=epochs)
    bwm = np.where(wan, bw, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    cfg = EngineConfig(
        n_nodes=n, barrier=barrier, streaming=streaming,
        staleness_feedback=feedback, grouping=True, filtering=True,
        tiv=True, planner="kcenter", epoch_ms=epoch_ms,
    )
    eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=seed)
    gen = TPCCGenerator(
        TPCCConfig(n_warehouses=20, mix="TPCC-A", remote_prob=0.25,
                   items_per_warehouse=20),
        n, seed=3,
    )
    return eng.run(gen, trace, txns_per_node=txns, n_epochs=epochs)


def test_feedback_requires_streaming():
    """Staleness is measured from the stitched multi-epoch simulation, so
    the flag is rejected without it (and with the barrier engine, which
    streaming already excludes)."""
    with pytest.raises(ValueError, match="staleness_feedback"):
        EngineConfig(n_nodes=4, staleness_feedback=True)
    with pytest.raises(ValueError, match="streaming"):
        EngineConfig(n_nodes=4, staleness_feedback=True, streaming=True,
                     barrier=True)


def test_default_off_digests_identical_across_engines():
    """The regression gate: with staleness_feedback=False (default) the
    committed state is byte-identical across barrier, event and streaming
    engines, and every abort is a write-write abort (the read rule is
    vacuous when reads are versioned against the globally-merged store)."""
    ba = _run(barrier=True)
    ev = _run()
    st = _run(streaming=True)
    assert ba.state_digest == ev.state_digest == st.state_digest
    assert ba.value_digest == ev.value_digest == st.value_digest
    assert ba.committed == ev.committed == st.committed
    # filtering runs against the same (global) snapshot with feedback off,
    # so the wire-byte accounting is identical too — pins the
    # aggregator-own-view change to the staleness_feedback=True path only
    # (the barrier engine's phase-sum accounting differs by construction)
    assert ev.wan_bytes == st.wan_bytes
    for rs in (ba, ev, st):
        assert rs.read_aborts == 0
        assert rs.ww_aborts == rs.aborted


def test_feedback_only_adds_read_aborts():
    """Same transaction stream (TPC-C generation never branches on snapshot
    *values*): write-write aborts are identical per epoch, the read rule
    adds aborts on top, and the committed count can only shrink."""
    off = _run(streaming=True)
    on = _run(streaming=True, feedback=True)
    assert on.total_txns == off.total_txns
    for e_off, e_on in zip(off.epochs, on.epochs):
        assert e_on.ww_aborts == e_off.ww_aborts
        assert e_off.read_aborts == 0
        assert e_on.aborted >= e_off.aborted
    assert on.read_aborts > 0
    assert on.committed < off.committed


def test_feedback_only_adds_read_aborts_ycsb_rewrites():
    """The YCSB generator draws its randomness unconditionally, so even with
    rewrite_frac > 0 (where write *payloads* consult the node's view) the
    txn structure — keys touched, read/write split — is independent of view
    staleness: write-write aborts stay invariant under feedback.  Regression
    for the snapshot-dependent RNG-consumption bug."""
    _, regions, trace, wan = _setup()
    bwm = np.where(wan, 20.0, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    runs = {}
    for feedback in (False, True):
        cfg = EngineConfig(n_nodes=5, streaming=True,
                           staleness_feedback=feedback, grouping=True,
                           filtering=True, tiv=True, planner="kcenter",
                           epoch_ms=2.0)
        eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=7)
        gen = YCSBGenerator(
            YCSBConfig(n_keys=300, theta=0.9, read_ratio=0.4,
                       hot_write_frac=0.3, rewrite_frac=0.2,
                       hot_locality=True),
            5, seed=3, node_region=regions,
        )
        runs[feedback] = eng.run(gen, trace, txns_per_node=10, n_epochs=8)
    off, on = runs[False], runs[True]
    assert on.total_txns == off.total_txns
    for e_off, e_on in zip(off.epochs, on.epochs):
        assert e_on.ww_aborts == e_off.ww_aborts
        assert e_on.aborted >= e_off.aborted
    assert on.read_aborts > 0


def test_feedback_view_lag_tracks_wan_backlog():
    """At a cadence far below the sync makespan the views fall behind
    (lag grows with the backlog) and stale reads abort; at a cadence above
    it every view is fresh by the next arrival — zero lag, zero read
    aborts, and the run is byte-identical to the feedback-off engine."""
    tight = _run(streaming=True, feedback=True, epoch_ms=2.0)
    assert max(e.view_lag_max for e in tight.epochs) >= 2
    assert tight.read_aborts > 0

    slack = _run(streaming=True, feedback=True, epoch_ms=2_000.0)
    assert all(e.view_lag_max == 0 for e in slack.epochs)
    assert slack.read_aborts == 0
    ref = _run(streaming=True, epoch_ms=2_000.0)
    assert slack.state_digest == ref.state_digest
    assert slack.value_digest == ref.value_digest


def test_feedback_abort_rate_falls_with_cadence():
    """The Fig-style coupling: read-abort rate is non-increasing in
    epoch_ms (more cadence slack -> less stale views) and strictly lower at
    the slack end than at the tight end."""
    rates = []
    for ems in (2.0, 20.0, 2_000.0):
        rs = _run(streaming=True, feedback=True, epoch_ms=ems)
        rates.append(rs.read_abort_rate)
    assert all(a >= b - 1e-12 for a, b in zip(rates, rates[1:]))
    assert rates[0] > rates[-1]
    assert rates[-1] == 0.0


def test_generators_version_reads_against_node_views():
    """Per-node snapshot views: each node's reads carry the version its own
    view holds, not the global store's."""
    fresh = DeltaCRDTStore()
    for k in range(50):
        fresh.apply(Update(f"k{k}", b"v", Version(3, k, 0)))
    stale = DeltaCRDTStore()  # node 0's view: saw nothing yet
    gen = YCSBGenerator(
        YCSBConfig(n_keys=50, theta=0.1, read_ratio=1.0), 2, seed=0
    )
    txns = gen.epoch_txns(4, 10, snapshot=[stale, fresh])
    for t in txns[0]:
        for _, ver in t.read_set:
            assert ver == Version.ZERO
    seen = [ver for t in txns[1] for _, ver in t.read_set]
    assert seen and all(v.epoch == 3 for v in seen)
    # a single store still applies to every node (back-compat)
    txns_one = gen.epoch_txns(5, 5, snapshot=fresh)
    for ts in txns_one.values():
        for t in ts:
            for _, ver in t.read_set:
                assert ver.epoch == 3


@pytest.mark.parametrize("make", [
    lambda n: YCSBGenerator(YCSBConfig(n_keys=100, theta=0.5, read_ratio=0.4),
                            n, seed=5),
    lambda n: TPCCGenerator(TPCCConfig(n_warehouses=12), n, seed=5),
])
def test_generator_seq_is_node_local_monotone(make):
    """Regression (duplicate-seq bug): `seq` was a random draw, so two
    same-node same-epoch txns could share a Version.  Now it is a
    node-local monotone counter: versions are unique and ordered by
    generation within a node."""
    n = 3
    gen = make(n)
    last = {}
    seen = set()
    for epoch in range(4):
        txns = gen.epoch_txns(epoch, 40)
        for node, ts in txns.items():
            for t in ts:
                key = (t.epoch, t.seq, t.node)
                assert key not in seen, "duplicate Version emitted"
                seen.add(key)
                assert t.seq > last.get(node, -1)
                last[node] = t.seq


# ---------------------------------------------------------------------------
# aggregator-side filtering under stale views
# ---------------------------------------------------------------------------


def test_aggregator_filters_against_own_view():
    """Under staleness_feedback each group's aggregator filters against
    *its own* (possibly stale) snapshot view — not the globally-merged
    store.  A spy filter records which snapshot it was handed: aggregator
    node ids under feedback, the global store (node_id -1) otherwise."""
    from repro.core import strategies as _strategies
    from repro.core.whitedata import filter_group_batch

    seen: list[int] = []

    def spy(txns, snapshot):
        seen.append(snapshot.node_id)
        return filter_group_batch(txns, snapshot)

    _strategies.register("filter", "spy-staleness-test", spy)
    _, regions, trace, wan = _setup()
    bwm = np.where(wan, 20.0, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    for feedback in (False, True):
        seen.clear()
        cfg = EngineConfig(n_nodes=5, streaming=True,
                           staleness_feedback=feedback, grouping=True,
                           filtering=True, tiv=True, planner="kcenter",
                           epoch_ms=2.0, filter_name="spy-staleness-test")
        eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=7)
        gen = TPCCGenerator(
            TPCCConfig(n_warehouses=20, mix="TPCC-A", remote_prob=0.25,
                       items_per_warehouse=20),
            5, seed=3,
        )
        eng.run(gen, trace, txns_per_node=10, n_epochs=8)
        assert seen
        if feedback:
            assert all(0 <= nid < 5 for nid in seen)
        else:
            assert all(nid == -1 for nid in seen)


def test_stale_aggregator_view_filters_fewer_updates():
    """Soundness of aggregator-own-view filtering: a stale view holds
    *smaller* versions, so the stale and null-effect rules can only fire
    less — the filter under-detects white data, it never drops a black
    update (a version stale against an older snapshot is stale against any
    newer one)."""
    from repro.core.occ import Txn
    from repro.core.whitedata import filter_group_batch

    fresh = DeltaCRDTStore(0)
    fresh.apply(Update("a", b"x", Version(2, 5, 0)))
    fresh.apply(Update("b", b"y", Version(2, 6, 0)))
    stale = DeltaCRDTStore(1)  # this aggregator hasn't merged epoch 2 yet

    txns = [
        # superseded by fresh's (2,5,0) -> stale rule fires on fresh only
        Txn(txn_id=0, node=1, epoch=1, seq=9, read_set=(),
            write_set=(("a", b"old"),)),
        # re-writes fresh's current value -> null rule fires on fresh only
        Txn(txn_id=1, node=1, epoch=3, seq=1, read_set=(),
            write_set=(("b", b"y"),)),
    ]
    fr_fresh = filter_group_batch(txns, fresh)
    fr_stale = filter_group_batch(txns, stale)
    assert fr_fresh.stats.stale_updates == 1
    assert fr_fresh.stats.null_updates == 1
    assert fr_stale.stats.stale_updates == 0
    assert fr_stale.stats.null_updates == 0
    # under-detection only: the stale aggregator keeps (and pays for) more
    assert fr_stale.stats.kept_bytes > fr_fresh.stats.kept_bytes
    assert fr_stale.stats.kept_updates >= fr_fresh.stats.kept_updates
