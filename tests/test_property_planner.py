"""Hypothesis property tests for the Planner/Communicator invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.latency import one_relay_effective, all_pairs_shortest
from repro.core.planner import (
    GroupPlan,
    hierarchical_comm_cost,
    kcenter_grouping,
    no_grouping,
    optimal_k,
    plan_cost,
    random_grouping,
)
from repro.core.schedule import (
    all_to_all_schedule,
    hierarchical_schedule,
    max_messages_per_node,
    messages_per_node,
)
from repro.core.simulator import WANSimulator


@st.composite
def latency_matrices(draw):
    n = draw(st.integers(4, 12))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    # random positive symmetric matrix with zero diagonal
    a = rng.uniform(1.0, 200.0, size=(n, n))
    lat = (a + a.T) / 2.0
    np.fill_diagonal(lat, 0.0)
    return lat


@given(latency_matrices(), st.integers(2, 5))
@settings(max_examples=60, deadline=None)
def test_round_guarantee_any_plan(lat, k):
    """Eq. 6-7 holds for every valid plan on every network."""
    n = lat.shape[0]
    k = min(k, n)
    plan = kcenter_grouping(lat, k)
    sched = hierarchical_schedule(plan, 100.0)
    assert max_messages_per_node(sched, n) <= 2 * (n - 1)
    # per-node counts: aggregators highest, but all bounded
    cnt = messages_per_node(sched, n)
    assert cnt.sum() == 2 * sched.n_transfers


@given(latency_matrices(), st.integers(2, 5))
@settings(max_examples=60, deadline=None)
def test_plan_cost_vs_simulated_latency(lat, k):
    """With infinite bandwidth, the simulated 3-phase makespan never exceeds
    the planner's (pessimistic, symmetric) cost bound — and the lower bound
    never exceeds any schedule's makespan."""
    n = lat.shape[0]
    k = min(k, n)
    plan = kcenter_grouping(lat, k)
    sim = WANSimulator(lat)
    m = sim.run(hierarchical_schedule(plan, 0.0)).makespan_ms
    assert m <= plan_cost(lat, plan) + 1e-6
    assert sim.lower_bound_ms() <= m + 1e-6
    assert sim.lower_bound_ms() <= sim.run(all_to_all_schedule(n, 0.0)).makespan_ms + 1e-6


@given(latency_matrices())
@settings(max_examples=60, deadline=None)
def test_relay_paths_sound(lat):
    """Effective latencies are consistent: eff <= direct, eff >= shortest."""
    eff, relay = one_relay_effective(lat)
    sp = all_pairs_shortest(lat)
    assert (eff <= lat + 1e-9).all()
    assert (sp <= eff + 1e-9).all()
    n = lat.shape[0]
    for i in range(n):
        for j in range(n):
            r = relay[i, j]
            if r >= 0:
                assert abs(eff[i, j] - (lat[i, r] + lat[r, j])) < 1e-9


@given(st.integers(4, 60))
@settings(max_examples=60, deadline=None)
def test_kstar_minimizes_cost_model(n):
    ks = optimal_k(n)
    assert 1.0 <= ks <= n
    costs = {k: hierarchical_comm_cost(n, k) for k in range(1, n + 1)}
    k_best = min(costs, key=costs.get)
    assert abs(ks - k_best) <= 1.5


@given(latency_matrices(), st.integers(2, 5), st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_plans_always_valid(lat, k, seed):
    n = lat.shape[0]
    for plan in (
        kcenter_grouping(lat, min(k, n)),
        random_grouping(lat, min(k, n), np.random.default_rng(seed)),
        no_grouping(lat),
    ):
        plan.validate(n)
        assert plan_cost(lat, plan) >= 0.0
