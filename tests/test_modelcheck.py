"""Bounded model checker (`repro.analysis.modelcheck`): the smoke scope is
violation-free with working provenance counters, every seeded mutant is
rejected, and the pinned adversarial instance reproduces a strict
``event > barrier`` greedy loss.

The full quick tier (the CI gate, ~20 s) runs in the lint job via
``python -m repro.analysis.modelcheck --tier quick``; these tests keep
tier-1 fast by exercising the same code paths at smoke scope.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.modelcheck import (
    Transfer,
    TransmissionSchedule,
    WANSimulator,
    _bw_matrix,
    _lat_matrix,
    check_admission,
    check_eviction,
    model_checked_count,
    rebuild_counterexample,
    reset_model_checked_count,
    run_selftest,
    run_tier,
    scope_for,
)

REPO = Path(__file__).resolve().parents[1]

# the worst instance the m=4 wire-only sweep finds under the lower-triangle
# starved matrix: two heavy 0->1 flows (one dependency-delayed) crossing two
# light acks — greedy overlaps the heavies and loses 42.6% to the barrier
_PINNED_TRI_LOSS = [
    (0, 1, 250_000.0, ()),
    (1, 2, 25_000.0, ()),
    (0, 1, 250_000.0, (1,)),
    (2, 0, 25_000.0, (0,)),
]


def test_smoke_scope_is_violation_free_with_counters():
    reset_model_checked_count()
    report = run_tier(scope_for("smoke"), selftest=False)
    assert report.ok, [
        str(v) for t in report.theorems for v in t.violations
    ]
    counts = report.counts()
    # every theorem family ran and counted clean instances
    for theorem in ("admission", "confluence", "occ_atomicity",
                    "abort_monotonicity", "eviction_prefix"):
        assert counts[theorem] > 0
        assert model_checked_count(theorem) > 0
    assert model_checked_count() == sum(
        model_checked_count(t) for t in counts
    )
    reset_model_checked_count()
    assert model_checked_count() == 0


def test_selftest_rejects_every_seeded_mutant():
    rejected = run_selftest()
    assert rejected == {
        "broken-admission-ranking": True,
        "non-commutative-merge": True,
        "occ-reinstatement": True,
        "frontier-under-read": True,
    }


def test_pinned_counterexample_reproduces_strict_greedy_loss():
    sched = TransmissionSchedule(
        [Transfer(s, d, nb, deps=deps) for s, d, nb, deps in _PINNED_TRI_LOSS],
        label="pinned",
    )
    lat, bw = _lat_matrix(3), _bw_matrix(3, "tri")
    barrier = WANSimulator(lat, bw).barrier_makespan_ms(sched)
    admitted = WANSimulator(lat, bw).run(sched).makespan_ms
    greedy = WANSimulator(lat, bw, admission=False).run(sched).makespan_ms
    # the admission theorem holds on the instance...
    assert admitted <= barrier * (1 + 1e-9) + 1e-6
    # ...and greedy strictly loses, by the sweep's recorded 42.6%
    assert greedy > barrier
    assert greedy / barrier - 1.0 == pytest.approx(0.4258, abs=5e-4)


def test_corpus_entries_rebuild_and_replay():
    report = check_admission(scope_for("smoke"))
    corpus = report.info["corpus"]
    assert report.info["corpus_size"] == len(corpus) > 0
    assert report.info["corpus_max_loss"] == pytest.approx(0.4258, abs=5e-4)
    worst = max(corpus, key=lambda c: c["loss"])
    sched, lat, bw = rebuild_counterexample(worst)
    greedy = WANSimulator(lat, bw, admission=False).run(sched).makespan_ms
    barrier = WANSimulator(lat, bw).barrier_makespan_ms(sched)
    assert greedy == pytest.approx(worst["greedy_ms"])
    assert barrier == pytest.approx(worst["barrier_ms"])
    assert greedy > barrier


def test_eviction_mutant_is_a_frontier_under_read():
    report = check_eviction(
        scope_for("smoke"),
        evict_floor=lambda vn: int(vn.min()) + 1,
    )
    assert report.violations
    assert any("frontier under-read" in v.message for v in report.violations)


def test_modelcheck_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.analysis.modelcheck",
           "--tier", "smoke", "--only", "confluence", "--no-selftest"]
    res = subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "confluence" in res.stdout
    assert "ok" in res.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis.modelcheck",
         "--tier", "smoke", "--only", "no-such-theorem"],
        capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert bad.returncode != 0
