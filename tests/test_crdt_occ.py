import numpy as np
import pytest

from repro.core.crdt import DeltaCRDTStore, Update, Version, merge_updates
from repro.core.occ import (
    Txn,
    committed_updates,
    txn_updates,
    validate_epoch,
    validate_epoch_detailed,
)


def _u(key, val, epoch, seq, node=0, txn=0):
    return Update(key, val, Version(epoch, seq, node), txn)


def test_version_total_order():
    assert Version(0, 1, 2) < Version(0, 1, 3) < Version(0, 2, 0) < Version(1, 0, 0)
    assert Version.ZERO < Version(0, 0, 0)


def test_store_apply_lww():
    s = DeltaCRDTStore()
    assert s.apply(_u("a", b"1", 0, 0))
    assert not s.apply(_u("a", b"0", 0, 0))       # same version: no-op
    assert not s.apply(_u("a", b"older", -1, 5))  # older epoch loses
    assert s.apply(_u("a", b"2", 0, 1))
    assert s.get("a") == b"2"


def test_store_idempotent_and_order_free():
    ups = [_u("a", b"1", 0, 0), _u("a", b"2", 0, 5), _u("b", b"x", 0, 1)]
    s1 = DeltaCRDTStore()
    s1.apply_many(ups)
    s2 = DeltaCRDTStore()
    s2.apply_many(list(reversed(ups)) + ups + ups)  # reorder + duplicates
    assert s1.full_state() == s2.full_state()
    assert s1.digest() == s2.digest()


def test_meta_only_is_wire_form_only():
    u = _u("a", b"payload", 1, 0)
    mu = u.meta_only()
    assert mu.value == b""
    assert mu.version == u.version and mu.key == u.key
    assert mu.nbytes < u.nbytes  # the point: fewer bytes on the wire


def test_merge_updates_invariance():
    ups = [_u("k", b"1", 0, 3), _u("k", b"2", 0, 1), _u("j", b"3", 0, 2)]
    m1 = merge_updates(ups)
    m2 = merge_updates(ups * 3)
    m3 = merge_updates(list(reversed(ups)))
    assert m1 == m2 == m3
    assert m1["k"].value == b"1"  # max version (seq 3) wins


def _txn(tid, node, seq, writes, reads=(), epoch=0):
    return Txn(
        txn_id=tid,
        node=node,
        epoch=epoch,
        seq=seq,
        read_set=tuple(reads),
        write_set=tuple(writes),
    )


def test_validate_first_writer_wins():
    t1 = _txn(1, 0, 10, [("k", b"a")])
    t2 = _txn(2, 1, 20, [("k", b"b")])
    committed, aborted = validate_epoch([t1, t2])
    assert committed == {1} and aborted == {2}


def test_validate_no_reinstatement():
    # t1 wins "x" but loses "y" to t0 -> t1 aborts.
    # t2 also wrote "x" later than t1; t2 still aborts (no reinstatement).
    t0 = _txn(0, 0, 1, [("y", b"0")])
    t1 = _txn(1, 1, 2, [("x", b"1"), ("y", b"1")])
    t2 = _txn(2, 2, 3, [("x", b"2")])
    committed, aborted = validate_epoch([t0, t1, t2])
    assert committed == {0}
    assert aborted == {1, 2}


def test_validate_monotone_under_subset():
    """A transaction aborted in any subset stays aborted in the full set."""
    rng = np.random.default_rng(0)
    txns = []
    for tid in range(30):
        keys = rng.choice(8, size=2, replace=False)
        txns.append(
            _txn(tid, int(rng.integers(3)), int(rng.integers(1000)),
                 [(f"k{k}", bytes([tid])) for k in keys])
        )
    _, aborted_full = validate_epoch(txns)
    subset = txns[:15]
    _, aborted_sub = validate_epoch(subset)
    assert aborted_sub <= aborted_full


def test_read_validation_stale_read():
    snap = DeltaCRDTStore()
    snap.apply(_u("k", b"v", 0, 5))
    ok = _txn(1, 0, 1, [("w", b"x")], reads=[("k", Version(0, 5, 0))], epoch=1)
    stale = _txn(2, 0, 2, [("w2", b"y")], reads=[("k", Version(0, 1, 0))], epoch=1)
    committed, aborted = validate_epoch([ok, stale], snap)
    assert 1 in committed and 2 in aborted


def test_committed_updates_apply_cleanly():
    t1 = _txn(1, 0, 1, [("a", b"1"), ("b", b"2")])
    t2 = _txn(2, 1, 2, [("a", b"3")])  # loses "a"
    ups, aborted = committed_updates([t1, t2])
    assert aborted == {2}
    s = DeltaCRDTStore()
    s.apply_many(ups)
    assert s.get("a") == b"1" and s.get("b") == b"2"


def test_validate_detailed_breakdown():
    """read_aborted / ww_aborted report which rule fired; a transaction can
    fail both, so the sets may overlap and `aborted` is their union."""
    snap = DeltaCRDTStore()
    snap.apply(_u("r", b"v", 0, 5))
    stale_read = [("r", Version(0, 1, 0))]
    t_ok = _txn(1, 0, 1, [("a", b"1")], epoch=1)
    t_read = _txn(2, 1, 1, [("b", b"2")], reads=stale_read, epoch=1)
    t_ww = _txn(3, 2, 2, [("a", b"3")], epoch=1)            # loses "a" to t1
    t_both = _txn(4, 3, 3, [("a", b"4")], reads=stale_read, epoch=1)
    res = validate_epoch_detailed([t_ok, t_read, t_ww, t_both], snap)
    assert res.committed == {1}
    assert res.read_aborted == {2, 4}
    assert res.ww_aborted == {3, 4}
    assert res.aborted == {2, 3, 4}
    # the compat wrapper agrees
    committed, aborted = validate_epoch([t_ok, t_read, t_ww, t_both], snap)
    assert committed == {1} and aborted == {2, 3, 4}


def test_forced_version_collision_single_winner():
    """Regression (duplicate-seq bug): two same-node same-epoch txns sharing
    a Version used to *both* match the winner map and both commit
    conflicting writes to the same key.  Ties now break on txn_id: exactly
    one writer wins, the other aborts."""
    a = _txn(10, 0, 7, [("k", b"a")])
    b = _txn(11, 0, 7, [("k", b"b")])  # forced (epoch, seq, node) collision
    assert a.version == b.version
    committed, aborted = validate_epoch([a, b])
    assert committed == {10} and aborted == {11}
    ups, _ = committed_updates([a, b])
    assert [u.value for u in ups if u.key == "k"] == [b"a"]
    # order-independent: the same txn wins whichever arrives first
    committed2, aborted2 = validate_epoch([b, a])
    assert committed2 == {10} and aborted2 == {11}


def test_winner_map_includes_read_aborted_writers():
    """Pinned semantics (no reinstatement): a read-aborted transaction still
    *wins* the keys it wrote first — a later writer of the same key aborts
    even though the winner itself never commits, and the key ends the epoch
    with no committed write.  This is what makes the abort set monotone in
    read staleness: adding read-aborts can never reinstate a write-write
    loser."""
    snap = DeltaCRDTStore()
    snap.apply(_u("r", b"v", 0, 9))
    # t1 wrote "k" first but read "r" stale; t2 wrote "k" later, reads fresh
    t1 = _txn(1, 0, 1, [("k", b"1")], reads=[("r", Version(0, 1, 0))], epoch=1)
    t2 = _txn(2, 1, 2, [("k", b"2")], reads=[("r", Version(0, 9, 0))], epoch=1)
    res = validate_epoch_detailed([t1, t2], snap)
    assert res.read_aborted == {1}
    assert res.ww_aborted == {2}          # t2 lost "k" to the aborted t1
    assert res.committed == set()
    ups, _ = committed_updates([t1, t2], snap)
    assert not ups                         # "k" gets no committed write
    # monotonicity of the pinned semantics: make t1's read fresh and the
    # abort set strictly shrinks (fresh-view aborts ⊆ stale-view aborts)
    t1_fresh = _txn(1, 0, 1, [("k", b"1")], reads=[("r", Version(0, 9, 0))],
                    epoch=1)
    res_fresh = validate_epoch_detailed([t1_fresh, t2], snap)
    assert res_fresh.aborted == {2}
    assert set(res_fresh.aborted) <= set(res.aborted)


def _random_epoch(rng, *, n_txns=60, n_keys=12, collisions=True):
    """Random epoch with heavy key contention and (optionally) forced
    (epoch, seq, node) version collisions, so the numpy winner map is
    exercised on its tie-break path."""
    txns = []
    for tid in range(n_txns):
        node = int(rng.integers(3))
        # small seq range => frequent same-(epoch,seq,node) collisions
        seq = int(rng.integers(8 if collisions else 10_000))
        writes = [
            (f"k{int(rng.integers(n_keys))}", bytes([tid % 256]))
            for _ in range(int(rng.integers(4)))
        ]
        reads = [
            (f"k{int(rng.integers(n_keys))}",
             Version(int(rng.integers(2)), int(rng.integers(8)), node))
            for _ in range(int(rng.integers(4)))
        ]
        txns.append(
            _txn(tid, node, seq, writes, reads=reads, epoch=1)
        )
    return txns


def test_numpy_validation_matches_python_reference():
    """Satellite pin: the vectorized validate_epoch_detailed path returns an
    identical ValidationResult to the reference loop — same committed set
    and same per-rule breakdown — across random contended epochs with
    forced version collisions, both with and without a snapshot."""
    rng = np.random.default_rng(11)
    snap = DeltaCRDTStore()
    for j in range(12):
        snap.apply(_u(f"k{j}", b"s", 1, int(rng.integers(8)), node=int(rng.integers(3))))
    for trial in range(25):
        txns = _random_epoch(rng, collisions=bool(trial % 2))
        for snapshot in (None, snap):
            py = validate_epoch_detailed(txns, snapshot, mode="python")
            vec = validate_epoch_detailed(txns, snapshot, mode="numpy")
            assert py == vec
            # and the result is order-independent under shuffling
            perm = list(txns)
            rng.shuffle(perm)
            assert validate_epoch_detailed(perm, snapshot, mode="numpy") == py


def test_validation_mode_dispatch():
    """mode=None dispatches on epoch size; unknown modes are rejected."""
    txns = [_txn(i, i % 2, i, [("k", b"x")]) for i in range(4)]
    assert validate_epoch_detailed(txns) == validate_epoch_detailed(
        txns, mode="python"
    )
    with pytest.raises(ValueError, match="unknown validation mode"):
        validate_epoch_detailed(txns, mode="eager")
    # empty read/write sets must not trip the vectorized path
    empty = [_txn(7, 0, 1, [])]
    res = validate_epoch_detailed(empty, DeltaCRDTStore(), mode="numpy")
    assert res.committed == {7} and not res.aborted
