"""Event-driven transmission engine: barrier-exactness, DAG pipelining gains,
and the pipelined replication engine's consistency guarantees."""

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    WANSimulator,
    YCSBConfig,
    YCSBGenerator,
    all_to_all_schedule,
    aws_latency_matrix,
    geo_clustered_matrix,
    hierarchical_schedule,
    jitter_trace,
)
from repro.core.planner import kcenter_grouping
from repro.core.schedule import Transfer, TransmissionSchedule


def _old_phase_sum(sim: WANSimulator, sched) -> float:
    """The pre-refactor simulator loop, reimplemented verbatim: per phase,
    phase-static degrees, makespan = sum of phase maxima."""
    total = 0.0
    for phase in sched.phases:
        if not phase:
            continue
        n = sim.n
        out_deg = np.zeros(n, dtype=int)
        in_deg = np.zeros(n, dtype=int)
        for t in phase:
            out_deg[t.src] += 1
            if t.via < 0:
                in_deg[t.dst] += 1
            else:
                in_deg[t.via] += 1
                out_deg[t.via] += 1
                in_deg[t.dst] += 1
        total += max(sim.transfer_time_ms(t, out_deg, in_deg) for t in phase)
    return total


def test_barrier_mode_reproduces_phase_sum_exactly():
    """Acceptance: WANSimulator(barrier=True) == the pre-refactor numbers."""
    for seed in range(4):
        lat, _ = geo_clustered_matrix(
            GeoClusterSpec(n_nodes=9, n_clusters=3), np.random.default_rng(seed)
        )
        plan = kcenter_grouping(lat, 3)
        sim = WANSimulator(lat, 300.0, barrier=True)
        for sched in (
            all_to_all_schedule(9, 250_000.0),
            hierarchical_schedule(plan, 250_000.0, lat=lat, tiv=True),
        ):
            assert sim.run(sched).makespan_ms == _old_phase_sum(sim, sched)


def test_event_equals_barrier_on_single_transfer_chain():
    lat = aws_latency_matrix()
    sim = WANSimulator(lat, 100.0)
    chain = TransmissionSchedule(
        [[Transfer(0, 3, 1e6)], [Transfer(3, 7, 5e5)], [Transfer(7, 1, 2e5)]]
    )
    ev = sim.run(chain)
    ba = sim.run(chain, barrier=True)
    assert ev.makespan_ms == pytest.approx(ba.makespan_ms)
    assert ev.critical_path == [0, 1, 2]


def test_event_strictly_faster_on_trace_topologies():
    """Acceptance: strictly lower makespan for hier/geococo on >=2 trace
    topologies (AWS 10-region + a geo-clustered deployment)."""
    geo_lat, _ = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=12, n_clusters=3), np.random.default_rng(3)
    )
    for base in (aws_latency_matrix(), geo_lat):
        n = base.shape[0]
        plan = kcenter_grouping(base, 3)
        for lat in jitter_trace(base, 5, np.random.default_rng(1)):
            sim = WANSimulator(lat, 500.0)
            for keep in (1.0, 0.4):  # hier (dense) and geococo (filtered)
                gp = np.array([len(g) * 250_000.0 * keep for g in plan.groups])
                sched = hierarchical_schedule(
                    plan, 250_000.0, group_payload_bytes=gp, lat=lat,
                    tiv=(keep < 1.0),
                )
                ev = sim.run(sched).makespan_ms
                ba = sim.run(sched, barrier=True).makespan_ms
                assert ev < ba  # strict: stages genuinely overlap


def test_compute_stage_overlaps_other_groups_wan():
    """A group's filter CPU (compute_ms on its exchanges) hides behind other
    groups' in-flight transfers instead of extending the round serially."""
    lat = aws_latency_matrix()
    plan = kcenter_grouping(lat, 3)
    sim = WANSimulator(lat, 500.0)
    dense = hierarchical_schedule(plan, 250_000.0)
    cpu = np.full(plan.k, 10.0)
    piped = hierarchical_schedule(plan, 250_000.0, group_compute_ms=cpu)
    m0 = sim.run(dense).makespan_ms
    m1 = sim.run(piped).makespan_ms
    assert m0 <= m1 <= m0 + float(cpu.sum())
    # barrier view ignores compute stages entirely (pre-refactor numbers)
    assert sim.run(piped, barrier=True).makespan_ms == pytest.approx(
        sim.run(dense, barrier=True).makespan_ms
    )


def test_critical_path_trace_is_a_dependency_chain():
    lat = aws_latency_matrix()
    plan = kcenter_grouping(lat, 3)
    sched = hierarchical_schedule(plan, 250_000.0, lat=lat, tiv=True)
    res = WANSimulator(lat, 500.0).run(sched)
    cp = res.critical_path
    assert cp and res.finish_ms[cp[-1]] == pytest.approx(res.makespan_ms)
    for a, b in zip(cp, cp[1:]):
        assert a in sched.transfers[b].deps
    # the path crosses stages: a scatter is always the sink of a hier round
    assert sched.transfers[cp[-1]].tag == "scatter"


def _adversarial_case():
    """The concrete adversarial input from the PR-3 follow-up (found by the
    test_property_dag brute force): a random symmetric WAN with a severely
    bandwidth-starved access link, where the greedy ASAP event engine lets a
    fast group's exchange steal NIC bandwidth from the other group's
    still-running gathers and LOSES to the barrier phase-sum."""
    rng = np.random.default_rng(0)
    a = rng.uniform(1.0, 200.0, size=(5, 5))
    lat = (a + a.T) / 2.0
    np.fill_diagonal(lat, 0.0)
    plan = kcenter_grouping(lat, 2)
    gp = np.array([len(g) * 250_000.0 * 0.4 for g in plan.groups])
    sched = hierarchical_schedule(
        plan, 250_000.0, group_payload_bytes=gp, lat=lat, tiv=True
    )
    return lat, sched


def test_greedy_event_engine_loses_on_adversarial_matrix():
    """Regression pin for the pre-fix unsoundness: without bandwidth
    admission, event > barrier on the adversarial matrix (if this starts
    failing, the greedy engine quietly changed and the admission fix may no
    longer be load-bearing — re-establish the adversarial input)."""
    lat, sched = _adversarial_case()
    for bw in (4.0, 6.0, 10.0):
        greedy = WANSimulator(lat, bw, admission=False).run(sched).makespan_ms
        barrier = WANSimulator(lat, bw).run(sched, barrier=True).makespan_ms
        assert greedy > barrier + 1e-6


def test_admission_restores_event_le_barrier_on_adversarial_matrix():
    """The bugfix: with bandwidth admission (the default), a later-phase
    exchange defers while its dst NIC is saturated by earlier-phase gathers,
    and event <= barrier holds on the exact matrix where greedy loses."""
    lat, sched = _adversarial_case()
    for bw in (4.0, 6.0, 10.0):
        sim = WANSimulator(lat, bw)
        ev = sim.run(sched).makespan_ms
        ba = sim.run(sched, barrier=True).makespan_ms
        assert ev <= ba + 1e-6


def test_admission_preserves_timeline_and_accounting():
    """Admission only defers starts: dependency ordering, the critical-path
    chain and the engine-independent byte accounting all survive."""
    lat, sched = _adversarial_case()
    sim = WANSimulator(lat, 6.0)
    res = sim.run(sched)
    ba = sim.run(sched, barrier=True)
    for i, t in enumerate(sched.transfers):
        for d in t.deps:
            assert res.start_ms[i] >= res.finish_ms[d] - 1e-9
    cp = res.critical_path
    assert cp and res.finish_ms[cp[-1]] == pytest.approx(res.makespan_ms)
    np.testing.assert_allclose(res.bytes_out, ba.bytes_out)
    np.testing.assert_array_equal(res.msg_matrix, ba.msg_matrix)


# ---------------------------------------------------------------------------
# pipelined replication engine
# ---------------------------------------------------------------------------


def _run_engine(barrier: bool, *, n=5, epochs=10, seed=7):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=2), np.random.default_rng(1)
    )
    trace = jitter_trace(lat, epochs, np.random.default_rng(2))
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    bw = np.where(wan, 200.0, 10_000.0)
    np.fill_diagonal(bw, np.inf)
    cfg = EngineConfig(n_nodes=n, barrier=barrier, grouping=True,
                       filtering=True, tiv=True, planner="kcenter")
    eng = GeoCluster(cfg, bandwidth_mbps=bw, wan_mask=wan, seed=seed)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=400, theta=0.9, read_ratio=0.3, hot_write_frac=0.3,
                   hot_locality=True),
        n, seed=3, node_region=regions,
    )
    return eng.run(gen, trace, txns_per_node=8, n_epochs=epochs)


def test_pipelined_engine_commits_byte_identical_state():
    """Acceptance: the pipelined engine's digests match the barrier engine —
    epoch commit waits for the full DAG to sink, so *when* bytes move never
    changes *which* bytes commit."""
    ev = _run_engine(barrier=False)
    ba = _run_engine(barrier=True)
    assert ev.state_digest == ba.state_digest
    assert ev.value_digest == ba.value_digest
    assert ev.committed == ba.committed
    # byte/message accounting matches too: both engines rank plans by the
    # makespan they execute, and on this fixed workload they agree on the
    # grouping, so the wire traffic is identical transfer-for-transfer
    assert ev.wan_bytes == pytest.approx(ba.wan_bytes)
    np.testing.assert_array_equal(ev.msg_matrix, ba.msg_matrix)


def test_epoch_stats_split_critical_vs_overlapped():
    ev = _run_engine(barrier=False)
    ba = _run_engine(barrier=True)
    for e in ev.epochs + ba.epochs:  # the identity holds in both engines
        assert e.sync_overlap_ms >= 0.0
        # exact (unclamped) identity: with bandwidth admission the event
        # makespan never exceeds barrier + modeled CPU, so the overlap is
        # non-negative by theorem, not by clipping
        assert e.sync_serial_ms == pytest.approx(
            e.sync_ms + e.sync_overlap_ms, abs=1e-9
        )
        # the honest split: filter-CPU hidden behind other groups' WAN vs
        # pure cross-stage WAN overlap — compute-dominated rounds no longer
        # report CPU savings as makespan slack
        assert e.sync_overlap_ms == pytest.approx(
            e.sync_cpu_hidden_ms + e.sync_wan_overlap_ms, abs=1e-9
        )
        assert e.sync_cpu_hidden_ms >= 0.0
    # the pipelined engine demonstrably hid work: its critical path beats
    # its own serialized reference (barrier phase-sum + back-to-back CPU).
    # Not compared against ba.makespans_ms directly — measured filter CPU
    # rides only the event engine's sync_ms, so load spikes during the
    # timing would make a cross-engine mean comparison flaky; the
    # serialized reference carries the same measured CPU on both sides.
    serial = np.array([e.sync_serial_ms for e in ev.epochs])
    assert ev.makespans_ms.mean() < serial.mean()
    assert ev.overlap_ms > 0.0
    # barrier engine reports no overlap by definition
    assert ba.overlap_ms == 0.0


def test_barrier_flag_roundtrips_through_named_strategy():
    cfg = EngineConfig(n_nodes=4, sync_strategy="geococo", barrier=True)
    assert cfg.barrier and cfg.grouping and cfg.filtering
