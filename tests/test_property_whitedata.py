"""Hypothesis property tests: the white-data filter is task-preserving.

The paper's central filtering claim (Sec 4.3): removing white data changes
no receiver-visible state.  We verify over random transaction batches that
merging the filtered batch produces the same value state as merging the raw
batch (given global validation semantics), plus soundness of intra-group
abort detection and the round-trip byte accounting.
"""

import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.crdt import DeltaCRDTStore, Update, Version
from repro.core.occ import Txn, txn_updates, validate_epoch
from repro.core.whitedata import filter_group_batch

# small alphabets on purpose: collisions generate conflicts, dups and nulls
_keys = st.sampled_from([f"k{i}" for i in range(8)])
_vals = st.sampled_from([bytes([i]) for i in range(4)])


@st.composite
def txn_batches(draw):
    n_txns = draw(st.integers(1, 20))
    txns = []
    for tid in range(n_txns):
        n_writes = draw(st.integers(1, 4))
        writes = {}
        for _ in range(n_writes):
            writes[draw(_keys)] = draw(_vals)
        txns.append(
            Txn(
                txn_id=tid,
                node=draw(st.integers(0, 3)),
                epoch=1,
                seq=draw(st.integers(0, 50)),
                write_set=tuple(writes.items()),
            )
        )
    return txns


@st.composite
def snapshots(draw):
    snap = DeltaCRDTStore()
    for i in range(draw(st.integers(0, 8))):
        snap.apply(Update(draw(_keys), draw(_vals), Version(0, i, 0)))
    return snap


@given(snapshots(), txn_batches())
@settings(max_examples=200, deadline=None)
def test_filter_value_lossless(snap, txns):
    fr = filter_group_batch(txns, snap)
    # raw pipeline: drop globally-aborted txns, merge the rest
    _, aborted = validate_epoch(txns, snap)
    raw = snap.snapshot()
    raw.apply_many(
        u for t in txns if t.txn_id not in aborted for u in txn_updates(t)
    )
    # filtered pipeline: merge the kept updates only
    filt = snap.snapshot()
    filt.apply_many(fr.kept)
    assert raw.value_state() == filt.value_state()


@given(snapshots(), txn_batches())
@settings(max_examples=200, deadline=None)
def test_intra_group_abort_subset_of_global(snap, txns):
    """Group-local aborts (any subset) are sound w.r.t. global validation."""
    fr = filter_group_batch(txns[: len(txns) // 2], snap)
    _, aborted_global = validate_epoch(txns, snap)
    assert fr.aborted_txns <= aborted_global


@given(snapshots(), txn_batches())
@settings(max_examples=200, deadline=None)
def test_byte_accounting_consistent(snap, txns):
    fr = filter_group_batch(txns, snap)
    st_ = fr.stats
    assert st_.kept_bytes <= st_.total_bytes
    assert st_.kept_updates <= st_.total_updates
    # wire bytes for kept updates never exceed their full size (null-effect
    # entries travel as metadata only)
    assert st_.kept_bytes <= sum(u.nbytes for u in fr.kept)
    dropped_updates = (
        st_.aborted_updates + st_.duplicate_updates + st_.stale_updates
    )
    assert st_.total_updates == st_.kept_updates + dropped_updates
    assert 0.0 <= st_.white_byte_ratio <= 1.0
    assert st_.wire_bytes <= st_.total_bytes + 24 * st_.total_updates


@given(snapshots(), txn_batches())
@settings(max_examples=100, deadline=None)
def test_filter_idempotent(snap, txns):
    """Filtering an already-filtered batch keeps it fixed (no over-pruning).

    Reconstructs txns from kept updates; aborted set must be empty the
    second time and kept content unchanged.
    """
    fr1 = filter_group_batch(txns, snap)
    survivors = [t for t in txns if t.txn_id not in fr1.aborted_txns]
    fr2 = filter_group_batch(survivors, snap)
    assert fr2.aborted_txns == set()
    kept1 = {(u.key, u.value, u.version) for u in fr1.kept}
    kept2 = {(u.key, u.value, u.version) for u in fr2.kept}
    assert kept1 == kept2
