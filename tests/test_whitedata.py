import numpy as np
import pytest

from repro.core.crdt import DeltaCRDTStore, Update, Version
from repro.core.occ import Txn, txn_updates
from repro.core.whitedata import filter_group_batch


def _txn(tid, node, seq, writes, epoch=1):
    return Txn(txn_id=tid, node=node, epoch=epoch, seq=seq,
               write_set=tuple(writes))


def _merged_value_state(snapshot, txns, kept_updates=None):
    """Merge either the raw batch or the filtered batch into a snapshot copy."""
    s = snapshot.snapshot()
    if kept_updates is None:
        ups = [u for t in txns for u in txn_updates(t)]
    else:
        ups = kept_updates
    s.apply_many(ups)
    return s.value_state()


def test_aborted_writes_are_filtered():
    snap = DeltaCRDTStore()
    t1 = _txn(1, 0, 1, [("k", b"a")])
    t2 = _txn(2, 1, 2, [("k", b"b"), ("other", b"c")])  # loses k -> all white
    fr = filter_group_batch([t1, t2], snap)
    assert fr.aborted_txns == {2}
    kept_keys = [(u.key, u.value) for u in fr.kept]
    assert ("other", b"c") not in kept_keys
    assert ("k", b"a") in kept_keys
    assert fr.stats.aborted_updates == 2


def test_stale_updates_filtered():
    snap = DeltaCRDTStore()
    snap.apply(Update("k", b"new", Version(5, 0, 0)))
    old = _txn(1, 0, 1, [("k", b"late")], epoch=2)  # epoch 2 < snapshot's 5
    fr = filter_group_batch([old], snap)
    assert fr.stats.stale_updates == 1
    assert fr.kept == []


def test_null_effect_payload_stripped():
    snap = DeltaCRDTStore()
    snap.apply(Update("k", b"same-value", Version(0, 0, 0)))
    t = _txn(1, 0, 1, [("k", b"same-value")], epoch=1)
    fr = filter_group_batch([t], snap)
    assert fr.stats.null_updates == 1
    assert len(fr.kept) == 1
    # semantically the full update is kept (receiver reconstructs it) ...
    assert fr.kept[0].value == b"same-value"
    # ... but only metadata bytes cross the WAN
    assert fr.stats.kept_bytes < sum(u.nbytes for u in txn_updates(t))
    assert fr.stats.kept_bytes == fr.kept[0].meta_only().nbytes


def test_duplicate_content_collapsed():
    snap = DeltaCRDTStore()
    # same (key, value) delivered twice (e.g. failover retransmission),
    # non-conflicting because it's the same logical txn replayed with a
    # fresh txn wrapper writing a *different* key each plus a shared key
    u_same = ("shared", b"payload")
    t1 = _txn(1, 0, 1, [u_same])
    t1_retx = _txn(1, 0, 1, [u_same])  # identical replay
    fr = filter_group_batch([t1, t1_retx], snap)
    # one of the copies is white (duplicate or conflict-free dedup)
    total_kept = [(u.key, u.value) for u in fr.kept]
    assert total_kept.count(u_same) == 1


def test_filtering_is_value_lossless():
    """Merging the filtered batch == merging the raw batch (value state)."""
    rng = np.random.default_rng(0)
    snap = DeltaCRDTStore()
    for i in range(20):
        snap.apply(Update(f"k{i}", bytes([i]), Version(0, i, 0)))
    txns = []
    for tid in range(40):
        writes = {}
        for _ in range(3):
            k = int(rng.integers(0, 30))
            val = bytes([int(rng.integers(0, 5))])  # small alphabet -> nulls/dups
            writes[f"k{k}"] = val
        txns.append(_txn(tid, int(rng.integers(0, 4)),
                         int(rng.integers(0, 1000)), list(writes.items())))
    fr = filter_group_batch(txns, snap)
    # raw merge must exclude aborted txns (they abort globally too)
    surviving = [t for t in txns if t.txn_id not in fr.aborted_txns]
    raw = _merged_value_state(snap, surviving)
    filt = _merged_value_state(snap, [], kept_updates=fr.kept)
    assert raw == filt


def test_filter_rules_toggle():
    snap = DeltaCRDTStore()
    snap.apply(Update("k", b"v", Version(0, 0, 0)))
    t_null = _txn(1, 0, 1, [("k", b"v")], epoch=1)
    fr_off = filter_group_batch([t_null], snap, enable_null=False)
    assert fr_off.stats.null_updates == 0
    assert fr_off.kept[0].value == b"v"
    fr_on = filter_group_batch([t_null], snap, enable_null=True)
    assert fr_on.stats.null_updates == 1


def test_wire_bytes_includes_tombstones():
    snap = DeltaCRDTStore()
    t1 = _txn(1, 0, 1, [("k", b"a" * 100)])
    t2 = _txn(2, 1, 2, [("k", b"b" * 100)])
    fr = filter_group_batch([t1, t2], snap)
    # loser's payload dropped but 24-byte tombstone still crosses the WAN
    assert fr.stats.wire_bytes == fr.stats.kept_bytes + 24
    assert fr.stats.wire_bytes < fr.stats.total_bytes
