import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    RaftCluster,
    YCSBConfig,
    YCSBGenerator,
    TPCCConfig,
    TPCCGenerator,
    geo_clustered_matrix,
    jitter_trace,
)


def _trace(n, rounds=15, seed=1):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=max(2, n // 3)),
        np.random.default_rng(seed),
    )
    return jitter_trace(lat, rounds, np.random.default_rng(seed + 1)), regions


def _lan_wan(regions, n, wan):
    if not np.isfinite(wan):
        return np.inf
    same = np.asarray(regions)[:, None] == np.asarray(regions)[None, :]
    bw = np.where(same, 10_000.0, float(wan))
    np.fill_diagonal(bw, np.inf)
    return bw


def _run(n, grouping, filtering, *, gen_seed=3, theta=0.9, hot=0.3,
         rewrite=0.1, bw=200.0, epochs=12, n_keys=400):
    cfg = EngineConfig(
        n_nodes=n, grouping=grouping, filtering=filtering, tiv=True,
        planner="kcenter",
    )
    trace, regions = _trace(n, epochs)
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    eng = GeoCluster(cfg, bandwidth_mbps=_lan_wan(regions, n, bw),
                     wan_mask=wan, seed=7)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=n_keys, theta=theta, read_ratio=0.3,
                   hot_write_frac=hot, rewrite_frac=rewrite,
                   hot_locality=True),
        n, seed=gen_seed, node_region=regions,
    )
    return eng.run(gen, trace, txns_per_node=8, n_epochs=epochs)


def test_end_to_end_state_identical_across_modes():
    """The headline consistency claim: grouping+filtering never change the
    replicated final state or the set of committed transactions."""
    base = _run(5, grouping=False, filtering=False)
    grp = _run(5, grouping=True, filtering=False)
    geo = _run(5, grouping=True, filtering=True)
    assert base.committed == grp.committed == geo.committed
    assert base.state_digest == grp.state_digest == geo.state_digest


def test_filtering_reduces_wan_bytes():
    grp = _run(5, grouping=True, filtering=False)
    geo = _run(5, grouping=True, filtering=True)
    assert geo.wan_bytes < grp.wan_bytes
    assert geo.white_stats.white_byte_ratio > 0.1


def test_grouping_improves_sync_makespan():
    base = _run(6, grouping=False, filtering=False, bw=np.inf)
    geo = _run(6, grouping=True, filtering=True, bw=np.inf)
    assert geo.makespans_ms.mean() < base.makespans_ms.mean()


def test_throughput_improves_under_wan_bottleneck():
    base = _run(5, grouping=False, filtering=False, bw=100.0)
    geo = _run(5, grouping=True, filtering=True, bw=100.0)
    assert geo.throughput_tps > base.throughput_tps


def test_conflict_free_workload_filter_noop():
    """Paper Table 1 row 1: at 0% conflicts filtering saves ~0% and costs ~0."""
    base = _run(4, True, False, theta=0.01, hot=0.0, rewrite=0.0, n_keys=100_000)
    geo = _run(4, True, True, theta=0.01, hot=0.0, rewrite=0.0, n_keys=100_000)
    # white ratio should be tiny (only rare random collisions)
    assert geo.white_stats.white_byte_ratio < 0.05
    assert geo.wan_bytes <= base.wan_bytes * 1.02


def test_compression_stacks_with_filtering():
    cfg_kw = dict(n_nodes=5, grouping=True, filtering=True, tiv=True,
                  planner="kcenter")
    gen_kw = dict(n_keys=400, theta=0.8, read_ratio=0.3, hot_write_frac=0.2,
                  hot_locality=True)
    tr, regions = _trace(5, 10)
    runs = {}
    for comp in (False, True):
        wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
        eng = GeoCluster(EngineConfig(compression=comp, **cfg_kw),
                         bandwidth_mbps=_lan_wan(regions, 5, 100.0),
                         wan_mask=wan, seed=7)
        gen = YCSBGenerator(YCSBConfig(**gen_kw), 5, seed=3,
                            node_region=regions)
        runs[comp] = eng.run(gen, tr, txns_per_node=8, n_epochs=10)
    assert runs[True].wan_bytes < runs[False].wan_bytes
    assert runs[True].state_digest == runs[False].state_digest


def test_tpcc_generator_and_engine():
    n = 4
    cfg = EngineConfig(n_nodes=n, grouping=True, filtering=True,
                       planner="kcenter")
    tr, regions = _trace(n, 8)
    eng = GeoCluster(cfg, bandwidth_mbps=_lan_wan(regions, n, 300.0), seed=5)
    gen = TPCCGenerator(TPCCConfig(n_warehouses=20, mix="TPCC-A"), n, seed=2)
    rs = eng.run(gen, tr, txns_per_node=6, n_epochs=8)
    assert rs.committed > 0
    # neworder_ids is the latest epoch's annotation set (bounded memory);
    # the cumulative counter covers the whole run
    assert gen.neworder_count >= len(gen.neworder_ids) > 0
    # tpmC accounting possible: committed NewOrders <= all NewOrders
    assert rs.committed <= rs.total_txns


def test_tpcc_mixes_have_distinct_write_ratios():
    n = 3
    byte_totals = {}
    for mix in ("TPCC-A", "TPCC-B"):
        gen = TPCCGenerator(TPCCConfig(n_warehouses=12, mix=mix), n, seed=2)
        txns = gen.epoch_txns(0, 50)
        writes = sum(
            len(t.write_set) for ts in txns.values() for t in ts
        )
        byte_totals[mix] = writes
    assert byte_totals["TPCC-A"] > 2 * byte_totals["TPCC-B"]


def test_raft_cluster_grouping_faster():
    n = 9
    tr, _ = _trace(n, 6, seed=11)
    flat = RaftCluster(n, grouping=False, tiv=False)
    geo = RaftCluster(n, grouping=True, tiv=True)
    t_flat = flat.throughput(tr, payload_bytes=16_000.0)
    t_geo = geo.throughput(tr, payload_bytes=16_000.0)
    assert t_geo > t_flat * 0.95  # grouped never catastrophically worse
    lat = tr[0]
    # commit latency with grouping respects quorum semantics (positive, finite)
    cl = geo.commit_latency_ms(lat, 0, 16_000.0)
    assert 0 < cl < 10_000


def test_raft_commit_latency_memoized():
    """Per-txn recomputation with identical (matrix, leader, payload) was
    pure waste: the second lookup must come from the cache and agree."""
    n = 7
    tr, _ = _trace(n, 4, seed=13)
    geo = RaftCluster(n, grouping=True, tiv=True)
    lat = tr[0]
    first = geo.commit_latency_ms(lat, 2, 16_000.0)
    assert geo.commit_cache_hits == 0
    again = geo.commit_latency_ms(lat, 2, 16_000.0)
    assert geo.commit_cache_hits == 1
    assert again == first
    # a different leader or payload is a different cache entry
    geo.commit_latency_ms(lat, 3, 16_000.0)
    geo.commit_latency_ms(lat, 2, 32_000.0)
    assert geo.commit_cache_hits == 1


def test_raft_event_engine_agrees_with_closed_form_contention_free():
    """On contention-free (infinite-bandwidth) matrices the event-driven
    quorum path degenerates to propagation sums and must agree exactly with
    the closed-form hop model, for both the flat and the grouped relay."""
    n = 9
    for seed in (5, 11, 23):
        tr, _ = _trace(n, 2, seed=seed)
        for grouping, tiv in ((False, False), (True, True), (True, False)):
            rc = RaftCluster(n, grouping=grouping, tiv=tiv)
            for lat in tr:
                for leader in (0, n // 2):
                    ev = rc.commit_latency_ms(lat, leader, 16_000.0)
                    cf = rc._closed_form_commit_latency_ms(lat, leader, 16_000.0)
                    assert ev == pytest.approx(cf, rel=1e-9)


def test_raft_event_engine_charges_nic_contention():
    """Under constrained bandwidth the leader's fan-out serializes on its
    NIC: the event-driven quorum latency must exceed the closed-form model,
    which charges every hop an uncontended wire."""
    n = 9
    tr, _ = _trace(n, 2, seed=11)
    rc = RaftCluster(n, grouping=False, tiv=False, bandwidth_mbps=50.0)
    lat = tr[0]
    ev = rc.commit_latency_ms(lat, 0, 256_000.0)
    cf = rc._closed_form_commit_latency_ms(lat, 0, 256_000.0)
    assert ev > cf


def _linear_model_throughput(n, tr, *, grouping, tiv, bandwidth_mbps=np.inf,
                             payload_bytes=64_000.0, batches_in_flight=8,
                             ops_per_batch=100, seed=0):
    """The pre-fix throughput model: ops * batches / mean single-batch
    commit — linear in batches_in_flight, blind to the leader's NIC.
    Reconstructed here (same leader draws) as the regression reference."""
    rc = RaftCluster(n, grouping=grouping, tiv=tiv,
                     bandwidth_mbps=bandwidth_mbps, seed=seed)
    lats = []
    for lat in tr:
        leader = int(rc.rng.integers(0, n))
        lats.append(rc.commit_latency_ms(lat, leader, payload_bytes))
    return ops_per_batch * batches_in_flight / (float(np.mean(lats)) / 1e3)


def test_raft_throughput_not_linear_in_batches_under_bandwidth():
    """Pinned regression: on a bandwidth-constrained matrix the old linear
    model overstates ops/s — the stitched leader-schedule stream charges
    the leader's NIC for every in-flight batch."""
    n = 9
    tr, _ = _trace(n, 4, seed=11)
    kw = dict(payload_bytes=256_000.0, batches_in_flight=8)
    rc = RaftCluster(n, grouping=False, tiv=False, bandwidth_mbps=50.0)
    measured = rc.throughput(tr, **kw)
    linear = _linear_model_throughput(n, tr, grouping=False, tiv=False,
                                      bandwidth_mbps=50.0, **kw)
    assert measured < linear * 0.9
    # more batches in flight can never *reduce* modeled ops/s (the stream
    # only appends work), but gains saturate at the NIC ceiling
    rc2 = RaftCluster(n, grouping=False, tiv=False, bandwidth_mbps=50.0)
    single = rc2.throughput(tr, payload_bytes=256_000.0, batches_in_flight=1)
    assert single <= measured * (1.0 + 1e-9)
    assert measured < single * 8


def test_raft_throughput_exact_at_one_batch():
    """batches_in_flight=1 reduces exactly to the single-batch commit model
    (same leader draws, same memoized event-engine path)."""
    n = 9
    tr, _ = _trace(n, 4, seed=13)
    for grouping, tiv, bw in ((False, False, 50.0), (True, True, np.inf)):
        rc = RaftCluster(n, grouping=grouping, tiv=tiv, bandwidth_mbps=bw)
        measured = rc.throughput(tr, payload_bytes=64_000.0,
                                 batches_in_flight=1)
        linear = _linear_model_throughput(
            n, tr, grouping=grouping, tiv=tiv, bandwidth_mbps=bw,
            payload_bytes=64_000.0, batches_in_flight=1)
        assert measured == pytest.approx(linear, rel=1e-12)


def test_raft_throughput_exact_on_contention_free_matrices():
    """On infinite-bandwidth matrices every batch streams at propagation
    speed: the last in-flight batch commits exactly when a single batch
    would, so the stitched stream agrees with the linear model exactly —
    the fix only bites where there is contention to model."""
    n = 9
    tr, _ = _trace(n, 3, seed=17)
    for grouping, tiv in ((False, False), (True, True)):
        rc = RaftCluster(n, grouping=grouping, tiv=tiv)
        measured = rc.throughput(tr, payload_bytes=256_000.0,
                                 batches_in_flight=8)
        linear = _linear_model_throughput(
            n, tr, grouping=grouping, tiv=tiv,
            payload_bytes=256_000.0, batches_in_flight=8)
        assert measured == pytest.approx(linear, rel=1e-9)


def test_planner_damping_limits_replans():
    rs = _run(6, grouping=True, filtering=True, epochs=12)
    # with mild jitter the damped replanner should not replan every epoch;
    # plans come from the kcenter search or the adaptive flat fallback
    methods = {e.plan_method for e in rs.epochs}
    assert methods <= {"kcenter", "kcenter+tiv", "none"}


def test_raft_pipelined_incremental_matches_resim_oracle():
    """pipelined_commit_ms now appends batches onto a StreamingTimeline;
    the result must equal the O(batches²) stitch-and-rerun oracle exactly
    (same floats, not approximately) across grouping modes, bandwidth
    regimes, leaders and pipeline depths."""
    n = 7
    tr, _ = _trace(n, 2, seed=11)
    lat = tr[0]
    for grouping in (False, True):
        for bw in (np.inf, 60.0):
            rc = RaftCluster(n, grouping=grouping, tiv=grouping,
                             bandwidth_mbps=bw)
            for batches in (2, 4, 9):
                for leader in (0, n // 2):
                    inc = rc.pipelined_commit_ms(lat, leader, 64_000.0,
                                                 batches)
                    ref = rc._pipelined_commit_ms_resim(lat, leader,
                                                        64_000.0, batches)
                    assert inc == ref
