"""Hypothesis property tests for the staleness-aware OCC model.

Two load-bearing invariants of the staleness_feedback work:

* **Engine independence of commit content** (default off): for any small
  workload/topology, `state_digest`/`value_digest` are byte-identical
  across the barrier, event and streaming engines — the engines change
  when bytes move, never which bytes commit.
* **Staleness monotonicity**: for the *same* transaction stream, versioning
  reads against older snapshots only ever *adds* aborts — the abort set
  under stale views is a superset of the abort set under fresh views, and
  the write-write abort set is unchanged.  This holds because the winner
  map includes read-aborted writers (no reinstatement; pinned in
  ``tests/test_crdt_occ.py``).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency; see requirements-dev.txt")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    DeltaCRDTStore,
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    Update,
    Version,
    YCSBConfig,
    YCSBGenerator,
    geo_clustered_matrix,
    jitter_trace,
)
from repro.core.occ import Txn, validate_epoch_detailed


# ---------------------------------------------------------------------------
# staleness monotonicity
# ---------------------------------------------------------------------------


@st.composite
def epoch_with_stale_variant(draw):
    """A snapshot, a txn stream with fresh read versions, and the same
    stream with a random subset of reads re-versioned strictly older."""
    n_keys = draw(st.integers(2, 8))
    keys = [f"k{i}" for i in range(n_keys)]
    snap = DeltaCRDTStore()
    for i, k in enumerate(keys):
        if draw(st.booleans()):
            snap.apply(Update(k, b"v", Version(0, draw(st.integers(1, 50)), i % 3)))
    n_txns = draw(st.integers(1, 12))
    fresh: list[Txn] = []
    stale: list[Txn] = []
    seq = 0
    for tid in range(n_txns):
        node = draw(st.integers(0, 2))
        writes = tuple(
            (k, bytes([tid])) for k in draw(
                st.lists(st.sampled_from(keys), max_size=3, unique=True)
            )
        )
        reads_f = []
        reads_s = []
        for k in draw(st.lists(st.sampled_from(keys), max_size=3, unique=True)):
            ver = snap.version_of(k)
            reads_f.append((k, ver))
            if draw(st.booleans()) and ver > Version.ZERO:
                # strictly older view of this key
                older = draw(st.sampled_from(
                    [Version.ZERO, Version(ver.epoch, ver.seq - 1, ver.node)]
                ))
                reads_s.append((k, older))
            else:
                reads_s.append((k, ver))
        seq += 1
        base = dict(txn_id=tid, node=node, epoch=1, seq=seq)
        fresh.append(Txn(**base, read_set=tuple(reads_f), write_set=writes))
        stale.append(Txn(**base, read_set=tuple(reads_s), write_set=writes))
    return snap, fresh, stale


@given(epoch_with_stale_variant())
@settings(max_examples=200, deadline=None)
def test_stale_views_only_add_aborts(case):
    snap, fresh, stale = case
    rf = validate_epoch_detailed(fresh, snap)
    rs = validate_epoch_detailed(stale, snap)
    # fresh reads (versioned at the validation snapshot) never read-abort
    assert rf.read_aborted == frozenset()
    # write-write outcome is a function of write sets alone: unchanged
    assert rs.ww_aborted == rf.ww_aborted
    # staleness is monotone: aborts only ever accrue
    assert rf.aborted <= rs.aborted
    assert rs.committed <= rf.committed


# ---------------------------------------------------------------------------
# three-engine digest identity (default staleness_feedback=False)
# ---------------------------------------------------------------------------


def _engine_run(*, barrier, streaming, n, epochs, bw, theta, seed):
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=2), np.random.default_rng(seed)
    )
    trace = jitter_trace(lat, epochs, np.random.default_rng(seed + 1))
    wan = np.asarray(regions)[:, None] != np.asarray(regions)[None, :]
    bwm = np.where(wan, bw, 10_000.0)
    np.fill_diagonal(bwm, np.inf)
    cfg = EngineConfig(n_nodes=n, barrier=barrier, streaming=streaming,
                       grouping=True, filtering=True, tiv=True,
                       planner="kcenter", epoch_ms=2.0)
    eng = GeoCluster(cfg, bandwidth_mbps=bwm, wan_mask=wan, seed=11)
    gen = YCSBGenerator(
        YCSBConfig(n_keys=60, theta=theta, read_ratio=0.4,
                   hot_write_frac=0.3, hot_locality=True),
        n, seed=seed + 2, node_region=regions,
    )
    return eng.run(gen, trace, txns_per_node=4, n_epochs=epochs)


@given(
    n=st.integers(3, 6),
    epochs=st.integers(2, 4),
    bw=st.sampled_from([np.inf, 200.0, 20.0]),
    theta=st.sampled_from([0.3, 0.9]),
    seed=st.integers(0, 50),
)
@settings(max_examples=10, deadline=None)
def test_engines_commit_identical_state_by_default(n, epochs, bw, theta, seed):
    kw = dict(n=n, epochs=epochs, bw=bw, theta=theta, seed=seed)
    ba = _engine_run(barrier=True, streaming=False, **kw)
    ev = _engine_run(barrier=False, streaming=False, **kw)
    stm = _engine_run(barrier=False, streaming=True, **kw)
    assert ba.state_digest == ev.state_digest == stm.state_digest
    assert ba.value_digest == ev.value_digest == stm.value_digest
    assert ba.committed == ev.committed == stm.committed
    # and the read rule stays vacuous: every abort is write-write
    for rs in (ba, ev, stm):
        assert rs.read_aborts == 0


# ---------------------------------------------------------------------------
# vectorized / reference validation equivalence
# ---------------------------------------------------------------------------


@given(epoch_with_stale_variant())
@settings(max_examples=200, deadline=None)
def test_numpy_validation_equals_python(case):
    """The vectorized fast path is extensionally identical to the reference
    loop: same ValidationResult (committed + per-rule breakdown) on every
    input, with and without a snapshot, in either mode of staleness."""
    snap, fresh, stale = case
    for txns in (fresh, stale):
        for snapshot in (None, snap):
            py = validate_epoch_detailed(txns, snapshot, mode="python")
            vec = validate_epoch_detailed(txns, snapshot, mode="numpy")
            assert py == vec
