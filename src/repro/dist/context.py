"""Distribution context: mesh-aware sharding decisions inside model code.

``build_train_step``/``build_serve_step`` enter :func:`distribution` around
the model forward so layers (attention head pinning, MoE expert
parallelism) can consult the active mesh without threading it through every
call.  :func:`current` returns ``None`` outside any distributed region, in
which case layers fall back to their single-device paths.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat

__all__ = ["DistContext", "distribution", "current"]


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh

    @property
    def pod_size(self) -> int:
        return self.mesh.shape.get("pod", 1)

    @property
    def data_size(self) -> int:
        return self.mesh.shape.get("data", 1)

    @property
    def model_size(self) -> int:
        return self.mesh.shape.get("model", 1)

    def constrain_heads(self, x: jax.Array) -> jax.Array:
        """Pin the head axis of a (B, S, H, D) tensor to ``model`` when it
        divides — and never let the partitioner split ``head_dim`` (it
        otherwise factors the contraction dim and emits an all-reduce per
        attention chunk pair)."""
        dm = self.model_size
        if dm <= 1 or getattr(x, "ndim", 0) != 4 or x.shape[2] % dm:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(None, None, "model", None))
        )

    @property
    def supports_manual_subregions(self) -> bool:
        """Whether a manual shard_map subregion (e.g. MoE expert-parallel
        dispatch) can be used under this runtime.  Requires either a
        pod-free mesh (full-manual covers all axes) or a runtime with
        working partial-auto shard_map."""
        return compat.has_partial_auto() or self.pod_size <= 1

    def shard_map(self, fn, *, in_specs, out_specs, axis_names):
        """Manual subregion over ``axis_names`` of the context mesh."""
        return compat.shard_map(
            fn, self.mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(axis_names),
        )


_STACK: list[DistContext] = []


@contextlib.contextmanager
def distribution(mesh: Mesh):
    """Activate a distribution context for the enclosed model code."""
    _STACK.append(DistContext(mesh))
    try:
        yield _STACK[-1]
    finally:
        _STACK.pop()


def current() -> DistContext | None:
    return _STACK[-1] if _STACK else None
