"""Parameter partitioning rules per sync strategy.

The strategy surface decides what crosses the pod (WAN-analogue) boundary:

* ``flat`` — parameters fully replicated; every pod would push a complete
  gradient replica across the WAN (the paper's all-to-all baseline).
* ``hier`` / ``geococo`` — FSDP over ``data`` + tensor parallelism over
  ``model`` inside each pod, so only per-device *shards* cross the pod
  boundary (grouping: the pod is the group, the shard-holding device its
  aggregator for that slice).

Rules are shape-driven so they apply to every architecture in the zoo:

* 0-d/1-d leaves (norm scales, biases) stay replicated;
* 2-d+ leaves shard dim 0 over ``data`` and the last dim over ``model``
  when divisible;
* scan-stacked leaves (leading super-block axis from the scan partition,
  path contains ``"scan"``) shift the rule right by one — the block axis is
  never sharded.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "param_shardings"]


def _is_scan_path(path) -> bool:
    return any(getattr(p, "key", None) == "scan" for p in path)


def _leaf_spec(path, leaf, mesh: Mesh, strategy: str) -> P:
    if strategy == "flat":
        return P()
    dd = mesh.shape.get("data", 1)
    dm = mesh.shape.get("model", 1)
    ndim = getattr(leaf, "ndim", 0)
    off = 1 if _is_scan_path(path) else 0
    if ndim - off < 2:
        return P()
    spec = [None] * ndim
    if dd > 1 and leaf.shape[off] % dd == 0:
        spec[off] = "data"
    if dm > 1 and leaf.shape[ndim - 1] % dm == 0:
        spec[ndim - 1] = "model"
    return P(*spec)


def param_specs(params: Any, mesh: Mesh, strategy: str = "hier") -> Any:
    """PartitionSpec pytree for a parameter (or gradient/optimizer) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, l: _leaf_spec(path, l, mesh, strategy), params
    )


def param_shardings(params: Any, mesh: Mesh, strategy: str = "hier") -> Any:
    """NamedSharding pytree matching :func:`param_specs`."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, strategy)
    )
