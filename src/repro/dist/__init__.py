"""``repro.dist`` — the device plane of the two-plane synchronization API.

The WAN-simulation plane (``repro.core``) models write-set synchronization
between geo-distributed database replicas; this package is its JAX device
analogue: the ``pod`` mesh axis is the WAN boundary, gradients are the write
sets, and the same strategy names (``flat`` / ``hier`` / ``geococo``)
resolve through the shared registry in ``repro.core.strategies``.

Modules:

* :mod:`~repro.dist.compat`      — JAX version shim (installed on import)
* :mod:`~repro.dist.collectives` — ``SyncConfig`` + pod-boundary collectives
* :mod:`~repro.dist.context`     — distribution context for model layers
* :mod:`~repro.dist.sharding`    — per-strategy parameter partitioning
"""

from . import compat  # noqa: F401  (installs the modern-API shims)
from .collectives import (
    DeviceSyncStrategy,
    SyncConfig,
    chunked_topk_exchange,
    estimate_sync_bytes,
    relay_psum,
    sync_gradients,
)
from .context import DistContext, current, distribution
from .sharding import param_shardings, param_specs

__all__ = [
    "DeviceSyncStrategy",
    "SyncConfig",
    "chunked_topk_exchange",
    "estimate_sync_bytes",
    "relay_psum",
    "sync_gradients",
    "DistContext",
    "current",
    "distribution",
    "param_shardings",
    "param_specs",
]
