"""JAX version-compatibility layer for the device plane.

The device plane targets the modern single-controller API surface
(``jax.shard_map`` with ``axis_names`` / ``check_vma``, ``jax.make_mesh``
with ``axis_types``).  The container's baked toolchain ships jax 0.4.x,
where:

* ``shard_map`` lives in ``jax.experimental.shard_map`` and spells partial
  automation ``auto=`` / replication checking ``check_rep=``;
* **partial-auto shard_map is unusable on the CPU backend** — mixing a
  manual axis with auto (GSPMD) axes trips XLA CHECK failures
  (``spmd_partitioner.cc IsManualSubgroup`` aborts on ``ppermute``,
  scatters, and ``with_sharding_constraint``) and ``PartitionId`` lowering
  is rejected outright.  Fully-manual shard_map (every mesh axis manual) is
  solid, as is pure GSPMD.

So on old JAX this module lowers every ``shard_map`` request to the
fully-manual form: ``axis_names`` smaller than the mesh means the caller's
body only uses collectives over those axes, and running the body replicated
over the remaining axes is semantically identical (the remaining axes see
replicated in/out specs).  The higher layers are arranged around that
constraint — model compute runs under pure GSPMD, and only the pod-boundary
gradient exchange enters a (fully-manual) shard_map.

On a modern JAX the wrappers delegate to the native API unchanged.
"""

from __future__ import annotations

import enum
from typing import Any

import jax
from jax.sharding import Mesh

__all__ = [
    "HAS_NATIVE_SHARD_MAP",
    "has_partial_auto",
    "shard_map",
    "make_mesh",
    "install",
]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

_PARTIAL_AUTO: bool | None = None


def has_partial_auto() -> bool:
    """Whether partial-auto shard_map (manual pod + GSPMD data/model in one
    region) can be trusted on the active backend.

    Conservative by design: requires the modern native API *and* a
    non-CPU backend — the CPU partitioner is where the CHECK failures
    live, and API presence alone (e.g. latest jax[cpu] in CI) says nothing
    about the backend.  Lazy because ``jax.default_backend()`` initializes
    the runtime.
    """
    global _PARTIAL_AUTO
    if _PARTIAL_AUTO is None:
        _PARTIAL_AUTO = (
            HAS_NATIVE_SHARD_MAP and jax.default_backend() != "cpu"
        )
    return _PARTIAL_AUTO


def shard_map(
    f,
    mesh: Mesh | None = None,
    *,
    in_specs: Any,
    out_specs: Any,
    axis_names: set | frozenset | None = None,
    check_vma: bool = False,
):
    """Version-portable ``shard_map``.

    ``axis_names`` is the set of axes the body treats manually (new-JAX
    meaning).  On old JAX the body is lowered fully manual over *all* mesh
    axes; this is only valid when in/out specs leave the non-manual axes
    replicated — exactly the contract the device plane's callers follow.
    """
    if HAS_NATIVE_SHARD_MAP:
        kwargs: dict[str, Any] = {}
        if axis_names is not None:
            names = set(axis_names)
            if mesh is not None and not has_partial_auto():
                # CPU backend: partial-auto is the unsafe configuration even
                # on modern JAX — widen to fully manual (callers' non-manual
                # axes carry replicated specs, so semantics are unchanged)
                names = set(mesh.axis_names)
            kwargs["axis_names"] = names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on old JAX."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, axis_types=None) -> Mesh:
    """``jax.make_mesh`` that tolerates the ``axis_types`` kwarg on old JAX
    (axis types only exist on the modern explicit-sharding stack)."""
    if axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def install() -> None:
    """Backfill the modern names onto the installed ``jax``.

    Applied at ``repro.dist`` import so test/benchmark code written against
    the modern API (``jax.shard_map``, ``jax.sharding.AxisType``) runs on
    the 0.4.x toolchain unmodified.  No-ops on a modern JAX.
    """
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        # modern API returns the current abstract mesh; old-JAX callers get
        # None ("not inside an explicit/manual mesh region"), which is the
        # truthful answer for the pure-GSPMD + fully-manual layering here
        jax.sharding.get_abstract_mesh = lambda: None


install()
