"""Device-plane synchronization: GeoCoCo's three levers over the mesh
``pod`` axis (the WAN analogue of the training stack).

This is the device-plane half of the two-plane strategy surface (see
``repro.core.strategies``):

* **grouping / hierarchy** (paper Sec 4.2): ``hier`` syncs FSDP-scattered
  gradient shards instead of full replicas, and :func:`relay_psum` expresses
  the aggregator relay ring (TIV-exploiting overlay paths map to the ring
  ``order``);
* **task-preserving filtering** (Sec 4.3): ``geococo`` runs
  :func:`chunked_topk_exchange` — density-based top-k selection with
  error-feedback residuals, the gradient analogue of white-data removal
  (dropped mass is *carried*, not lost, so the training task is preserved);
* **consistency-guaranteed transmission** (Sec 4.4): every strategy is a
  deterministic collective — all pods hold identical synced gradients after
  the exchange, mirroring the epoch-commit guarantee of the WAN plane.

Strategies register under ``("device_sync", name)`` in the shared registry,
so the WAN plane (``EngineConfig``) and the device plane (``SyncConfig``)
resolve the *same names* — ``flat`` / ``hier`` / ``geococo``.

:func:`estimate_sync_bytes` is the analytic wire model the benchmarks
cross-check against the WAN simulator and against bytes actually moved by
:func:`sync_gradients`.

Deployment note: on a single-controller runtime (this container) the
backward pass has already all-reduced gradients over every mesh axis by the
time ``sync_gradients`` runs, so the pod exchange operates on pod-identical
inputs — ``pmean`` is then numerically a no-op while the ``geococo``
sparsification still changes the update exactly as on a real multi-pod
deployment.  On a multi-controller deployment the same collectives perform
the real exchange; the wire model is identical either way.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core import strategies

__all__ = [
    "SyncConfig",
    "DeviceSyncStrategy",
    "sync_gradients",
    "relay_psum",
    "chunked_topk_exchange",
    "estimate_sync_bytes",
]

_INDEX_BYTES = 4  # chunk-local top-k index cost per transmitted value


# ---------------------------------------------------------------------------
# strategy objects + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceSyncStrategy:
    """One named gradient-exchange strategy.

    ``wire_values(n, cfg, shard_factor)`` returns ``(dense_values,
    sparse_values)`` — how many dense values and how many (value, index)
    pairs of an ``n``-element leaf cross the pod boundary per all-reduce;
    the split keeps the analytic estimator and the measured nonzero counts
    comparable.  ``shard_factor`` is how many in-pod devices a leaf is
    split across: the filter's ``min_leaf_size`` / chunking decisions
    happen on the shard each device actually holds.

    ``react(cfg, event)`` declares how the strategy responds to a
    ``repro.control`` :class:`~repro.control.events.NetworkEvent`: it
    returns an updated :class:`SyncConfig` (the trainer then rebuilds its
    step) or ``None`` for "no reaction".  ``flat`` ignores the network
    (replicated all-to-all has no ring to re-route); ``hier`` and
    ``geococo`` adopt the control plane's relay ring on
    :class:`~repro.control.events.RelayOrderChanged`.
    """

    name: str
    needs_residuals: bool
    wire_values: Callable[[float, "SyncConfig", float], tuple[float, float]]
    react: Callable[["SyncConfig", Any], "SyncConfig | None"] | None = None


def _dense_wire(n: float, cfg: "SyncConfig", shard_factor: float = 1.0):
    return float(n), 0.0


def _topk_wire(n: float, cfg: "SyncConfig", shard_factor: float = 1.0):
    local_n = n / max(shard_factor, 1.0)
    if local_n < cfg.min_leaf_size:
        return float(n), 0.0  # small (per-shard) leaves are exchanged densely
    n_chunks = math.ceil(local_n / cfg.chunk)
    k = max(1, int(round(cfg.density * cfg.chunk)))
    return 0.0, float(n_chunks * min(k, cfg.chunk) * max(shard_factor, 1.0))


def _react_relay_order(cfg: "SyncConfig", event: Any) -> "SyncConfig | None":
    """Ring-bearing strategies adopt the control plane's new relay order."""
    from ..control.events import RelayOrderChanged

    if isinstance(event, RelayOrderChanged):
        order = tuple(int(i) for i in event.order)
        if order != cfg.ring_order:
            return dataclasses.replace(cfg, ring_order=order)
    return None


strategies.register(
    "device_sync", "flat",
    DeviceSyncStrategy("flat", needs_residuals=False, wire_values=_dense_wire),
)
strategies.register(
    "device_sync", "hier",
    DeviceSyncStrategy("hier", needs_residuals=False, wire_values=_dense_wire,
                       react=_react_relay_order),
)
strategies.register(
    "device_sync", "geococo",
    DeviceSyncStrategy("geococo", needs_residuals=True, wire_values=_topk_wire,
                       react=_react_relay_order),
)


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """Device-plane sync strategy configuration.

    ``strategy`` must name a registered ``device_sync`` strategy.  ``density``
    is the kept fraction per chunk for the filtered exchange; ``chunk`` the
    top-k selection granularity; ``min_leaf_size`` the element count below
    which a leaf skips filtering (norm scales and biases are cheap and
    high-impact — always sent densely, a task-preservation choice).

    ``ring_order`` is the pod relay ring for the exchange — the device-plane
    image of the WAN plane's TIV relay paths, normally fed by
    ``repro.control.ControlPlane`` from *measured* inter-pod latency (a
    :class:`RelayOrderChanged` event through the strategy's ``react``).
    ``None`` keeps the pmean default (ring order left to XLA).
    """

    strategy: str = "hier"
    density: float = 0.10
    chunk: int = 2048
    min_leaf_size: int = 4096
    ring_order: tuple[int, ...] | None = None

    def __post_init__(self):
        known = strategies.names("device_sync")
        if self.strategy not in known:
            raise ValueError(
                f"unknown sync strategy {self.strategy!r}; registered: {known}"
            )
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.min_leaf_size < 0:
            raise ValueError(
                f"min_leaf_size must be >= 0, got {self.min_leaf_size}"
            )
        if self.ring_order is not None:
            order = tuple(int(i) for i in self.ring_order)
            if sorted(order) != list(range(len(order))):
                raise ValueError(
                    f"ring_order must be a permutation of 0..n_pods-1, "
                    f"got {self.ring_order}"
                )
            object.__setattr__(self, "ring_order", order)

    @property
    def spec(self) -> DeviceSyncStrategy:
        return strategies.get("device_sync", self.strategy)

    @property
    def needs_residuals(self) -> bool:
        return self.spec.needs_residuals


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------


def relay_psum(x: jnp.ndarray, axis: str = "pod", *, order=None) -> jnp.ndarray:
    """All-reduce over ``axis`` via an explicit relay ring.

    ``order`` is the ring order of pod indices — the device-plane mirror of
    the WAN plane's TIV relay paths (``repro.core.latency.one_relay_effective``):
    a profitable overlay path becomes the ring neighbor ordering, so the
    slowest direct pair never carries traffic.  The result equals
    ``jax.lax.psum`` (up to float reassociation).
    """
    if order is not None:
        n = len(order)
    else:
        n = int(jax.lax.psum(1, axis))
        order = tuple(range(n))
    if n <= 1:
        return x
    perm = [(int(order[i]), int(order[(i + 1) % n])) for i in range(n)]
    acc = x
    msg = x
    for _ in range(n - 1):
        msg = jax.lax.ppermute(msg, axis, perm=perm)
        acc = acc + msg
    return acc


def _pod_mean(x: jnp.ndarray, axis: str, n_pods: int, order) -> jnp.ndarray:
    """Mean over pods — through the explicit relay ring when an order is
    set (measured-latency routing), else the stock ``pmean``."""
    if order is None:
        return jax.lax.pmean(x, axis)
    return relay_psum(x, axis, order=order) / n_pods


def _topk_mask(m: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-row mask selecting the ``k`` largest-|.| entries of ``m``."""
    rows, chunk = m.shape
    if k >= chunk:
        return jnp.ones_like(m)
    _, idx = jax.lax.top_k(jnp.abs(m), k)                      # (rows, k)
    row_ids = jnp.repeat(jnp.arange(rows), k)
    return jnp.zeros_like(m).at[row_ids, idx.ravel()].set(1.0)


def chunked_topk_exchange(
    grad: jnp.ndarray,
    residual: jnp.ndarray | None,
    *,
    axis: str = "pod",
    density: float = 0.10,
    chunk: int = 2048,
    order: tuple[int, ...] | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Density-based top-k gradient exchange with error feedback.

    The device-plane analogue of white-data filtering: per ``chunk``-sized
    block, only the ``density`` fraction of largest-magnitude entries of
    ``grad + residual`` crosses the pod boundary; the rest stays in the new
    residual and is *carried to the next step* (error feedback), so no task
    signal is dropped — only deferred.  Returns ``(pmean_of_sent,
    new_residual)``.  With ``density=1.0`` this is exactly a ``pmean`` and
    the residual returns to zero.  ``order`` routes the reduction over an
    explicit relay ring (see :func:`relay_psum`); the result is identical
    up to float reassociation.
    """
    dtype = grad.dtype
    acc = grad.astype(jnp.float32)
    if residual is not None:
        acc = acc + residual.astype(jnp.float32)
    shape = acc.shape
    flat = acc.ravel()
    n = flat.size
    pad = (-n) % chunk
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    m = flat.reshape(-1, chunk)
    k = max(1, int(round(density * chunk)))
    mask = _topk_mask(m, k)
    sent = m * mask
    new_res = m - sent
    if order is not None:
        out = relay_psum(sent, axis, order=order) / len(order)
    else:
        out = jax.lax.pmean(sent, axis)
    out = out.ravel()[:n].reshape(shape).astype(dtype)
    new_res = new_res.ravel()[:n].reshape(shape)
    return out, new_res


def sync_gradients(
    grads: Any,
    residuals: Any,
    cfg: SyncConfig,
    *,
    axis: str = "pod",
    n_pods: int | None = None,
    leaf_specs: Any = None,
) -> tuple[Any, Any]:
    """Synchronize a gradient pytree across pods under ``cfg.strategy``.

    Must run where ``axis`` is a bound (manual) mesh axis when
    ``n_pods > 1`` — e.g. inside a ``shard_map`` over the pod axis.  With a
    single pod this is the identity (the input objects are returned
    untouched).  ``leaf_specs`` is accepted for callers that track per-leaf
    partitioning; the exchange itself operates on whatever slice of each
    leaf the calling region holds.

    Returns ``(synced_grads, new_residuals)``.  ``new_residuals`` is ``None``
    whenever ``residuals`` is ``None`` and the strategy carries no state.
    """
    del leaf_specs
    if n_pods is None or n_pods <= 1:
        return grads, residuals
    order = cfg.ring_order
    if order is not None and len(order) != n_pods:
        raise ValueError(
            f"ring_order {order} does not cover the {n_pods}-pod axis"
        )
    spec = cfg.spec
    if not spec.needs_residuals:
        synced = jax.tree.map(
            lambda g: _pod_mean(g, axis, n_pods, order), grads
        )
        return synced, residuals

    res = residuals
    if res is None:
        res = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        if g.size < cfg.min_leaf_size:
            return _pod_mean(g, axis, n_pods, order), r
        return chunked_topk_exchange(
            g, r, axis=axis, density=cfg.density, chunk=cfg.chunk, order=order
        )

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(res)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    synced = td.unflatten([o[0] for o in out])
    new_res = td.unflatten([o[1] for o in out])
    return synced, new_res


# ---------------------------------------------------------------------------
# analytic wire model
# ---------------------------------------------------------------------------


def estimate_sync_bytes(
    n_params: float | Any,
    cfg: SyncConfig,
    n_pods: int,
    *,
    bytes_per_value: int = 4,
    shard_factor: float = 1.0,
) -> float:
    """Analytic inter-pod bytes per device per step.

    ``n_params`` is either an element count (the per-device shard size the
    strategy actually exchanges — full replica for ``flat``, FSDP shard for
    ``hier``/``geococo``) or a gradient pytree of *logical* leaves, in
    which case the per-leaf accounting (``min_leaf_size`` dense fallback,
    chunk-granular top-k) matches :func:`sync_gradients`.  When leaves are
    split across in-pod devices, pass ``shard_factor`` (devices per leaf):
    the filter operates on the shard each device actually holds, so the
    dense-fallback threshold applies to ``leaf.size / shard_factor``, not
    the logical size.

    The exchange volume model is the ring all-reduce ``2 (P-1)/P`` factor;
    filtered values pay ``bytes_per_value + 4`` for the chunk-local index.
    The benchmarks cross-check this model against the WAN simulator's
    hierarchical schedule and against bytes actually moved on the mesh.
    """
    if n_pods <= 1:
        return 0.0
    spec = cfg.spec
    if isinstance(n_params, (int, float)):
        sizes = [float(n_params)]
    else:
        sizes = [float(l.size) for l in jax.tree.leaves(n_params)]
    dense = sparse = 0.0
    for n in sizes:
        d, s = spec.wire_values(n, cfg, shard_factor)
        dense += d
        sparse += s
    ring = 2.0 * (n_pods - 1) / n_pods
    return ring * (
        dense * bytes_per_value + sparse * (bytes_per_value + _INDEX_BYTES)
    )
