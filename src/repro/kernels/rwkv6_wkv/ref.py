"""Pure-jnp oracle for the RWKV-6 WKV recurrence.

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Shapes: r/k/v/w (B, T, H, N); u (H, N); state (B, H, N, N).
All math in float32 (the recurrence is precision-sensitive: products of
decays underflow quickly in bf16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv6_ref"]


def wkv6_ref(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    state: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = u.astype(jnp.float32)
    state = state.astype(jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                       # (B, H, N)
        kv = kt[..., :, None] * vt[..., None, :]    # (B, H, N, N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        return wt[..., None] * s + kv, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), final
