"""Public jit'd wrapper for the WKV6 kernel: model-facing shapes, padding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import wkv6_ref
from .rwkv6_wkv import wkv6_pallas

__all__ = ["wkv6", "wkv6_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def wkv6(
    r: jnp.ndarray,    # (B, T, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,    # (H, N)
    state: jnp.ndarray,  # (B, H, N, N)
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    time_chunk: int = 128,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Model-facing WKV6: returns (y (B,T,H,N), final_state)."""
    if not use_kernel:
        return wkv6_ref(r, k, v, w, u, state)
    interpret = (not _ON_TPU) if interpret is None else interpret
    b, t, h, n = r.shape

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, n).astype(jnp.float32)

    u_bh = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n).astype(jnp.float32)
    s_bh = state.reshape(b * h, n, n).astype(jnp.float32)
    y, s_fin = wkv6_pallas(
        to_bh(r), to_bh(k), to_bh(v), to_bh(w), u_bh, s_bh,
        time_chunk=time_chunk, interpret=interpret,
    )
    y = y.reshape(b, h, t, n).transpose(0, 2, 1, 3)
    return y, s_fin.reshape(b, h, n, n)
