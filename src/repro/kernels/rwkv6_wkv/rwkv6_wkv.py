"""Pallas TPU kernel: RWKV-6 WKV recurrence, time-chunked with VMEM-resident
state.

TPU adaptation (vs the CUDA wkv6 kernel): the GPU version assigns one thread
per (batch, head, channel) and serializes over T in registers; on TPU we keep
the whole (N, N) per-head state as a VMEM scratch tile and sweep time in
chunks.  The grid is (B*H, T / tc) with ``dimension_semantics=("parallel",
"arbitrary")``: time iterates innermost, so the scratch state persists across
one head's chunks and is re-initialized at chunk 0.

Per chunk, an inner fori_loop performs tc rank-1 updates on the state tile
(VPU ops on an (N, N) tile; N=64 head dims round up to the 128-lane register
width).  HBM traffic is O(T*N) in/out; the O(T*N^2) kv outer products never
leave VMEM — that is the kernel's point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TIME_CHUNK = 128


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sfin_ref, state):
    tc = r_ref.shape[1]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        state[...] = s0_ref[0]

    def step(t, carry):
        rt = r_ref[0, t, :]                     # (N,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        u = u_ref[0, :]
        s = state[...]                          # (N, N)
        kv = kt[:, None] * vt[None, :]          # (N, N)
        y = (rt[:, None] * (s + u[:, None] * kv)).sum(axis=0)   # (N,)
        y_ref[0, t, :] = y
        state[...] = wt[:, None] * s + kv
        return carry

    jax.lax.fori_loop(0, tc, step, 0)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _fin():
        sfin_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("time_chunk", "interpret"))
def wkv6_pallas(
    r: jnp.ndarray,     # (BH, T, N) float32
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,     # (BH, N)
    s0: jnp.ndarray,    # (BH, N, N)
    *,
    time_chunk: int = DEFAULT_TIME_CHUNK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    from jax.experimental.pallas import tpu as pltpu

    bh, t, n = r.shape
    tc = min(time_chunk, t)
    while t % tc:
        tc -= 1
    grid = (bh, t // tc)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    return pl.pallas_call(
        _wkv6_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tc, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tc, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tc, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tc, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(r, k, v, w, u, s0)
