"""Public jit'd wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import rglru_scan_ref
from .rglru_scan import rglru_scan_pallas

__all__ = ["rglru_scan", "rglru_scan_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def rglru_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if not use_kernel:
        return rglru_scan_ref(a, b, h0)
    interpret = (not _ON_TPU) if interpret is None else interpret
    return rglru_scan_pallas(
        a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32),
        interpret=interpret,
    )
