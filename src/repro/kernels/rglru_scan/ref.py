"""Pure-jnp oracle for the RG-LRU gated linear recurrence.

    h_t = a_t * h_{t-1} + b_t        (per channel)

Inputs: a, b (B, T, D) with a in (0, 1]; h0 (B, D).
Returns (h (B, T, D), h_T (B, D)).  Sequential scan in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rglru_scan_ref"]


def rglru_scan_ref(
    a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
    )
    return hs.transpose(1, 0, 2), h_last
