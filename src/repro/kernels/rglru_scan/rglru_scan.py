"""Pallas TPU kernel: RG-LRU gated linear recurrence (Griffin).

Grid: (B, D / bd, T / tc) — batch and channel-blocks are parallel; time is
the innermost (arbitrary) dimension so the (1, bd) state row in VMEM scratch
persists across a channel block's chunks.  Within a chunk the fori_loop walks
tc steps; every step is a fused multiply-add on a (1, bd) register row.

vs GPU: the CUDA linear-scan kernels (e.g. Hawk/Griffin) block over channels
per warp with shuffle-based chunked prefix products; the TPU layout instead
keeps channels lane-aligned (bd a multiple of 128) and trades the log-depth
prefix trick for a short sequential sweep per chunk — the MXU is idle either
way and HBM traffic is identical, so the simple sweep is roofline-neutral.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TIME_CHUNK = 256
DEFAULT_CHANNEL_BLOCK = 512


def _rglru_kernel(a_ref, b_ref, h0_ref, h_ref, hfin_ref, state):
    tc = a_ref.shape[1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        state[...] = h0_ref[...]

    def step(t, carry):
        h = a_ref[0, t, :] * state[0, :] + b_ref[0, t, :]
        h_ref[0, t, :] = h
        state[0, :] = h
        return carry

    jax.lax.fori_loop(0, tc, step, 0)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _fin():
        hfin_ref[...] = state[...]


@functools.partial(jax.jit, static_argnames=("time_chunk", "channel_block", "interpret"))
def rglru_scan_pallas(
    a: jnp.ndarray,      # (B, T, D) float32
    b: jnp.ndarray,
    h0: jnp.ndarray,     # (B, D)
    *,
    time_chunk: int = DEFAULT_TIME_CHUNK,
    channel_block: int = DEFAULT_CHANNEL_BLOCK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    from jax.experimental.pallas import tpu as pltpu

    bsz, t, d = a.shape
    tc = min(time_chunk, t)
    while t % tc:
        tc -= 1
    bd = min(channel_block, d)
    while d % bd:
        bd -= 1
    grid = (bsz, d // bd, t // tc)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    return pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, tc, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, bd), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tc, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, bd), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(a, b, h0)
