"""Pure-jnp oracle for the fused error-feedback white-data filter.

Semantics (per element, over a gradient block g and residual r):

    acc   = g + r                       (error-feedback accumulation)
    keep  = |acc| >= tau                (white-data test)
    send  = keep ? acc : 0              (crosses the slow link)
    r'    = keep ? 0   : acc            (stays local, re-accumulates)
    kept  = sum(keep)                   (per-block statistics)

This is the gradient-plane analogue of the paper's task-preserving filter:
``send + r' == g + r`` always (nothing is lost, only deferred), mirroring
the database filter's losslessness.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["whitedata_filter_ref"]


def whitedata_filter_ref(
    g: jnp.ndarray, r: jnp.ndarray, tau: jnp.ndarray | float
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (send, new_residual, kept_count:int32 scalar)."""
    acc = g.astype(jnp.float32) + r.astype(jnp.float32)
    keep = jnp.abs(acc) >= jnp.asarray(tau, jnp.float32)
    send = jnp.where(keep, acc, 0.0).astype(g.dtype)
    new_r = jnp.where(keep, 0.0, acc).astype(r.dtype)
    kept = keep.sum(dtype=jnp.int32)
    return send, new_r, kept
