"""Pallas TPU kernel: fused error-feedback white-data filter.

One VMEM pass computes accumulate + threshold + split + block-count, where
the naive jnp version makes four HBM round-trips over (g, r).  The op is
purely elementwise + a block reduction — a VPU kernel (no MXU), bound by
HBM bandwidth; fusing the four ops quarters the bytes moved.

Grid: 2-D over (M / bm, N / bn) row-major; each program handles one
(bm, bn) VMEM tile.  ``kept`` is a per-program partial count reduced by the
wrapper (keeps the kernel free of cross-program communication).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)  # multiples of the (8, 128) float32 VMEM tile


def _filter_kernel(g_ref, r_ref, tau_ref, send_ref, newr_ref, kept_ref):
    g = g_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    tau = tau_ref[0]
    acc = g + r
    keep = jnp.abs(acc) >= tau
    send_ref[...] = jnp.where(keep, acc, 0.0).astype(send_ref.dtype)
    newr_ref[...] = jnp.where(keep, 0.0, acc).astype(newr_ref.dtype)
    kept_ref[0, 0] = keep.sum(dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def whitedata_filter_pallas(
    g: jnp.ndarray,
    r: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """g, r: (M, N); tau: () scalar.  Returns (send, new_r, kept_count)."""
    m, n = g.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    if m % bm or n % bn:
        raise ValueError(f"shape {(m, n)} not divisible by block {(bm, bn)}")
    grid = (m // bm, n // bn)
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)

    send, new_r, kept = pl.pallas_call(
        _filter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),     # tau: tiny, replicated
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), g.dtype),
            jax.ShapeDtypeStruct((m, n), r.dtype),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(g, r, tau_arr)
    return send, new_r, kept.sum(dtype=jnp.int32)
