"""Public jit'd wrapper for the white-data gradient filter.

Handles arbitrary pytrees / shapes by flattening to padded 2-D tiles, calls
the Pallas kernel (interpret mode on CPU, compiled on TPU), and exposes the
high-level ``filter_gradient`` used by the geococo sync strategy.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .ref import whitedata_filter_ref
from .whitedata_filter import DEFAULT_BLOCK, whitedata_filter_pallas

__all__ = ["whitedata_filter", "filter_gradient", "whitedata_filter_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def whitedata_filter(
    g: jnp.ndarray,
    r: jnp.ndarray,
    tau,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Filter one array (any shape).  Returns (send, new_r, kept)."""
    if not use_kernel:
        return whitedata_filter_ref(g, r, tau)
    interpret = (not _ON_TPU) if interpret is None else interpret
    shape = g.shape
    size = g.size
    bm, bn = DEFAULT_BLOCK
    if size % bn:
        # pad the flat vector up to a tile multiple
        pad = bn - size % bn
        gf = jnp.concatenate([g.reshape(-1), jnp.zeros(pad, g.dtype)])
        rf = jnp.concatenate([r.reshape(-1), jnp.zeros(pad, r.dtype)])
    else:
        pad = 0
        gf, rf = g.reshape(-1), r.reshape(-1)
    rows = gf.size // bn
    bm_eff = math.gcd(rows, bm) if rows % bm else bm
    send, new_r, kept = whitedata_filter_pallas(
        gf.reshape(rows, bn), rf.reshape(rows, bn), tau,
        block=(bm_eff, bn), interpret=interpret,
    )
    send = send.reshape(-1)[:size].reshape(shape)
    new_r = new_r.reshape(-1)[:size].reshape(shape)
    return send, new_r, kept


def filter_gradient(grads, residuals, tau, *, use_kernel: bool = True):
    """Apply the filter across a gradient pytree.

    Returns (send_tree, new_residual_tree, stats) with
    stats = {"kept": int32, "total": int32, "density": f32}.
    """
    leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residuals)
    sends, new_rs, kepts = [], [], []
    total = 0
    for g, r in zip(leaves, r_leaves):
        s, nr, k = whitedata_filter(g, r, tau, use_kernel=use_kernel)
        sends.append(s)
        new_rs.append(nr)
        kepts.append(k)
        total += g.size
    kept = sum(kepts)
    stats = {
        "kept": kept,
        "total": jnp.asarray(total, jnp.int32),
        "density": kept.astype(jnp.float32) / total,
    }
    return treedef.unflatten(sends), treedef.unflatten(new_rs), stats
