"""Pure-jnp oracle for the versioned CRDT merge (LWW lattice join).

Row-wise last-writer-wins over two batches of slots:

    winner_i = a if ver_a[i] >= ver_b[i] else b
    out_val[i]  = winner_i's values
    out_ver[i]  = max(ver_a[i], ver_b[i])

Ties keep side a (deterministic; the system guarantees equal versions imply
equal payloads, see repro.core.crdt).  The join is ACI, so the fault-tolerant
reducer can apply duplicated / reordered delta batches safely.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["crdt_merge_ref"]


def crdt_merge_ref(
    val_a: jnp.ndarray,   # (M, N)
    ver_a: jnp.ndarray,   # (M,) int32
    val_b: jnp.ndarray,
    ver_b: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    take_a = ver_a >= ver_b
    out_val = jnp.where(take_a[:, None], val_a, val_b)
    out_ver = jnp.maximum(ver_a, ver_b)
    return out_val, out_ver
