"""Public jit'd wrapper for the versioned CRDT merge kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .crdt_merge import DEFAULT_BLOCK, crdt_merge_pallas
from .ref import crdt_merge_ref

__all__ = ["crdt_merge", "crdt_merge_many", "crdt_merge_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def crdt_merge(
    val_a, ver_a, val_b, ver_b, *, use_kernel: bool = True,
    interpret: bool | None = None,
):
    """Merge two versioned slot batches: (M, N) payloads + (M,) versions."""
    if not use_kernel:
        return crdt_merge_ref(val_a, ver_a, val_b, ver_b)
    interpret = (not _ON_TPU) if interpret is None else interpret
    m, n = val_a.shape
    bm = _div_block(m, DEFAULT_BLOCK[0])
    bn = _div_block(n, DEFAULT_BLOCK[1])
    return crdt_merge_pallas(
        val_a, ver_a.astype(jnp.int32), val_b, ver_b.astype(jnp.int32),
        block=(bm, bn), interpret=interpret,
    )


def _div_block(n: int, target: int) -> int:
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def crdt_merge_many(batches, *, use_kernel: bool = True):
    """Fold-merge a list of (values, versions) batches (ACI => any order)."""
    val, ver = batches[0]
    ver = ver.astype(jnp.int32)
    for vb, rb in batches[1:]:
        val, ver = crdt_merge(val, ver, vb, rb, use_kernel=use_kernel)
    return val, ver
