"""Pallas TPU kernel: row-versioned LWW merge of update batches.

TPU adaptation notes (vs a GPU implementation): a GPU merge typically uses
per-row CAS/atomic loops; on TPU the merge is a pure lattice join — a
predicated select on (version, payload) rows with no atomics, executed on
the VPU over (bm, bn) VMEM tiles.  Versions ride along as a (bm, 1) column
so one row-predicate broadcasts across the payload tile.

Grid: (M / bm, N / bn); versions are written only by the first column
program (j == 0) to avoid redundant stores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _merge_kernel(va_ref, ra_ref, vb_ref, rb_ref, out_ref, over_ref):
    ver_a = ra_ref[...]                      # (bm, 1) int32
    ver_b = rb_ref[...]
    take_a = ver_a >= ver_b                  # (bm, 1) bool
    out_ref[...] = jnp.where(take_a, va_ref[...], vb_ref[...])
    @pl.when(pl.program_id(1) == 0)
    def _():
        over_ref[...] = jnp.maximum(ver_a, ver_b)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def crdt_merge_pallas(
    val_a: jnp.ndarray,
    ver_a: jnp.ndarray,
    val_b: jnp.ndarray,
    ver_b: jnp.ndarray,
    *,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    m, n = val_a.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    if m % bm or n % bn:
        raise ValueError(f"shape {(m, n)} not divisible by block {(bm, bn)}")
    grid = (m // bm, n // bn)
    ra = ver_a.reshape(m, 1)
    rb = ver_b.reshape(m, 1)

    out_val, out_ver = pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), val_a.dtype),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(val_a, ra, val_b, rb)
    return out_val, out_ver.reshape(m)
