"""Trace-driven WAN simulator (paper Sec 6.1, "Trace-driven Simulation").

Executes a :class:`~repro.core.schedule.TransmissionSchedule` against latency
and bandwidth matrices (optionally with packet loss and retransmission
timeouts), producing the round *makespan*, per-node/per-link byte counters
and per-pair message-frequency matrices — the raw measurements behind the
paper's Figs. 9, 10, 13, 14, 16 and 17.

Transfer-time model (one transfer of ``B`` bytes over link (s, d)):

    t = propagation(s, d) + B * 8 * c / bandwidth(s, d)        [ms]

where ``c`` is the **access-link contention factor**: within a phase, a
node's NIC serializes its concurrent flows, so each flow effectively gets
``bw / max(out_degree(src), in_degree(dst))``.  This is what makes the flat
all-to-all expensive in practice (every node carries n-1 concurrent flows)
and aggregation cheap (degree <= group size) — the economics behind the
paper's Fig. 3 and Sec 2.2.

Propagation is inflated by expected retransmissions under loss ``p``
(geometric retries, each costing timeout ``tau``):

    t += (p / (1 - p)) * tau

Relayed transfers (``via >= 0``) pay both hops' propagation and both hops'
(contended) serialization — a user-space store-and-forward overlay relay.

Phases are barrier-synchronized; the makespan of a round is the sum of the
phase maxima (the paper's Eq. 1 objective generalized to include transmission
time).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .schedule import Transfer, TransmissionSchedule

__all__ = ["WANSimulator", "RoundResult"]


@dataclasses.dataclass
class RoundResult:
    makespan_ms: float
    phase_ms: list[float]
    bytes_out: np.ndarray          # per node, WAN egress (matches NIC counters, Sec 6.1)
    bytes_in: np.ndarray
    msg_matrix: np.ndarray         # (n, n) message counts, src -> dst
    link_bytes: np.ndarray         # (n, n) bytes moved per directed link
    n_transfers: int

    @property
    def total_bytes(self) -> float:
        return float(self.link_bytes.sum())


class WANSimulator:
    """Simulates schedule execution over a given network state."""

    def __init__(
        self,
        latency_ms: np.ndarray,
        bandwidth_mbps: np.ndarray | float = np.inf,
        *,
        loss: np.ndarray | float = 0.0,
        retx_timeout_ms: float = 200.0,
        rng: np.random.Generator | None = None,
        stochastic_loss: bool = False,
    ):
        self.lat = np.asarray(latency_ms, dtype=float)
        n = self.lat.shape[0]
        self.n = n
        bw = np.asarray(bandwidth_mbps, dtype=float)
        self.bw = np.broadcast_to(bw, (n, n)).copy() if bw.ndim < 2 else bw.copy()
        self.loss = np.broadcast_to(np.asarray(loss, dtype=float), (n, n))
        self.retx_timeout_ms = retx_timeout_ms
        self.rng = rng or np.random.default_rng(0)
        self.stochastic_loss = stochastic_loss

    # -- single-transfer cost ------------------------------------------------

    def _hop_time(self, s: int, d: int, nbytes: float,
                  contention: float = 1.0) -> float:
        prop = self.lat[s, d]
        p = float(self.loss[s, d])
        if p > 0.0:
            if self.stochastic_loss:
                retries = self.rng.geometric(1.0 - p) - 1
                prop += retries * self.retx_timeout_ms
            else:
                prop += (p / (1.0 - p)) * self.retx_timeout_ms
        bw = self.bw[s, d]
        tx = (
            0.0
            if not np.isfinite(bw)
            else nbytes * 8.0 * contention / (bw * 1e6) * 1e3
        )
        return prop + tx

    def transfer_time_ms(self, t: Transfer, out_deg=None, in_deg=None) -> float:
        def c(s, d):
            if out_deg is None:
                return 1.0
            return float(max(out_deg[s], in_deg[d], 1))

        if t.via < 0:
            return self._hop_time(t.src, t.dst, t.nbytes, c(t.src, t.dst))
        return self._hop_time(
            t.src, t.via, t.nbytes, c(t.src, t.via)
        ) + self._hop_time(t.via, t.dst, t.nbytes, c(t.via, t.dst))

    # -- full round ----------------------------------------------------------

    def run(self, schedule: TransmissionSchedule) -> RoundResult:
        n = self.n
        bytes_out = np.zeros(n)
        bytes_in = np.zeros(n)
        msg = np.zeros((n, n), dtype=int)
        link = np.zeros((n, n))
        phase_ms: list[float] = []
        for phase in schedule.phases:
            if not phase:
                phase_ms.append(0.0)
                continue
            # NIC contention: concurrent flows within the phase share each
            # node's access link.
            out_deg = np.zeros(n, dtype=int)
            in_deg = np.zeros(n, dtype=int)
            for t in phase:
                if t.via < 0:
                    out_deg[t.src] += 1
                    in_deg[t.dst] += 1
                else:
                    out_deg[t.src] += 1
                    in_deg[t.via] += 1
                    out_deg[t.via] += 1
                    in_deg[t.dst] += 1
            tmax = 0.0
            for t in phase:
                tt = self.transfer_time_ms(t, out_deg, in_deg)
                tmax = max(tmax, tt)
                if t.via < 0:
                    bytes_out[t.src] += t.nbytes
                    bytes_in[t.dst] += t.nbytes
                    msg[t.src, t.dst] += 1
                    link[t.src, t.dst] += t.nbytes
                else:
                    bytes_out[t.src] += t.nbytes
                    bytes_in[t.via] += t.nbytes
                    bytes_out[t.via] += t.nbytes
                    bytes_in[t.dst] += t.nbytes
                    msg[t.src, t.via] += 1
                    msg[t.via, t.dst] += 1
                    link[t.src, t.via] += t.nbytes
                    link[t.via, t.dst] += t.nbytes
            phase_ms.append(tmax)
        return RoundResult(
            makespan_ms=float(sum(phase_ms)),
            phase_ms=phase_ms,
            bytes_out=bytes_out,
            bytes_in=bytes_in,
            msg_matrix=msg,
            link_bytes=link,
            n_transfers=schedule.n_transfers,
        )

    # -- bounds ----------------------------------------------------------------

    def lower_bound_ms(self, payload_bytes: float = 0.0) -> float:
        """Theoretical optimum for one all-to-all round (Fig 9 "Low Bound").

        Every pair must exchange its payload; no schedule beats the all-pairs
        shortest-path latency of the slowest pair plus its serialization time.
        """
        from .latency import all_pairs_shortest

        sp = all_pairs_shortest(self.lat)
        n = self.n
        mask = ~np.eye(n, dtype=bool)
        prop = sp[mask].max()
        if payload_bytes > 0.0 and np.isfinite(self.bw).any():
            tx = payload_bytes * 8.0 / (self.bw[mask].max() * 1e6) * 1e3
        else:
            tx = 0.0
        return float(prop + tx)
