"""Trace-driven WAN simulator (paper Sec 6.1, "Trace-driven Simulation").

Executes a :class:`~repro.core.schedule.TransmissionSchedule` against latency
and bandwidth matrices (optionally with packet loss and retransmission
timeouts), producing the round *makespan*, per-node/per-link byte counters
and per-pair message-frequency matrices — the raw measurements behind the
paper's Figs. 9, 10, 13, 14, 16 and 17.

Two execution engines over the same wire model:

* **event-driven** (default): a fluid-flow event-queue simulation of the
  transfer DAG.  Each transfer starts the moment its dependencies have been
  delivered (plus its ``compute_ms`` CPU stage); NIC contention is computed
  from the set of flows *actually moving bytes concurrently in time* — a
  node's access link is shared equally among its live flows, and rates are
  re-solved at every flow start/finish.  Relayed transfers (``via >= 0``)
  run as two chained hops (store-and-forward: the second hop starts at the
  first hop's delivery).  The makespan is the DAG critical path, which the
  :class:`RoundResult` exposes via per-transfer start/finish times and a
  backtracked critical-path trace.

  The engine is *lazy per flow*: a flow's byte integration is materialized
  only at events on its own two directed NICs (its src out-NIC and dst
  in-NIC), and finishes are projected drain events invalidated by a token
  when the NIC population changes.  Events elsewhere in the DAG never touch
  the flow's floating-point state, so a flow's measured times are a pure
  function of its NIC-local event history.  That locality is what makes
  **incremental simulation exact**: under bandwidth admission a later
  epoch's flows never share a NIC in time with an earlier epoch's, so
  :meth:`WANSimulator.simulate_segment` can replay one appended epoch
  against carried :class:`NicState` floors and reproduce the full
  re-simulation's times byte-for-byte
  (:class:`repro.core.stream.StreamingTimeline` builds on this).

  **Bandwidth admission** (``admission=True``, the default): a ready hop is
  *deferred* while either of its NICs still carries undrained flows of a
  strictly earlier phase rank — a later-phase exchange/scatter can never
  steal NIC bandwidth from an earlier phase's still-running gathers.  With
  admission, at any instant the byte-moving flows on a directed NIC all
  share one phase rank and never outnumber that phase's static degree, so
  every flow runs at least as fast as its barrier-static estimate and
  ``event <= barrier`` is a *theorem* for any schedule whose dependencies
  point at strictly earlier phases (all builders; property-tested in
  ``tests/test_property_dag.py``).  ``admission=False`` restores the
  greedy ASAP start, which on adversarial matrices (severely
  bandwidth-starved links) can exceed the barrier phase-sum.

Transfers with ``src == dst`` are **local compute stages** (the streaming
multi-epoch engine's per-node execution stages): they occupy no NIC, move
no bytes, take ``compute_ms`` after their dependencies, and are excluded
from byte/message accounting in both engines.

For stitched multi-epoch schedules (:func:`~repro.core.schedule.stitch_schedules`)
the event engine accepts ``run(schedule, lats=[lat_0, lat_1, ...])``: each
transfer's propagation is taken from its epoch's latency matrix (the trace
the replication engine iterates), while bandwidth/loss stay constructor-
fixed.  The barrier engine rejects latency stacks — cross-epoch streaming
has no barrier-phase semantics.

* **barrier** (``barrier=True``): the pre-DAG semantics, kept for regression
  comparison.  Phases (the schedule's derived compatibility view) are
  barrier-synchronized; within a phase each flow is charged the phase-static
  contention factor ``max(out_degree(src), in_degree(dst))``, and the round
  makespan is the *sum of the phase maxima* (the paper's Eq. 1 objective
  generalized to include transmission time).  This reproduces the
  pre-refactor phase-sum numbers exactly.

Transfer-time model (one hop of ``B`` bytes over link (s, d)):

    t = propagation(s, d) + B * 8 * c / bandwidth(s, d)        [ms]

where ``c`` is the access-link contention factor (phase-static degrees under
``barrier``; the time-varying live-flow count under the event engine).  This
is what makes the flat all-to-all expensive in practice (every node carries
n-1 concurrent flows) and aggregation cheap (degree <= group size) — the
economics behind the paper's Fig. 3 and Sec 2.2.

Propagation is inflated by expected retransmissions under loss ``p``
(geometric retries, each costing timeout ``tau``):

    t += (p / (1 - p)) * tau
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from .schedule import Transfer, TransmissionSchedule

__all__ = [
    "EpochLatencyCycle",
    "NicState",
    "RoundResult",
    "WANSimulator",
    "epoch_commit_row",
    "node_commit_ms",
]


class EpochLatencyCycle:
    """Per-epoch latency matrices as a cyclic view over a trace.

    The replication engine's epoch ``e`` always uses ``trace[e % len(trace)]``,
    so a run's per-epoch latency "stack" is fully determined by the trace
    plus the horizon — materializing ``[trace[e % p] for e in range(E)]``
    (E full matrices) is pure duplication.  This sequence indexes the trace
    lazily instead; ``len()`` is the horizon, ``[k]`` the epoch's matrix.
    Consumers that index with ``lats[min(e, len(lats) - 1)]`` (the event
    engine, the serve plane) see exactly the matrices the materialized
    list held.
    """

    def __init__(self, trace: Sequence[np.ndarray], n_epochs: int):
        self._stack = [np.asarray(l, dtype=float) for l in trace]
        if not self._stack:
            raise ValueError("EpochLatencyCycle requires a non-empty trace")
        self._n = int(n_epochs)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, k: int) -> np.ndarray:
        k = int(k)
        if k < 0 or k >= self._n:
            raise IndexError(f"epoch {k} out of range [0, {self._n})")
        return self._stack[k % len(self._stack)]


@dataclasses.dataclass
class NicState:
    """Per-directed-NIC admission floors carried across appended segments.

    ``clear_out[i]`` / ``clear_in[i]`` is the last drain time of any
    byte-moving hop on node ``i``'s out-/in-NIC so far.  Under bandwidth
    admission every hop of a later segment has a strictly higher rank than
    everything already streamed, so it may not occupy either of its NICs
    before these floors — exactly when the full re-simulation's ``min_out``
    / ``min_in`` would have advanced past the earlier epochs' ranks.
    """

    clear_out: np.ndarray
    clear_in: np.ndarray

    @classmethod
    def zeros(cls, n: int) -> "NicState":
        return cls(np.zeros(n), np.zeros(n))


@dataclasses.dataclass
class RoundResult:
    makespan_ms: float
    phase_ms: list[float]
    bytes_out: np.ndarray          # per node, WAN egress (matches NIC counters, Sec 6.1)
    bytes_in: np.ndarray
    msg_matrix: np.ndarray         # (n, n) message counts, src -> dst
    link_bytes: np.ndarray         # (n, n) bytes moved per directed link
    n_transfers: int
    start_ms: np.ndarray | None = None    # per transfer: wire start (post-compute)
    finish_ms: np.ndarray | None = None   # per transfer: delivery at dst
    critical_path: list[int] = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return float(self.link_bytes.sum())

    @property
    def critical_path_ms(self) -> float:
        """Alias for the makespan — under the event engine this is the DAG
        critical path, under ``barrier`` the phase-sum."""
        return self.makespan_ms


def epoch_commit_row(
    transfers: Sequence[Transfer],
    finish_ms: np.ndarray,
    n: int,
) -> np.ndarray:
    """One epoch's *raw* per-node commit row: per node, the max delivery
    over the transfers it owns (``src`` for local compute stages, ``dst``
    for wire hops; cadence ``clock`` stages are unowned).  ``-inf`` marks a
    node silent in the epoch — callers fold rows with a cumulative max and
    map residual ``-inf`` to 0 (see :func:`node_commit_ms`).
    """
    row = np.full(n, -np.inf)
    for i, t in enumerate(transfers):
        if t.tag == "clock":
            continue  # cadence stage: not owned by a real node
        node = t.src if t.src == t.dst else t.dst
        f = float(finish_ms[i])
        if f > row[node]:
            row[node] = f
    return row


def node_commit_ms(
    schedule: TransmissionSchedule,
    result: RoundResult,
    n: int,
    n_epochs: int | None = None,
    *,
    start_epoch: int = 0,
    base_row: np.ndarray | None = None,
) -> np.ndarray:
    """Per-node, per-epoch commit times of a simulated (stitched) schedule.

    ``out[k, i]`` is the time node ``i`` commits epoch ``k``: the delivery of
    every epoch-``k`` transfer *into* ``i`` (the same dependency set
    :func:`~repro.core.schedule.stitch_schedules` gates node ``i``'s
    epoch-``k+1`` sends on) joined with ``i``'s own epoch-``k`` local
    execution stage.  Nodes that neither receive nor execute in an epoch
    inherit their previous epoch's commit time (their view had nothing new
    to wait for).  This is the measured staleness signal the
    ``staleness_feedback`` OCC loop consumes: node ``i``'s snapshot view
    may advance to epoch ``k`` only at ``out[k, i]``.

    The windowed form computes only rows ``[start_epoch, n_epochs)``:
    ``base_row`` must then be the cumulative commit row of epoch
    ``start_epoch - 1`` (it seeds the running max, so the window is exactly
    the corresponding slice of the full matrix).  Omitting ``base_row``
    with ``start_epoch > 0`` drops the earlier epochs' history and is only
    meaningful when no node was silent across the whole window.
    """
    if n_epochs is None:
        n_epochs = max((t.epoch for t in schedule.transfers), default=-1) + 1
    rows = max(n_epochs - start_epoch, 0)
    out = np.full((rows, n), -np.inf)
    for idx, t in enumerate(schedule.transfers):
        if t.tag == "clock" or t.epoch < start_epoch or t.epoch >= n_epochs:
            continue  # cadence stage / outside the requested window
        node = t.src if t.src == t.dst else t.dst
        f = float(result.finish_ms[idx])
        if f > out[t.epoch - start_epoch, node]:
            out[t.epoch - start_epoch, node] = f
    if base_row is not None and rows:
        np.maximum(out[0], np.asarray(base_row, dtype=float), out=out[0])
    # a node silent in epoch k committed it the moment it committed k-1
    out = np.maximum.accumulate(out, axis=0)
    out[~np.isfinite(out)] = 0.0
    return out


class WANSimulator:
    """Simulates schedule execution over a given network state.

    ``barrier=True`` selects the legacy phase-sum engine (exact pre-DAG
    numbers); the default runs the event-driven DAG engine.  Byte, message
    and link accounting are identical across both engines — only timing
    differs — so consistency checks (digests, WAN-byte counters) are
    engine-independent.  ``admission=False`` disables the event engine's
    bandwidth-admission heuristic (greedy ASAP starts, the pre-fix
    behavior — kept for the adversarial regression tests and ablation).
    ``verify=True`` statically verifies every schedule before executing it
    (:func:`repro.analysis.schedule_check.verify_schedule` — acyclicity,
    phase monotonicity along deps, clock-chain linearity, ...).
    """

    def __init__(
        self,
        latency_ms: np.ndarray,
        bandwidth_mbps: np.ndarray | float = np.inf,
        *,
        loss: np.ndarray | float = 0.0,
        retx_timeout_ms: float = 200.0,
        rng: np.random.Generator | None = None,
        stochastic_loss: bool = False,
        barrier: bool = False,
        admission: bool = True,
        verify: bool = False,
    ):
        self.lat = np.asarray(latency_ms, dtype=float)
        n = self.lat.shape[0]
        self.n = n
        bw = np.asarray(bandwidth_mbps, dtype=float)
        self.bw = np.broadcast_to(bw, (n, n)).copy() if bw.ndim < 2 else bw.copy()
        self.loss = np.broadcast_to(np.asarray(loss, dtype=float), (n, n))
        self.retx_timeout_ms = retx_timeout_ms
        self.rng = rng or np.random.default_rng(0)
        self.stochastic_loss = stochastic_loss
        self.barrier = barrier
        self.admission = admission
        self.verify = verify

    # -- single-hop cost -----------------------------------------------------

    def _prop_ms(self, s: int, d: int, lat: np.ndarray | None = None) -> float:
        prop = (self.lat if lat is None else lat)[s, d]
        p = float(self.loss[s, d])
        if p > 0.0:
            if self.stochastic_loss:
                retries = self.rng.geometric(1.0 - p) - 1
                prop += retries * self.retx_timeout_ms
            else:
                prop += (p / (1.0 - p)) * self.retx_timeout_ms
        return float(prop)

    def _hop_time(self, s: int, d: int, nbytes: float,
                  contention: float = 1.0) -> float:
        prop = self._prop_ms(s, d)
        bw = self.bw[s, d]
        tx = (
            0.0
            if not np.isfinite(bw)
            else nbytes * 8.0 * contention / (bw * 1e6) * 1e3
        )
        return prop + tx

    def transfer_time_ms(self, t: Transfer, out_deg=None, in_deg=None) -> float:
        def c(s, d):
            if out_deg is None:
                return 1.0
            return float(max(out_deg[s], in_deg[d], 1))

        if t.src == t.dst:
            return 0.0  # local compute stage: no wire (barrier ignores CPU)
        if t.via < 0:
            return self._hop_time(t.src, t.dst, t.nbytes, c(t.src, t.dst))
        return self._hop_time(
            t.src, t.via, t.nbytes, c(t.src, t.via)
        ) + self._hop_time(t.via, t.dst, t.nbytes, c(t.via, t.dst))

    # -- byte / message accounting (engine-independent) ------------------------

    def _account(self, schedule: TransmissionSchedule):
        n = self.n
        bytes_out = np.zeros(n)
        bytes_in = np.zeros(n)
        msg = np.zeros((n, n), dtype=int)
        link = np.zeros((n, n))
        for t in schedule.all_transfers():
            if t.src == t.dst:
                continue  # local compute stage: nothing on the wire
            if t.via < 0:
                bytes_out[t.src] += t.nbytes
                bytes_in[t.dst] += t.nbytes
                msg[t.src, t.dst] += 1
                link[t.src, t.dst] += t.nbytes
            else:
                bytes_out[t.src] += t.nbytes
                bytes_in[t.via] += t.nbytes
                bytes_out[t.via] += t.nbytes
                bytes_in[t.dst] += t.nbytes
                msg[t.src, t.via] += 1
                msg[t.via, t.dst] += 1
                link[t.src, t.via] += t.nbytes
                link[t.via, t.dst] += t.nbytes
        return bytes_out, bytes_in, msg, link

    # -- full round ----------------------------------------------------------

    def run(self, schedule: TransmissionSchedule,
            barrier: bool | None = None,
            lats: Sequence[np.ndarray] | None = None) -> RoundResult:
        """Execute the schedule.  ``lats`` (a per-epoch latency-matrix list
        for stitched multi-epoch schedules; each transfer's propagation is
        taken from ``lats[transfer.epoch]``) is event-engine only.

        With ``verify=True`` (the ``EngineConfig(verify_schedules=True)``
        debug hook) every schedule is statically verified first — an
        O(V+E) pass over the invariants both engines assume — and a
        :class:`~repro.analysis.schedule_check.ScheduleVerificationError`
        (a ``ValueError``) is raised on any violation."""
        if self.verify:
            from ..analysis.schedule_check import (
                ScheduleVerificationError,
                verify_schedule,
            )

            violations = verify_schedule(schedule, n_nodes=self.n)
            if violations:
                raise ScheduleVerificationError(violations, schedule.label)
        if barrier if barrier is not None else self.barrier:
            if lats is not None:
                raise ValueError(
                    "per-epoch latency stacks require the event engine: "
                    "cross-epoch streaming has no barrier-phase semantics"
                )
            return self._run_barrier(schedule)
        return self._run_event(schedule, lats=lats)

    # -- barrier engine (pre-DAG phase-sum semantics) --------------------------

    def _phase_degrees(self, phase):
        """NIC contention degrees of one barrier phase: concurrent flows
        within the phase share each node's access link (phase-static)."""
        out_deg = np.zeros(self.n, dtype=int)
        in_deg = np.zeros(self.n, dtype=int)
        for t in phase:
            if t.src == t.dst:
                continue  # local compute stage: no NIC
            if t.via < 0:
                out_deg[t.src] += 1
                in_deg[t.dst] += 1
            else:
                out_deg[t.src] += 1
                in_deg[t.via] += 1
                out_deg[t.via] += 1
                in_deg[t.dst] += 1
        return out_deg, in_deg

    def barrier_makespan_ms(self, schedule: TransmissionSchedule) -> float:
        """Phase-sum makespan alone — no byte accounting, no per-transfer
        timeline.  The cheap serialized reference the pipelined replication
        engine reports its overlap split against every epoch."""
        total = 0.0
        for phase in schedule.phases:
            if not phase:
                continue
            out_deg, in_deg = self._phase_degrees(phase)
            total += max(
                self.transfer_time_ms(t, out_deg, in_deg) for t in phase
            )
        return total

    def _run_barrier(self, schedule: TransmissionSchedule) -> RoundResult:
        m = schedule.n_transfers
        start = np.zeros(m)
        finish = np.zeros(m)
        phase_ms: list[float] = []
        crit: list[int] = []
        t_base = 0.0
        for phase_idx in schedule.phase_indices():
            if not phase_idx:
                phase_ms.append(0.0)
                continue
            phase = [schedule.transfers[i] for i in phase_idx]
            out_deg, in_deg = self._phase_degrees(phase)
            tmax = 0.0
            tmax_idx = -1
            for i, t in zip(phase_idx, phase):
                tt = self.transfer_time_ms(t, out_deg, in_deg)
                start[i] = t_base
                finish[i] = t_base + tt
                if tt > tmax:
                    tmax, tmax_idx = tt, i
            phase_ms.append(tmax)
            if tmax_idx >= 0:
                crit.append(tmax_idx)
            t_base += tmax
        bytes_out, bytes_in, msg, link = self._account(schedule)
        return RoundResult(
            makespan_ms=float(sum(phase_ms)),
            phase_ms=phase_ms,
            bytes_out=bytes_out,
            bytes_in=bytes_in,
            msg_matrix=msg,
            link_bytes=link,
            n_transfers=m,
            start_ms=start,
            finish_ms=finish,
            critical_path=crit,
        )

    # -- event-driven engine (fluid-flow DAG simulation) -----------------------

    def _admission_ranks(self, schedule: TransmissionSchedule) -> np.ndarray:
        """Per-transfer admission rank: the builder-recorded positional phase,
        repaired to be strictly increasing along dependency edges (so a hop
        never waits on a rank that could wait back — admission cannot
        deadlock).  Falls back to ASAP dependency levels without phases."""
        base = schedule.phase_of
        rank = np.zeros(schedule.n_transfers, dtype=int)
        for i, t in enumerate(schedule.transfers):
            r = 0
            for d in t.deps:
                if rank[d] + 1 > r:
                    r = rank[d] + 1
            if base is not None and base[i] > r:
                r = int(base[i])
            rank[i] = r
        return rank

    def _simulate_dag(
        self,
        transfers: Sequence[Transfer],
        prop_fn,
        rank: np.ndarray | None,
        *,
        deps: Sequence[tuple[int, ...]] | None = None,
        ext_ready: Sequence[float] | None = None,
        nic: NicState | None = None,
        tid_base: int = 0,
    ):
        """Lazy per-flow event simulation of one transfer list.

        ``deps`` (default: each transfer's own ``deps``) must be local
        indices into ``transfers``; dependencies on transfers simulated
        earlier (a previous segment) are folded into ``ext_ready[i]`` — the
        earliest time transfer ``i``'s external dependencies allow it to
        become ready (its ``compute_ms`` is added on top, exactly as a live
        dependency's delivery would be).  ``nic`` carries the per-directed-
        NIC clear floors across segments and is updated in place.
        ``tid_base`` offsets the event keys so a segment's events tie-break
        identically to the same transfers inside a full stitched run —
        equal-time event order is part of the byte-identity contract.

        A flow's floating-point state (remaining bytes, current rate,
        last-materialization time) is touched only by events on its own two
        directed NICs; finishes are projected drain events invalidated by a
        per-flow token.  Returns ``(start, finish, pred)``.
        """
        m = len(transfers)
        if deps is None:
            deps = [t.deps for t in transfers]
        hops = [  # per transfer: the 1 or 2 (src, dst) wire hops
            [(t.src, t.dst)] if t.via < 0 else [(t.src, t.via), (t.via, t.dst)]
            for t in transfers
        ]
        indeg = [len(ds) for ds in deps]
        children: list[list[int]] = [[] for _ in range(m)]
        for i, ds in enumerate(deps):
            for d in ds:
                children[d].append(i)

        # bandwidth admission: register every byte-moving hop on its NICs up
        # front, bucketed by admission rank.  A ready hop starts only when no
        # *undrained* lower-rank hop shares its src out-NIC or dst in-NIC —
        # arrival order is irrelevant, so per NIC the live flows always share
        # one rank and never exceed that phase's static degree (the invariant
        # behind the event <= barrier theorem).  Ranks are rebased by the
        # segment minimum so an appended epoch's pend table stays O(segment).
        rankb: list[int] | None = None
        if rank is not None:
            rmin = int(rank.min()) if m else 0
            n_ranks = (int(rank.max()) - rmin + 1) if m else 1
            rankb = [int(r) - rmin for r in rank]
            pend_out = np.zeros((self.n, n_ranks), dtype=int)
            pend_in = np.zeros((self.n, n_ranks), dtype=int)
            for i, t in enumerate(transfers):
                if t.src == t.dst or t.nbytes <= 0.0:
                    continue
                for s, d in hops[i]:
                    if np.isfinite(self.bw[s, d]):
                        pend_out[s, rankb[i]] += 1
                        pend_in[d, rankb[i]] += 1
            # cached min pending rank per directed NIC (only ever advances:
            # all hops are registered up front and only drains decrement)
            min_out = np.zeros(self.n, dtype=int)
            min_in = np.zeros(self.n, dtype=int)

            def _advance(pend, mins, node):
                while mins[node] < n_ranks and pend[node, mins[node]] == 0:
                    mins[node] += 1

            for node in range(self.n):
                _advance(pend_out, min_out, node)
                _advance(pend_in, min_in, node)

        parked: list[tuple[int, int]] = []  # hops deferred by admission

        start = np.full(m, np.nan)      # wire start (after deps + compute)
        finish = np.full(m, np.nan)     # delivery of the final hop at dst
        pred = np.full(m, -1, dtype=int)  # latest-finishing dependency

        # lazy per-flow fluid state
        active = [False] * m
        rem = [0.0] * m                 # remaining bytes, current hop
        rate = [0.0] * m                # bytes/ms under current contention
        seg_t = [0.0] * m               # time rem was last materialized
        token = [0] * m                 # invalidates stale drain projections
        cur = [(0, 0, 0)] * m           # current hop (s, d, hop)
        out_cnt = np.zeros(self.n, dtype=int)
        in_cnt = np.zeros(self.n, dtype=int)
        # insertion-ordered id sets of live flows per directed NIC (order is
        # never observable — each flow's update is independent — but dicts
        # keep iteration reproducible for free)
        out_flows: list[dict[int, None]] = [{} for _ in range(self.n)]
        in_flows: list[dict[int, None]] = [{} for _ in range(self.n)]

        READY, DELIVER, DRAIN = 0, 1, 2
        # event keys order by (time, kind, global tid, aux): canonical across
        # full and segment runs — `serial` only breaks exact duplicates
        events: list[tuple[float, int, int, int, int, int]] = []
        serial = 0

        def push(time: float, kind: int, tid: int, aux: int):
            nonlocal serial
            heapq.heappush(events, (time, kind, tid_base + tid, aux, serial,
                                    tid))
            serial += 1

        def retune(s: int, d: int, now: float):
            """Re-solve every flow sharing the two touched NICs: integrate
            its bytes up to ``now`` at the old rate, then re-rate under the
            new population and re-project its drain."""
            touched = dict(out_flows[s])
            touched.update(in_flows[d])
            for j in touched:
                if now > seg_t[j]:
                    rem[j] -= rate[j] * (now - seg_t[j])
                    seg_t[j] = now
                js, jd, _ = cur[j]
                c = max(int(out_cnt[js]), int(in_cnt[jd]), 1)
                rate[j] = float(self.bw[js, jd]) * 1e6 / 8.0 / 1e3 / c
                token[j] += 1
                left = rem[j] / rate[j] if rem[j] > 0.0 else 0.0
                push(seg_t[j] + left, DRAIN, j, token[j])

        def begin_hop(now: float, tid: int, hop: int):
            s, d = hops[tid][hop]
            t = transfers[tid]
            if s == d or t.nbytes <= 0.0 or not np.isfinite(self.bw[s, d]):
                # nothing to serialize: deliver after propagation only
                if hop == 0:
                    start[tid] = now
                push(now + prop_fn(tid, s, d), DELIVER, tid, hop)
                return
            if nic is not None:
                floor = max(float(nic.clear_out[s]), float(nic.clear_in[d]))
                if now < floor:
                    # an earlier segment still occupies a NIC: retry exactly
                    # when the full run's admission would have cleared it
                    push(floor, READY, tid, hop)
                    return
            if rankb is not None and (
                min_out[s] < rankb[tid] or min_in[d] < rankb[tid]
            ):
                parked.append((tid, hop))  # dst/src NIC busy with earlier phase
                return
            if hop == 0:
                start[tid] = now
            active[tid] = True
            rem[tid] = float(t.nbytes)
            seg_t[tid] = now
            cur[tid] = (s, d, hop)
            out_cnt[s] += 1
            in_cnt[d] += 1
            out_flows[s][tid] = None
            in_flows[d][tid] = None
            retune(s, d, now)

        for i in range(m):
            if indeg[i] == 0:
                rt = 0.0 if ext_ready is None else float(ext_ready[i])
                push(rt + transfers[i].compute_ms, READY, i, 0)

        while events:
            now, kind, _gid, aux, _serial, tid = heapq.heappop(events)
            if kind == READY:
                begin_hop(now, tid, aux)
            elif kind == DRAIN:
                if not active[tid] or aux != token[tid]:
                    continue  # stale projection: the NIC population changed
                active[tid] = False
                rem[tid] = 0.0
                s, d, hop = cur[tid]
                out_cnt[s] -= 1
                in_cnt[d] -= 1
                del out_flows[s][tid]
                del in_flows[d][tid]
                if nic is not None:
                    nic.clear_out[s] = now
                    nic.clear_in[d] = now
                push(now + prop_fn(tid, s, d), DELIVER, tid, hop)
                if rankb is not None:
                    r = rankb[tid]
                    pend_out[s, r] -= 1
                    pend_in[d, r] -= 1
                    _advance(pend_out, min_out, s)
                    _advance(pend_in, min_in, d)
                    if parked:
                        # the drain may have unblocked deferred hops; ready
                        # ones start now, the rest re-park inside begin_hop
                        pk, parked[:] = list(parked), []
                        for tid2, hop2 in pk:
                            begin_hop(now, tid2, hop2)
                retune(s, d, now)
            else:  # DELIVER
                if aux + 1 < len(hops[tid]):
                    begin_hop(now, tid, aux + 1)  # store-and-forward relay
                    continue
                finish[tid] = now
                for c in children[tid]:
                    if pred[c] < 0 or finish[pred[c]] <= now:
                        pred[c] = tid
                    indeg[c] -= 1
                    if indeg[c] == 0:
                        rt = now if ext_ready is None else max(
                            now, float(ext_ready[c])
                        )
                        push(rt + transfers[c].compute_ms, READY, c, 0)

        if parked:  # unreachable: ranks strictly increase along deps
            raise RuntimeError(
                f"admission deadlock: {len(parked)} hops still parked"
            )
        return start, finish, pred

    def simulate_segment(
        self,
        transfers: Sequence[Transfer],
        *,
        rank: np.ndarray,
        deps: Sequence[tuple[int, ...]],
        ext_ready: Sequence[float],
        nic: NicState,
        lat: np.ndarray | None = None,
        tid_base: int = 0,
    ):
        """Simulate one appended segment of a stitched stream against the
        carried cross-segment state (:class:`NicState` floors, folded
        external-dependency ready times) — the incremental half of the
        byte-identity contract (see :class:`repro.core.stream.
        StreamingTimeline`).  ``lat`` is this segment's latency matrix
        (each appended epoch sees its own trace step, like ``run(...,
        lats=[...])``).  Returns ``(start, finish, pred)`` and updates
        ``nic`` in place."""
        if self.barrier:
            raise ValueError(
                "segment simulation requires the event engine: barrier "
                "phases have no cross-segment semantics"
            )
        if not self.admission:
            raise ValueError(
                "segment simulation is only sound under bandwidth admission "
                "(admission=False lets later segments slow earlier flows)"
            )
        if self.stochastic_loss:
            raise ValueError(
                "segment simulation rejects stochastic_loss=True: the "
                "retransmission draws happen in event order, which differs "
                "between incremental and full runs"
            )
        lat_m = self.lat if lat is None else np.asarray(lat, dtype=float)

        def prop_fn(tid: int, s: int, d: int) -> float:
            if s == d:
                return 0.0  # local compute stage
            return self._prop_ms(s, d, lat=lat_m)

        return self._simulate_dag(
            transfers, prop_fn, rank, deps=deps, ext_ready=ext_ready,
            nic=nic, tid_base=tid_base,
        )

    def _run_event(self, schedule: TransmissionSchedule,
                   lats: Sequence[np.ndarray] | None = None) -> RoundResult:
        transfers = schedule.transfers
        m = len(transfers)
        bytes_out, bytes_in, msg, link = self._account(schedule)
        if m == 0:
            return RoundResult(
                makespan_ms=0.0, phase_ms=[], bytes_out=bytes_out,
                bytes_in=bytes_in, msg_matrix=msg, link_bytes=link,
                n_transfers=0, start_ms=np.zeros(0), finish_ms=np.zeros(0),
            )

        stack: Sequence[np.ndarray] | None = None
        if lats is not None:
            # an EpochLatencyCycle already indexes lazily — wrapping it in a
            # list would materialize the E duplicated matrices it exists to
            # avoid
            if isinstance(lats, EpochLatencyCycle):
                stack = lats
            else:
                stack = [np.asarray(l, dtype=float) for l in lats]

        def prop_ms(tid: int, s: int, d: int) -> float:
            if s == d:
                return 0.0  # local compute stage
            if stack is None:
                return self._prop_ms(s, d)
            return self._prop_ms(
                s, d, lat=stack[min(transfers[tid].epoch, len(stack) - 1)]
            )

        rank = self._admission_ranks(schedule) if self.admission else None
        start, finish, pred = self._simulate_dag(transfers, prop_ms, rank)
        makespan = float(np.nanmax(finish)) if m else 0.0
        # critical path: backtrack from the makespan-defining transfer through
        # each transfer's latest-finishing dependency
        crit: list[int] = []
        cur = int(np.nanargmax(finish))
        while cur >= 0:
            crit.append(cur)
            cur = int(pred[cur])
        crit.reverse()
        return RoundResult(
            makespan_ms=makespan,
            phase_ms=[],
            bytes_out=bytes_out,
            bytes_in=bytes_in,
            msg_matrix=msg,
            link_bytes=link,
            n_transfers=m,
            start_ms=start,
            finish_ms=finish,
            critical_path=crit,
        )

    # -- bounds ----------------------------------------------------------------

    def lower_bound_ms(self, payload_bytes: float = 0.0) -> float:
        """Theoretical optimum for one all-to-all round (Fig 9 "Low Bound").

        Every pair must exchange its payload; no schedule beats the all-pairs
        shortest-path latency of the slowest pair plus its serialization time.
        """
        from .latency import all_pairs_shortest

        sp = all_pairs_shortest(self.lat)
        n = self.n
        mask = ~np.eye(n, dtype=bool)
        prop = sp[mask].max()
        if payload_bytes > 0.0 and np.isfinite(self.bw).any():
            tx = payload_bytes * 8.0 / (self.bw[mask].max() * 1e6) * 1e3
        else:
            tx = 0.0
        return float(prop + tx)
