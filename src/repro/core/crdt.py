"""Delta-CRDT replication state (paper Sec 4.4, "Correctness under ...").

GeoCoCo inherits GeoGauss's epoch-aware delta-CRDT model: per-key
last-writer-wins registers under a total version order.  The merge operator
is the lattice join (max by version), which is **commutative, associative and
idempotent (ACI)** — the algebraic foundation for correctness under message
reordering, duplication and delayed delivery.  Property tests in
``tests/test_property_crdt.py`` verify ACI and the permutation/multiplicity
invariance equation from Sec 4.4 directly.

A :class:`Version` is the tuple ``(epoch, seq, node)``; versions are unique
per update and totally ordered, so ``merge`` is deterministic everywhere.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import ClassVar, Iterable, Mapping

__all__ = ["Version", "Update", "DeltaCRDTStore", "merge_updates"]


@dataclasses.dataclass(frozen=True, order=True)
class Version:
    epoch: int
    seq: int          # deterministic within-epoch order (e.g. commit timestamp)
    node: int         # tie-break: origin replica id

    ZERO: ClassVar["Version"]


Version.ZERO = Version(-1, -1, -1)


@dataclasses.dataclass(frozen=True)
class Update:
    """A delta: one versioned write to one key."""

    key: str
    value: bytes
    version: Version
    txn_id: int = -1

    @property
    def nbytes(self) -> int:
        # key + value payload + fixed version/txn metadata
        return len(self.key) + len(self.value) + 24

    def meta_only(self) -> "Update":
        """Payload-stripped wire form (key + version metadata, no value).

        Used for byte accounting of null-effect white data: the receiver
        reconstructs the full update from its own snapshot, so only this
        form crosses the WAN.  Never applied to a store directly.
        """
        return dataclasses.replace(self, value=b"")


class DeltaCRDTStore:
    """Per-key LWW-register map with ACI merge."""

    def __init__(self, node_id: int = -1):
        self.node_id = node_id
        self._data: dict[str, tuple[bytes, Version]] = {}

    # -- reads ---------------------------------------------------------------

    def get(self, key: str) -> bytes | None:
        ent = self._data.get(key)
        return ent[0] if ent is not None else None

    def version_of(self, key: str) -> Version:
        ent = self._data.get(key)
        return ent[1] if ent is not None else Version.ZERO

    def keys(self) -> Iterable[str]:
        return self._data.keys()

    def __len__(self) -> int:
        return len(self._data)

    # -- merge (the CRDT join) -------------------------------------------------

    def apply(self, u: Update) -> bool:
        """Join one update into the store.  Returns True iff state changed.

        Idempotent (re-applying is a no-op) and commutative/associative across
        updates because the winner is the version-order maximum.  System
        invariant (enforced by OCC version assignment): for a given
        ``(key, version)`` the underlying full payload is unique — a
        same-version duplicate is either an identical re-delivery or the
        payload-stripped (meta-only) form of the same update.
        """
        cur = self._data.get(u.key)
        if cur is not None and cur[1] >= u.version:
            return False
        self._data[u.key] = (u.value, u.version)
        return True

    def apply_many(self, updates: Iterable[Update]) -> int:
        return sum(self.apply(u) for u in updates)

    def merge_store(self, other: "DeltaCRDTStore") -> None:
        # sorted: merge outcome is order-independent (LWW), but apply-order
        # must not depend on the peer's insertion (arrival) order
        for key, (val, ver) in sorted(other._data.items()):
            self.apply(Update(key, val, ver))

    # -- state equality / digests ----------------------------------------------

    def value_state(self) -> dict[str, bytes]:
        return {k: v for k, (v, _) in sorted(self._data.items())}

    def full_state(self) -> dict[str, tuple[bytes, Version]]:
        return dict(self._data)

    def digest(self, *, values_only: bool = False) -> str:
        h = hashlib.sha256()
        for k in sorted(self._data):
            v, ver = self._data[k]
            h.update(k.encode())
            h.update(v)
            if not values_only:
                h.update(f"{ver.epoch}:{ver.seq}:{ver.node}".encode())
        return h.hexdigest()

    def snapshot(self) -> "DeltaCRDTStore":
        s = DeltaCRDTStore(self.node_id)
        s._data = dict(self._data)
        return s


def merge_updates(updates: Iterable[Update]) -> dict[str, Update]:
    """Pure merge of a batch: per-key version-order maximum.

    ``merge_updates(perm_with_dups(U)) == merge_updates(U)`` for any
    permutation and multiplicity — the Sec 4.4 invariance equation.
    """
    out: dict[str, Update] = {}
    for u in updates:
        cur = out.get(u.key)
        if cur is None or u.version > cur.version:
            out[u.key] = u
    return out
