"""Real-time latency monitoring (paper Sec 4.2 + Sec 5 "Delay Monitoring").

Two estimation regimes, matching the paper:

* :class:`LatencyMonitor` — full-mesh background probing with EWMA smoothing
  and sustained-deviation detection (the input to the damped Replanner).
  Tracks probe traffic so the "Cost of Delay Monitoring" numbers (Sec 6.4)
  are measurable.
* :class:`VivaldiSystem` — the Vivaldi network-coordinate system used at
  large scale (>= hundreds of nodes) to approximate the N x N matrix from
  O(N * samples) probes, with periodic verification sampling that corrects
  drift (the paper reports 96.4% probe reduction at 1024 nodes with <= 18%
  error).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LatencyMonitor", "VivaldiSystem"]

PROBE_BYTES = 64  # one RTT probe packet


class LatencyMonitor:
    """EWMA latency estimator over full-mesh probes."""

    def __init__(self, n: int, *, alpha: float = 0.3):
        self.n = n
        self.alpha = alpha
        self.est = np.zeros((n, n))
        self._have = np.zeros((n, n), dtype=bool)
        self.probe_count = 0

    def probe_all(self, truth: np.ndarray, rng: np.random.Generator | None = None,
                  noise: float = 0.0) -> np.ndarray:
        """One full-mesh probing round against the true matrix."""
        obs = truth.copy()
        if noise > 0.0 and rng is not None:
            obs = obs * np.exp(rng.normal(0.0, noise, size=obs.shape))
            obs = (obs + obs.T) / 2.0
            np.fill_diagonal(obs, 0.0)
        new = np.where(self._have, (1 - self.alpha) * self.est + self.alpha * obs, obs)
        self.est = new
        self._have[:] = True
        self.probe_count += self.n * (self.n - 1)
        return self.est

    def estimate(self) -> np.ndarray:
        """Current EWMA estimate (no probes) — the same accessor contract as
        :meth:`VivaldiSystem.estimate`, so ``repro.control`` views treat
        both regimes uniformly."""
        return self.est.copy()

    @property
    def probe_bytes(self) -> int:
        return self.probe_count * PROBE_BYTES


@dataclasses.dataclass
class VivaldiConfig:
    dim: int = 3
    ce: float = 0.25      # adaptive timestep constant
    cc: float = 0.25      # error-weight constant
    height: bool = True   # height vector models access-link latency
    init_error: float = 1.0


class VivaldiSystem:
    """Decentralized network coordinates (Dabek et al., SIGCOMM'04)."""

    def __init__(self, n: int, cfg: VivaldiConfig | None = None, seed: int = 0):
        self.n = n
        self.cfg = cfg or VivaldiConfig()
        rng = np.random.default_rng(seed)
        self.x = rng.normal(0.0, 1.0, size=(n, self.cfg.dim))
        self.h = np.full(n, 1.0) if self.cfg.height else np.zeros(n)
        self.err = np.full(n, self.cfg.init_error)
        self.probe_count = 0

    def _dist(self, i: int, j: int) -> float:
        return float(np.linalg.norm(self.x[i] - self.x[j]) + self.h[i] + self.h[j])

    def observe(self, i: int, j: int, rtt: float) -> None:
        """One RTT sample (i probes j)."""
        self.probe_count += 1
        w = self.err[i] / max(self.err[i] + self.err[j], 1e-9)
        d = self._dist(i, j)
        e_sample = abs(d - rtt) / max(rtt, 1e-9)
        self.err[i] = e_sample * self.cfg.cc * w + self.err[i] * (1 - self.cfg.cc * w)
        delta = self.cfg.ce * w
        diff = self.x[i] - self.x[j]
        nrm = np.linalg.norm(diff)
        unit = diff / nrm if nrm > 1e-12 else np.random.default_rng(0).normal(size=diff.shape)
        if nrm <= 1e-12:
            unit = unit / np.linalg.norm(unit)
        self.x[i] += delta * (rtt - d) * unit
        if self.cfg.height:
            self.h[i] = max(1e-3, self.h[i] + delta * (rtt - d) * 0.1)

    def fit(
        self,
        truth: np.ndarray,
        *,
        rounds: int = 100,
        samples_per_node: int = 8,
        rng: np.random.Generator | None = None,
    ) -> np.ndarray:
        """Fit coordinates from sparse random probing; returns estimate."""
        rng = rng or np.random.default_rng(0)
        n = self.n
        for _ in range(rounds):
            for i in range(n):
                peers = rng.choice(n - 1, size=min(samples_per_node, n - 1), replace=False)
                peers = np.where(peers >= i, peers + 1, peers)
                for j in peers:
                    self.observe(i, int(j), float(truth[i, j]))
        return self.estimate()

    def estimate(self) -> np.ndarray:
        d = np.linalg.norm(self.x[:, None, :] - self.x[None, :, :], axis=-1)
        d = d + self.h[:, None] + self.h[None, :]
        np.fill_diagonal(d, 0.0)
        return d

    def seed_from_matrix(self, measured: np.ndarray) -> None:
        """Monitor-seeded warmup: place coordinates at the classical-MDS
        embedding of a directly measured latency matrix.

        Random initial coordinates need many sparse rounds to untangle at
        small n (the poor small-n relay-order agreement in Fig 5); seeding
        from one full-mesh measurement starts the spring system at a
        near-correct configuration, and subsequent sparse rounds only track
        drift.  Probe accounting for the measurement is the caller's job
        (the view knows how many probes it actually paid)."""
        m = np.maximum(np.asarray(measured, dtype=float), 0.0)
        m = (m + m.T) / 2.0
        np.fill_diagonal(m, 0.0)
        n = self.n
        d2 = m ** 2
        j = np.eye(n) - np.ones((n, n)) / n
        b = -0.5 * j @ d2 @ j
        w, v = np.linalg.eigh(b)
        idx = np.argsort(w)[::-1][: self.cfg.dim]
        w = np.clip(w[idx], 0.0, None)
        x = v[:, idx] * np.sqrt(w)[None, :]
        if x.shape[1] < self.cfg.dim:  # degenerate spectra: pad flat dims
            x = np.pad(x, ((0, 0), (0, self.cfg.dim - x.shape[1])))
        self.x = x
        if self.cfg.height:
            # per-node residual the embedding could not place goes into the
            # height (access-link) component, split between endpoints
            est = np.linalg.norm(x[:, None, :] - x[None, :, :], axis=-1)
            off = ~np.eye(n, dtype=bool)
            resid = np.where(off, m - est, 0.0)
            self.h = np.maximum(resid.sum(axis=1) / max(n - 1, 1) / 2.0, 1e-3)
        # a seeded node is far more confident than a random one
        self.err = np.full(n, min(self.cfg.init_error, 0.25))

    def verify_and_correct(
        self,
        truth: np.ndarray,
        *,
        sample_frac: float = 0.05,
        rng: np.random.Generator | None = None,
        tol: float = 0.25,
    ) -> np.ndarray:
        """Verification mechanism (Sec 5): sample direct probes, pin entries
        whose predicted/measured deviation exceeds ``tol`` to the measurement."""
        rng = rng or np.random.default_rng(0)
        n = self.n
        est = self.estimate()
        iu = np.triu_indices(n, k=1)
        n_pairs = iu[0].size
        k = max(1, int(sample_frac * n_pairs))
        sel = rng.choice(n_pairs, size=k, replace=False)
        self.probe_count += k
        for s in sel:
            i, j = int(iu[0][s]), int(iu[1][s])
            t = float(truth[i, j])
            if t > 0 and abs(est[i, j] - t) / t > tol:
                est[i, j] = est[j, i] = t
        return est

    def median_rel_error(self, truth: np.ndarray) -> float:
        est = self.estimate()
        n = self.n
        iu = np.triu_indices(n, k=1)
        t = truth[iu]
        e = est[iu]
        mask = t > 0
        return float(np.median(np.abs(e[mask] - t[mask]) / t[mask]))

    @property
    def probe_bytes(self) -> int:
        return self.probe_count * PROBE_BYTES
