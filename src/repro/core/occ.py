"""Epoch-based optimistic concurrency control (GeoGauss-style, paper Sec 4.3).

Multi-master execution model: every replica executes transactions locally
against its (replicated) snapshot during an epoch, then exchanges batched
write sets.  Validation is deterministic and identical at every replica:

* **Write-write rule (first-writer-wins, no reinstatement)**: for each key
  written in the epoch, the writer with the smallest version wins the key.
  A transaction *aborts* iff it loses any key it writes — regardless of
  whether the winner itself later aborts.  This deliberately avoids cascaded
  reinstatement so the decision is computable from raw write-set overlap
  alone; crucially it makes *intra-group* abort detection at an aggregator
  sound: losing a key to any same-epoch writer is final (Sec 4.3 step 2).
* **Read validation**: a transaction aborts if any read version is stale
  w.r.t. the epoch-start snapshot (models delayed/stale reads).

Committed writes become :class:`~repro.core.crdt.Update` deltas and merge via
the CRDT join.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

from .crdt import DeltaCRDTStore, Update, Version

__all__ = ["Txn", "validate_epoch", "committed_updates", "txn_updates"]


@dataclasses.dataclass(frozen=True)
class Txn:
    """One transaction executed optimistically at ``node`` during ``epoch``.

    ``seq`` is the node-local commit timestamp; the global deterministic order
    is by ``Version(epoch, seq, node)``.
    """

    txn_id: int
    node: int
    epoch: int
    seq: int
    read_set: tuple[tuple[str, Version], ...] = ()
    write_set: tuple[tuple[str, bytes], ...] = ()

    @property
    def version(self) -> Version:
        return Version(self.epoch, self.seq, self.node)

    def writes_keys(self) -> tuple[str, ...]:
        return tuple(k for k, _ in self.write_set)


def txn_updates(txn: Txn) -> list[Update]:
    """The delta updates a transaction would produce if committed."""
    return [
        Update(key=k, value=v, version=txn.version, txn_id=txn.txn_id)
        for k, v in txn.write_set
    ]


def validate_epoch(
    txns: Sequence[Txn], snapshot: DeltaCRDTStore | None = None
) -> tuple[set[int], set[int]]:
    """Deterministic epoch validation.  Returns (committed_ids, aborted_ids).

    Works on any subset of the epoch's transactions; running it on a group's
    local subset yields abort decisions that are a *sound under-approximation*
    of the global outcome (a transaction aborted locally is aborted globally,
    because first-writer-wins per key is monotone under adding more writers).
    """
    aborted: set[int] = set()
    # read validation against the epoch-start snapshot
    if snapshot is not None:
        for t in txns:
            for key, ver in t.read_set:
                if snapshot.version_of(key) > ver:
                    aborted.add(t.txn_id)
                    break
    # first-writer-wins per key
    winners: dict[str, Version] = {}
    by_key: dict[str, list[Txn]] = {}
    for t in txns:
        for k in t.writes_keys():
            by_key.setdefault(k, []).append(t)
            v = t.version
            if k not in winners or v < winners[k]:
                winners[k] = v
    for k, writers in by_key.items():
        for t in writers:
            if t.version != winners[k]:
                aborted.add(t.txn_id)
    committed = {t.txn_id for t in txns} - aborted
    return committed, aborted


def committed_updates(
    txns: Sequence[Txn], snapshot: DeltaCRDTStore | None = None
) -> tuple[list[Update], set[int]]:
    """Validate and emit the updates of committed transactions."""
    committed, aborted = validate_epoch(txns, snapshot)
    ups: list[Update] = []
    for t in txns:
        if t.txn_id in committed:
            ups.extend(txn_updates(t))
    return ups, aborted
