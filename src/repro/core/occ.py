"""Epoch-based optimistic concurrency control (GeoGauss-style, paper Sec 4.3).

Multi-master execution model: every replica executes transactions locally
against its (replicated) snapshot during an epoch, then exchanges batched
write sets.  Validation is deterministic and identical at every replica:

* **Write-write rule (first-writer-wins, no reinstatement)**: for each key
  written in the epoch, the writer with the smallest version wins the key.
  A transaction *aborts* iff it loses any key it writes — regardless of
  whether the winner itself later aborts, **and regardless of whether the
  winner was itself read-aborted**.  This deliberately avoids cascaded
  reinstatement so the decision is computable from raw write-set overlap
  alone; crucially it makes *intra-group* abort detection at an aggregator
  sound: losing a key to any same-epoch writer is final (Sec 4.3 step 2).
  Including read-aborted writers in the winner map is what makes the abort
  set *monotone in staleness*: versioning the same transaction stream's
  reads against older snapshots can only ever add aborts, never reinstate
  a write-write loser (``tests/test_crdt_occ.py`` pins this semantics).
  Version ties (two transactions sharing ``(epoch, seq, node)`` — impossible
  for well-formed generators, whose ``seq`` is a node-local monotone
  counter) are broken deterministically by ``txn_id``, so at most one
  writer ever wins a key.

* **Read validation**: a transaction aborts if any read version is stale
  w.r.t. the epoch-start snapshot.  Reads are versioned at the *executing
  node's* snapshot view; when that view lags the global epoch-start state
  (the replica is paying off a WAN backlog, see
  ``EngineConfig(staleness_feedback=True)``), the rule fires — the paper's
  consistency argument that late-arriving state makes replicas validate
  against older snapshots.

Committed writes become :class:`~repro.core.crdt.Update` deltas and merge via
the CRDT join.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Mapping, Sequence

import numpy as np

from .crdt import DeltaCRDTStore, Update, Version

__all__ = [
    "Txn",
    "ValidationResult",
    "validate_epoch",
    "validate_epoch_detailed",
    "committed_updates",
    "txn_updates",
]

# validate_epoch_detailed dispatches to the vectorized path above this many
# transactions; below it the per-call numpy overhead (array building,
# np.unique on key strings) dominates the pure-Python loop it replaces
_NUMPY_THRESHOLD = 512


@dataclasses.dataclass(frozen=True)
class Txn:
    """One transaction executed optimistically at ``node`` during ``epoch``.

    ``seq`` is the node-local commit timestamp; the global deterministic order
    is by ``Version(epoch, seq, node)``.
    """

    txn_id: int
    node: int
    epoch: int
    seq: int
    read_set: tuple[tuple[str, Version], ...] = ()
    write_set: tuple[tuple[str, bytes], ...] = ()

    @property
    def version(self) -> Version:
        return Version(self.epoch, self.seq, self.node)

    def writes_keys(self) -> tuple[str, ...]:
        return tuple(k for k, _ in self.write_set)


def txn_updates(txn: Txn) -> list[Update]:
    """The delta updates a transaction would produce if committed."""
    return [
        Update(key=k, value=v, version=txn.version, txn_id=txn.txn_id)
        for k, v in txn.write_set
    ]


@dataclasses.dataclass(frozen=True)
class ValidationResult:
    """Abort breakdown of one epoch validation.

    ``read_aborted`` (stale read versions) and ``ww_aborted`` (lost a
    written key to an earlier writer) may overlap — a transaction can fail
    both rules; ``aborted`` is their union and ``committed`` its complement.
    """

    committed: frozenset[int]
    read_aborted: frozenset[int]
    ww_aborted: frozenset[int]

    @property
    def aborted(self) -> frozenset[int]:
        return self.read_aborted | self.ww_aborted


def validate_epoch_detailed(
    txns: Sequence[Txn],
    snapshot: DeltaCRDTStore | None = None,
    *,
    mode: str | None = None,
) -> ValidationResult:
    """Deterministic epoch validation with a per-rule abort breakdown.

    Works on any subset of the epoch's transactions; running it on a group's
    local subset yields abort decisions that are a *sound under-approximation*
    of the global outcome (a transaction aborted locally is aborted globally,
    because first-writer-wins per key is monotone under adding more writers).

    ``mode`` selects the implementation: ``"python"`` (the reference loop),
    ``"numpy"`` (vectorized winner map via one lexsort on
    ``(key, epoch, seq, node, txn_id)`` plus array version compares), or
    ``None`` (default) to dispatch on epoch size.  Both produce identical
    :class:`ValidationResult`\\ s on every input
    (``tests/test_property_occ.py`` pins the equivalence).
    """
    if mode is None:
        mode = "numpy" if len(txns) >= _NUMPY_THRESHOLD else "python"
    if mode == "numpy":
        return _validate_numpy(txns, snapshot)
    if mode != "python":
        raise ValueError(f"unknown validation mode {mode!r}")
    return _validate_python(txns, snapshot)


def _validate_python(
    txns: Sequence[Txn], snapshot: DeltaCRDTStore | None = None
) -> ValidationResult:
    """Reference implementation: the original per-txn validation loop."""
    read_aborted: set[int] = set()
    # read validation against the epoch-start snapshot
    if snapshot is not None:
        for t in txns:
            for key, ver in t.read_set:
                if snapshot.version_of(key) > ver:
                    read_aborted.add(t.txn_id)
                    break
    # first-writer-wins per key.  The winner map includes read-aborted
    # writers (no reinstatement — see module docstring) and breaks version
    # ties by txn_id, so a forced (epoch, seq, node) collision still yields
    # exactly one winner per key.
    ww_aborted: set[int] = set()
    winners: dict[str, tuple[Version, int]] = {}
    by_key: dict[str, list[Txn]] = {}
    for t in txns:
        for k in t.writes_keys():
            by_key.setdefault(k, []).append(t)
            cand = (t.version, t.txn_id)
            if k not in winners or cand < winners[k]:
                winners[k] = cand
    for k, writers in sorted(by_key.items()):
        for t in writers:
            if (t.version, t.txn_id) != winners[k]:
                ww_aborted.add(t.txn_id)
    committed = {t.txn_id for t in txns} - read_aborted - ww_aborted
    return ValidationResult(
        committed=frozenset(committed),
        read_aborted=frozenset(read_aborted),
        ww_aborted=frozenset(ww_aborted),
    )


def _validate_numpy(
    txns: Sequence[Txn], snapshot: DeltaCRDTStore | None = None
) -> ValidationResult:
    """Vectorized validation, identical by construction to
    :func:`_validate_python`.

    Key strings are interned to dense ids with one ``dict.setdefault``
    pass *inside* the flattening comprehension (far cheaper than
    ``np.unique`` over a string array, which pays an O(L log L) string
    sort), and the resulting all-int rows flatten through one
    ``np.fromiter(chain.from_iterable(...))`` into an ``(L, 5)`` matrix —
    no per-column re-iteration, no ``zip(*rows)`` transpose.

    Write-write: lexsort by ``(key-id, epoch, seq, node, txn_id)`` —
    within each key group the first row is the unique winner (the same
    ``min((Version, txn_id))`` the reference computes) — broadcast the
    winner down its group with a running maximum over group-start indices,
    and abort every row whose identity differs from its winner's.

    Reads: gather the snapshot version once per *unique* read key (the only
    remaining per-key Python work), then compare ``(epoch, seq, node)``
    lexicographically in arrays.
    """
    def cols(rows):
        L = len(rows)
        arr = np.fromiter(
            itertools.chain.from_iterable(rows), np.int64, 5 * L
        ).reshape(L, 5)
        return (arr[:, j] for j in range(5))

    read_aborted: set[int] = set()
    if snapshot is not None:
        kid: dict[str, int] = {}
        rows = [
            (t.txn_id, ver.epoch, ver.seq, ver.node,
             kid.setdefault(key, len(kid)))
            for t in txns
            for key, ver in t.read_set
        ]
        if rows:
            tid, ep, sq, nd, inv = cols(rows)
            snap = np.empty((len(kid), 3), dtype=np.int64)
            # each key writes its own row j, so iteration order cannot
            # reach the result
            for key, j in kid.items():  # lint: allow[unordered-dict-iter]
                sv = snapshot.version_of(key)
                snap[j] = (sv.epoch, sv.seq, sv.node)
            se, ss, sn = snap[inv, 0], snap[inv, 1], snap[inv, 2]
            stale = (
                (se > ep)
                | ((se == ep) & (ss > sq))
                | ((se == ep) & (ss == sq) & (sn > nd))
            )
            read_aborted.update(tid[stale].tolist())

    ww_aborted: set[int] = set()
    kid = {}
    w_rows = [
        (t.txn_id, t.epoch, t.seq, t.node, kid.setdefault(k, len(kid)))
        for t in txns
        for k, _v in t.write_set
    ]
    if w_rows:
        tid, ep, sq, nd, inv = cols(w_rows)
        order = np.lexsort((tid, nd, sq, ep, inv))
        inv_s = inv[order]
        start = np.empty(len(order), dtype=bool)
        start[0] = True
        start[1:] = inv_s[1:] != inv_s[:-1]
        winner_of = np.maximum.accumulate(
            np.where(start, np.arange(len(order)), 0)
        )
        win = order[winner_of]
        lose = (
            (tid[order] != tid[win])
            | (ep[order] != ep[win])
            | (sq[order] != sq[win])
            | (nd[order] != nd[win])
        )
        ww_aborted.update(tid[order][lose].tolist())

    committed = {t.txn_id for t in txns} - read_aborted - ww_aborted
    return ValidationResult(
        committed=frozenset(committed),
        read_aborted=frozenset(read_aborted),
        ww_aborted=frozenset(ww_aborted),
    )


def validate_epoch(
    txns: Sequence[Txn], snapshot: DeltaCRDTStore | None = None
) -> tuple[set[int], set[int]]:
    """Deterministic epoch validation.  Returns (committed_ids, aborted_ids).

    Compatibility wrapper around :func:`validate_epoch_detailed` (which also
    reports the read-rule vs write-write abort breakdown).
    """
    res = validate_epoch_detailed(txns, snapshot)
    return set(res.committed), set(res.aborted)


def committed_updates(
    txns: Sequence[Txn], snapshot: DeltaCRDTStore | None = None
) -> tuple[list[Update], set[int]]:
    """Validate and emit the updates of committed transactions."""
    res = validate_epoch_detailed(txns, snapshot)
    ups: list[Update] = []
    for t in txns:
        if t.txn_id in res.committed:
            ups.extend(txn_updates(t))
    return ups, set(res.aborted)
