"""Epoch-sink pipeline: push-based per-epoch consumers of a running engine.

The engine used to *accumulate then report*: every ``EpochStats``, every
``_EpochRound`` and the full ``(E, n)`` commit matrix stayed alive until
the end of ``GeoCluster.run``, making long-horizon memory O(E) even after
the O(E) *time* refactor (:mod:`repro.core.stream`).  This module is the
other half: stats are **pushed** to sinks the moment an epoch's numbers
are final, and nothing about the epoch needs to be retained afterwards.

Why per-epoch finality is sound (the PR-4 bandwidth-admission theorem
doing triple duty): every wire hop of epoch ``k+1`` carries a strictly
higher admission rank than everything already streamed, so later epochs'
flows never share a NIC in time with earlier ones — the moment
``StreamingTimeline.append_epoch`` returns, epoch ``k``'s measured commit
row and finish mark are what the full re-simulation would report, forever.
Eager extraction loses nothing.

Sinks:

* :class:`RunAggregator` (here) — online ``RunStats`` summary: running
  totals / moments (:class:`RunSummary`) plus a bounded trailing window of
  ``EpochStats`` (``EngineConfig(keep_epochs=False, stats_window=...)``;
  the default ``keep_epochs=True`` retains the full list, so existing
  consumers are untouched).
* ``repro.serve.ServingSink`` — the serving plane consuming commit rows +
  the epoch's trace matrix as they land, instead of the whole matrix at
  end of run.

Both implement the :class:`EpochSink` protocol; the engine drives every
attached sink from one dispatch point per epoch.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import TYPE_CHECKING, Protocol

import numpy as np

from .whitedata import FilterStats

if TYPE_CHECKING:  # pragma: no cover - typing only (avoids an import cycle)
    from .replication import EpochStats

__all__ = ["EpochContext", "EpochSink", "RunAggregator", "RunSummary"]


@dataclasses.dataclass(frozen=True)
class EpochContext:
    """Streaming-only side channel handed to sinks beside the stats.

    ``commit_row`` is the epoch's cumulative per-node commit row
    (``node_commit_ms`` semantics — final by the admission theorem) and
    ``lat`` the epoch's trace latency matrix (``trace[e % len(trace)]``;
    a reference, never a copy).  Non-streaming engines pass ``None``.
    """

    epoch: int
    commit_row: np.ndarray | None = None
    lat: np.ndarray | None = None


class EpochSink(Protocol):
    """A push-based consumer of finalized per-epoch stats.

    ``on_epoch`` is called exactly once per epoch, in epoch order, the
    moment the epoch's numbers are final; implementations must not retain
    unbounded per-epoch state (that is the point).  Finalization is
    sink-specific (e.g. ``RunAggregator.summary`` is always current;
    ``ServingSink.finish(wall_ms)`` builds the ``ServeStats``).
    """

    def on_epoch(
        self, stats: "EpochStats", ctx: EpochContext | None = None
    ) -> None: ...


@dataclasses.dataclass
class RunSummary:
    """Online run-level totals — what ``RunStats``' summing properties used
    to recompute from the full ``epochs`` list on every access.

    Accumulated strictly in epoch order with the same left-fold the old
    ``sum(e.x for e in epochs)`` properties performed, so every total is
    **byte-identical** to the retained computation (float addition is
    order-sensitive; the order is part of the contract).  ``sync_ms_sum``
    / ``sync_ms_sumsq`` / ``sync_ms_max`` are running moments for bounded
    runs where the full per-epoch array is gone.
    """

    n_epochs: int = 0
    n_txns: int = 0
    committed: int = 0
    aborted: int = 0
    read_aborts: int = 0
    ww_aborts: int = 0
    wall_ms: float = 0.0
    wan_bytes: float = 0.0
    sync_overlap_ms: float = 0.0
    pipeline_overlap_ms: float = 0.0
    filter_cpu_ms: float = 0.0
    filter_stats: FilterStats = dataclasses.field(default_factory=FilterStats)
    # running moments of the per-epoch DAG critical path (sync_ms) and the
    # measured wall gap — the bounded-memory stand-ins for the full arrays
    sync_ms_sum: float = 0.0
    sync_ms_sumsq: float = 0.0
    sync_ms_max: float = 0.0
    wall_ms_max: float = 0.0
    view_lag_mean_sum: float = 0.0
    view_lag_max: int = 0

    @property
    def abort_rate(self) -> float:
        return self.aborted / self.n_txns if self.n_txns else 0.0

    @property
    def read_abort_rate(self) -> float:
        return self.read_aborts / self.n_txns if self.n_txns else 0.0

    @property
    def sync_ms_mean(self) -> float:
        return self.sync_ms_sum / self.n_epochs if self.n_epochs else 0.0

    @property
    def sync_ms_std(self) -> float:
        """Population std from the running moments (clamped at 0: the
        two-pass identity loses precision when mean >> std)."""
        if not self.n_epochs:
            return 0.0
        m = self.sync_ms_mean
        return math.sqrt(max(self.sync_ms_sumsq / self.n_epochs - m * m, 0.0))

    @property
    def view_lag_mean(self) -> float:
        return self.view_lag_mean_sum / self.n_epochs if self.n_epochs else 0.0


class RunAggregator:
    """The engine's stats sink: running :class:`RunSummary` + a bounded
    trailing ``EpochStats`` window.

    ``keep_epochs=True`` (the engine default) retains the full list — the
    historical ``RunStats.epochs`` surface, memory O(E).  With
    ``keep_epochs=False`` only the trailing ``window`` epochs survive
    (``RunStats.epochs`` becomes that window; totals keep coming from the
    summary, byte-identical to the retained run).
    """

    def __init__(self, *, keep_epochs: bool = True, window: int = 64):
        self.summary = RunSummary()
        self.keep_epochs = keep_epochs
        self.window = int(window)
        self._epochs: "list[EpochStats] | collections.deque[EpochStats]"
        if keep_epochs:
            self._epochs = []
        else:
            self._epochs = collections.deque(maxlen=max(self.window, 0))

    def on_epoch(
        self, stats: "EpochStats", ctx: EpochContext | None = None
    ) -> None:
        s = self.summary
        s.n_epochs += 1
        s.n_txns += stats.n_txns
        s.committed += stats.committed
        s.aborted += stats.aborted
        s.read_aborts += stats.read_aborts
        s.ww_aborts += stats.ww_aborts
        s.wall_ms += stats.wall_ms
        s.wan_bytes += stats.wan_bytes
        s.sync_overlap_ms += stats.sync_overlap_ms
        s.pipeline_overlap_ms += stats.pipeline_overlap_ms
        s.filter_cpu_ms += stats.filter_cpu_ms
        if stats.filter_stats is not None:
            s.filter_stats = s.filter_stats.merge(stats.filter_stats)
        sync = stats.sync_ms
        s.sync_ms_sum += sync
        s.sync_ms_sumsq += sync * sync
        if sync > s.sync_ms_max:
            s.sync_ms_max = sync
        if stats.wall_ms > s.wall_ms_max:
            s.wall_ms_max = stats.wall_ms
        s.view_lag_mean_sum += stats.view_lag_mean
        if stats.view_lag_max > s.view_lag_max:
            s.view_lag_max = stats.view_lag_max
        self._epochs.append(stats)

    @property
    def epochs(self) -> "list[EpochStats]":
        """The retained epochs: everything (``keep_epochs=True``) or the
        trailing window."""
        return list(self._epochs)
