"""GeoCoCo core: the paper's contribution (Planner / Filter / Communicator).

Public API:

* Planner  — :mod:`repro.core.planner` (MILP + k-center grouping, k* model,
  damped replanning), :mod:`repro.core.monitor` (RTT probing, Vivaldi NCS).
* Filter   — :mod:`repro.core.whitedata` (task-preserving white-data removal),
  backed by :mod:`repro.core.occ` (epoch OCC) and :mod:`repro.core.crdt`
  (ACI delta-CRDT merge).
* Communicator — :mod:`repro.core.schedule` (hierarchical 3-phase rounds, TIV
  relays), :mod:`repro.core.simulator` (trace-driven WAN execution),
  :mod:`repro.core.replication` (end-to-end multi-master engine).
"""

from . import strategies
from .crdt import DeltaCRDTStore, Update, Version, merge_updates
from .latency import (
    AWS_REGIONS,
    GeoClusterSpec,
    LatencyTrace,
    all_pairs_shortest,
    aws_latency_matrix,
    bandwidth_matrix,
    geo_clustered_matrix,
    jitter_trace,
    one_relay_effective,
    tiv_fraction,
    tiv_pairs,
)
from .monitor import LatencyMonitor, VivaldiSystem
from .occ import (
    Txn,
    ValidationResult,
    committed_updates,
    txn_updates,
    validate_epoch,
    validate_epoch_detailed,
)
from .planner import (
    GroupPlan,
    Replanner,
    agglomerative_grouping,
    best_plan,
    hierarchical_comm_cost,
    k_search_band,
    kcenter_grouping,
    kmeans_grouping,
    milp_grouping,
    no_grouping,
    optimal_k,
    plan_cost,
    random_grouping,
)
from .replication import EngineConfig, EpochStats, GeoCluster, RaftCluster, RunStats
from .schedule import (
    StitchState,
    Transfer,
    TransmissionSchedule,
    all_to_all_schedule,
    hierarchical_schedule,
    leader_schedule,
    max_messages_per_node,
    messages_per_node,
    stitch_schedules,
)
from .simulator import (
    EpochLatencyCycle,
    NicState,
    RoundResult,
    WANSimulator,
    epoch_commit_row,
    node_commit_ms,
)
from .sinks import EpochContext, EpochSink, RunAggregator, RunSummary
from .stream import EpochTimings, StreamingTimeline
from .whitedata import (
    FilterResult,
    FilterStats,
    filter_group_batch,
    no_filter,
    white_ratio,
)
from .workload import (
    TPCC_MIXES,
    DiurnalLoad,
    TPCCConfig,
    TPCCGenerator,
    YCSBConfig,
    YCSBGenerator,
    ZipfianSampler,
)
