"""Unified two-plane synchronization strategy registry.

The paper's three levers — latency-aware grouping (Sec 4.2), task-preserving
filtering (Sec 4.3), and consistency-guaranteed transmission (Sec 4.4) —
exist in two planes of this repo:

* the **WAN-simulation plane** (``repro.core``): transaction write-set
  synchronization over a simulated geo-distributed WAN, and
* the **device plane** (``repro.dist``): gradient synchronization over the
  ``pod`` axis of a JAX mesh, where the pod boundary is the WAN analogue.

Both planes register their strategies here by ``(kind, name)`` so that new
scenarios (a Raft plane, multi-cloud topologies, new filter codecs) plug in
without editing ``replication.py`` or ``train_step.py``.  Registered kinds:

============  ===============================================================
kind          contract of a registered entry
============  ===============================================================
planner       ``fn(lat, k, *, tiv=False, tiv_margin=0.05, time_limit_s=5.0)
              -> GroupPlan`` — grouping strategy (Sec 4.2 / Fig. 12)
schedule      schedule builder; see :mod:`repro.core.schedule` for the
              per-builder signatures (``all_to_all`` / ``hierarchical`` /
              ``leader``)
filter        ``fn(txns, snapshot, **opts) -> FilterResult`` — aggregator-
              side white-data removal (Sec 4.3)
device_sync   :class:`DeviceSyncStrategy` — gradient exchange over the
              mesh ``pod`` axis (``repro.dist.collectives``)
wan_sync      :class:`WanSyncStrategy` — named preset binding the engine's
              grouping/filtering/tiv/compression stages together
============  ===============================================================

Names are intentionally shared across planes: ``flat`` / ``hier`` /
``geococo`` mean the same thing to ``EngineConfig`` (WAN plane) and
``SyncConfig`` (device plane).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

__all__ = [
    "register",
    "get",
    "names",
    "kinds",
    "items",
    "WanSyncStrategy",
    "wan_strategy_name",
]


_REGISTRY: dict[str, dict[str, Any]] = {}


def register(kind: str, name: str, obj: Any = None):
    """Register ``obj`` under ``(kind, name)``.

    Usable directly (``register("filter", "none", fn)``) or as a decorator
    (``@register("planner", "milp")``).  Re-registering a name replaces the
    previous entry (last one wins — lets downstream code override presets).
    """
    if obj is None:

        def deco(f):
            _REGISTRY.setdefault(kind, {})[name] = f
            return f

        return deco
    _REGISTRY.setdefault(kind, {})[name] = obj
    return obj


def get(kind: str, name: str) -> Any:
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        known = sorted(_REGISTRY.get(kind, {}))
        raise KeyError(
            f"no {kind!r} strategy named {name!r}; registered: {known}"
        ) from None


def names(kind: str) -> list[str]:
    return sorted(_REGISTRY.get(kind, {}))


def kinds() -> list[str]:
    return sorted(_REGISTRY)


def items(kind: str) -> Iterator[tuple[str, Any]]:
    yield from sorted(_REGISTRY.get(kind, {}).items())


# ---------------------------------------------------------------------------
# WAN-plane named presets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WanSyncStrategy:
    """One named configuration of the engine's synchronization stages.

    ``schedule`` / ``filter`` are names resolved through this registry at
    engine-construction time, so a preset can point at a custom builder
    without the engine knowing about it.
    """

    name: str
    grouping: bool
    filtering: bool
    tiv: bool
    compression: bool = False
    schedule: str = "hierarchical"
    filter: str = "whitedata"

    def describe(self) -> str:
        stages = [
            "grouping" if self.grouping else "flat",
            f"filter:{self.filter}" if self.filtering else "no-filter",
            "tiv" if self.tiv else "no-tiv",
        ]
        if self.compression:
            stages.append("zlib")
        return f"{self.name}({', '.join(stages)})"


register(
    "wan_sync",
    "flat",
    WanSyncStrategy("flat", grouping=False, filtering=False, tiv=False,
                    schedule="all_to_all", filter="none"),
)
register(
    "wan_sync",
    "hier",
    WanSyncStrategy("hier", grouping=True, filtering=False, tiv=False,
                    filter="none"),
)
register(
    "wan_sync",
    "geococo",
    WanSyncStrategy("geococo", grouping=True, filtering=True, tiv=True),
)
register(
    "wan_sync",
    "geococo-zlib",
    WanSyncStrategy("geococo-zlib", grouping=True, filtering=True, tiv=True,
                    compression=True),
)


def wan_strategy_name(
    *, grouping: bool, filtering: bool, tiv: bool, compression: bool
) -> str:
    """Faithful name for a legacy-boolean ``EngineConfig``.

    The structural base (``flat`` / ``hier`` / ``geococo[-zlib]``) comes
    from grouping/filtering/compression; when the remaining stages differ
    from the registered preset, a ``+stage``/``-stage`` modifier is
    appended (the planner's ``milp+tiv`` idiom), so the name never claims a
    preset whose stages the config does not run.  Modified names are *not*
    registered — round-tripping one through ``EngineConfig(sync_strategy=)``
    fails loudly rather than silently changing the config.  ``tiv`` only
    matters under grouping (the flat round has no relay hop) and is ignored
    otherwise.
    """
    if not grouping:
        base = "flat"
    elif not filtering:
        base = "hier"
    else:
        base = "geococo-zlib" if compression else "geococo"
    spec = get("wan_sync", base)
    if grouping and tiv != spec.tiv:
        base += "+tiv" if tiv else "-tiv"
    if compression != spec.compression:
        base += "+zlib" if compression else "-zlib"
    return base
