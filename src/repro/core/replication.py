"""Multi-master epoch replication engine (GeoGauss-like) + Raft-plane model.

This is the end-to-end database plane the macro benchmarks (paper Fig. 11,
14, 17, 18, Table 1) run on.  Per epoch (default cadence 10 ms, the GeoGauss
setting):

1. every replica executes its transaction batch locally (OCC, Sec 4.3),
2. write sets are synchronized — flat all-to-all (baseline) or GeoCoCo's
   hierarchical schedule with aggregator-side white-data filtering,
3. deterministic global validation commits the epoch and all replicas merge
   the committed deltas (CRDT join), producing identical state everywhere.

Throughput model — two regimes:

* **formula pipelining** (``EngineConfig.streaming=False``, the historical
  model): epochs overlap only arithmetically — the epoch wall-clock is
  ``max(epoch_cadence, execution, synchronization)`` (execution of epoch
  e+1 is assumed to hide under the synchronization of epoch e), and
  synchronization becomes the bottleneck exactly when WAN latency/bandwidth
  dominate (Fig. 3).
* **streaming simulation** (``streaming=True``): consecutive epochs' DAGs
  are *stitched* (:func:`~repro.core.schedule.stitch_schedules`) — epoch
  e+1's gathers out of node s depend only on s's epoch-e commit, per-node
  transaction execution and the epoch cadence ride the DAG as local compute
  stages — and one event-driven simulation measures real per-epoch commit
  times.  Epoch e+1's gathers genuinely stream under epoch e's scatters
  (they ride disjoint NIC directions), as GeoGauss streams multi-master
  state; ``EpochStats.wall_ms`` is the measured inter-commit gap and
  ``pipeline_overlap_ms`` is what the formula would have charged on top.
  Commit content is untouched (validation still waits for every epoch
  write set), so digests are byte-identical across both regimes.

  ``EngineConfig(staleness_feedback=True)`` (streaming only) additionally
  feeds the measured timing back into the OCC outcome: each replica keeps
  its own snapshot view, advanced only when the stitched simulation has
  delivered that node's inbound epoch transfers, and transactions version
  their reads against the executing node's view — so a node paying off a
  WAN backlog executes epoch ``e`` against an epoch ``e-k`` snapshot and
  read-validation aborts become a function of network conditions
  (timing-dependent commit by design; digests may diverge from the
  default engines, see ``EpochStats.read_aborts`` / ``view_lag_mean``).

Within an epoch the synchronization itself is pipelined too (the default,
``EngineConfig.barrier=False``): write-set rounds execute as an event-driven
transfer DAG where each group's aggregator-side filter/compress CPU time is
charged on that group's exchange transfers — so one group's CPU overlaps
other groups' in-flight WAN transfers, and ``sync_ms`` is the DAG critical
path rather than the barrier phase-sum.  Epoch commit still waits for the
*full* DAG to sink (every transfer delivered), so the committed state is
byte-identical to the barrier engine — :class:`EpochStats` reports the
hidden work as ``sync_overlap_ms = sync_serial_ms - sync_ms``.
``EngineConfig(barrier=True)`` restores the pre-DAG barrier engine exactly,
for regression comparison.

The :class:`RaftCluster` models the CockroachDB integration (Sec 5
"Extensions"): leader-based AppendEntries fan-out, commit at majority quorum,
with GeoCoCo optionally relaying through group aggregators.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
import zlib as _zlib
from typing import Callable, Sequence

import numpy as np

from . import strategies as _strategies
from .crdt import DeltaCRDTStore, Update
from .occ import Txn, txn_updates, validate_epoch_detailed
from .planner import GroupPlan, Replanner, no_grouping
from .schedule import (
    TransmissionSchedule,
    all_to_all_schedule,
    hierarchical_schedule,
    leader_schedule,
    stitch_schedules,
)
from .simulator import EpochLatencyCycle, WANSimulator, node_commit_ms
from .sinks import EpochContext, EpochSink, RunAggregator, RunSummary
from .stream import StreamingTimeline
from .whitedata import FilterResult, FilterStats, filter_group_batch

# the serving plane lives above this engine (it consumes measured commit
# times, never feeds back into them); importing its config here keeps
# EngineConfig the single wiring surface, like staleness_feedback
from ..analysis.config_check import validate_config
from ..serve.config import ServeConfig
from ..serve.stats import ServeStats

__all__ = ["EngineConfig", "EpochStats", "RunStats", "GeoCluster",
           "RaftCluster", "advance_views"]


@dataclasses.dataclass
class EngineConfig:
    """Engine configuration with a named-strategy surface.

    ``sync_strategy`` names a registered ``wan_sync`` preset (``flat`` /
    ``hier`` / ``geococo`` / ``geococo-zlib`` — the same names the device
    plane's ``SyncConfig`` uses); when given it drives the per-stage
    booleans.  The booleans remain writable for back-compat (the original
    API) and for ablations without an exact preset — ``__post_init__``
    derives the nearest ``sync_strategy`` name from them.  ``schedule_name``
    and ``filter_name`` select registered implementations for the grouping
    transmission and the aggregator filter, so new builders and codecs plug
    in without touching this engine.
    """

    n_nodes: int
    epoch_ms: float = 10.0
    txn_exec_us: float = 40.0
    barrier: bool = False              # True = pre-DAG barrier-phase engine
    streaming: bool = False            # True = cross-epoch stitched simulation
    # feed measured per-node commit staleness back into the OCC abort model:
    # replicas execute each epoch against their *own* snapshot view, which
    # advances only when the stitched simulation delivered that node's
    # inbound epoch transfers — so read-set validation aborts become a
    # function of network conditions.  Timing-dependent commit by design:
    # the default (off) preserves the byte-identical-digest invariant
    # across barrier/event/streaming engines.
    staleness_feedback: bool = False
    # read serving plane (streaming only, default off): region-affine client
    # populations serve follower reads against the per-node stale views the
    # stitched simulation measures; results land on RunStats.serve.  Purely
    # observational — serving never changes which bytes commit, so digests
    # are unaffected.
    serve: ServeConfig | None = None
    # modeled bytes-proportional filter/compress CPU instead of measured
    # perf_counter wall-clock (opt-in): gated benchmarks whose metric rides
    # the simulated timeline (Fig16 stacking, abort-curve monotonicity)
    # become fully deterministic under harness load.  Rates are ns/byte of
    # filter input / compressor input respectively (zlib-6 streams at
    # ~60-70 MB/s on commodity cores -> ~15 ns/B; the filter's per-update
    # hash+version checks are ~2 ns/B).
    modeled_cpu: bool = False
    filter_cpu_ns_per_byte: float = 2.0
    compress_cpu_ns_per_byte: float = 15.0
    # how the streaming engine times the cross-epoch stream:
    # "incremental" (default) appends each epoch onto a StreamingTimeline
    # and simulates only the new events — O(E) total, byte-identical to the
    # full re-simulation by the bandwidth-admission finality argument;
    # "resim" keeps the O(E²) stitch-everything-and-rerun oracle
    # (repro.core.stream documents the identity argument; tests pin it).
    stream_mode: str = "incremental"
    # run-dataflow retention: keep_epochs=True (default) retains the full
    # per-epoch EpochStats list on RunStats.epochs (the historical surface);
    # keep_epochs=False caps RunStats.epochs at the trailing `stats_window`
    # epochs and the run-level totals come from the online RunSummary
    # instead (repro.core.sinks.RunAggregator) — byte-identical to the
    # retained path, memory O(window) instead of O(E).  A bounded run with
    # a serving plane needs ServeConfig(keep_epochs=False) too (rule table:
    # repro.analysis.config_check).
    keep_epochs: bool = True
    stats_window: int = 64
    # debug hook: statically verify every schedule the engine simulates
    # (repro.analysis.schedule_check.verify_schedule — acyclicity, phase
    # monotonicity along deps, clock-chain linearity, payload/node sanity)
    # before it runs.  O(V+E) per round; raises ScheduleVerificationError
    # on the first unsound DAG instead of silently mistiming it.
    verify_schedules: bool = False
    sync_strategy: str | None = None   # named wan_sync preset (overrides booleans)
    grouping: bool = True              # GeoCoCo hierarchical transmission
    filtering: bool = True             # white-data filter at aggregators
    tiv: bool = True                   # overlay relay exploitation
    tiv_margin: float = 0.05
    compression: bool = False          # zlib on WAN payloads (Fig 16)
    compression_level: int = 6
    schedule_name: str | None = None   # registered "schedule" builder
    filter_name: str | None = None     # registered "filter" implementation
    planner: str = "milp"              # registered "planner" strategy
    replan_threshold: float = 0.20
    replan_sustain: int = 3
    planner_time_limit_s: float = 10.0

    def __post_init__(self):
        # A named strategy drives the stage booleans (the shim direction);
        # nothing else is written back, so `dataclasses.replace` on the
        # booleans of a boolean-configured instance behaves as expected
        # (with sync_strategy set, the name wins on replace — by design;
        # ablate via the booleans or pass sync_strategy=None).
        # flag-compatibility constraints live in the declarative rule table
        # (repro.analysis.config_check) — one place for every flag, same
        # historical error messages
        validate_config(self)
        if self.sync_strategy is not None:
            spec = _strategies.get("wan_sync", self.sync_strategy)
            self.grouping = spec.grouping
            self.filtering = spec.filtering
            self.tiv = spec.tiv
            self.compression = spec.compression
        _strategies.get("planner", self.planner)      # fail fast on typos
        if self.schedule_name is not None:
            _strategies.get("schedule", self.schedule_name)
        if self.filter_name is not None:
            _strategies.get("filter", self.filter_name)

    @property
    def resolved_sync_strategy(self) -> str:
        if self.sync_strategy is not None:
            return self.sync_strategy
        return _strategies.wan_strategy_name(
            grouping=self.grouping, filtering=self.filtering,
            tiv=self.tiv, compression=self.compression,
        )

    @property
    def resolved_schedule_name(self) -> str:
        if self.schedule_name is not None:
            return self.schedule_name
        if self.sync_strategy is not None:
            return _strategies.get("wan_sync", self.sync_strategy).schedule
        return "hierarchical" if self.grouping else "all_to_all"

    @property
    def resolved_filter_name(self) -> str:
        if not self.filtering:
            return "none"
        if self.filter_name is not None:
            return self.filter_name
        if self.sync_strategy is not None:
            return _strategies.get("wan_sync", self.sync_strategy).filter
        return "whitedata"


@dataclasses.dataclass
class EpochStats:
    epoch: int
    n_txns: int
    committed: int
    aborted: int
    sync_ms: float                 # event engine: DAG critical path (CPU
    exec_ms: float                 # stages included where on the path);
    wall_ms: float                 # barrier engine: phase-sum makespan
    wan_bytes: float
    filter_stats: FilterStats | None
    filter_cpu_ms: float
    plan_method: str
    # critical-path vs overlapped split: sync_serial_ms is what a fully
    # serialized round would cost (barrier phase-sum + every group's
    # filter/compress CPU back-to-back), and sync_overlap_ms =
    # sync_serial_ms - sync_ms is the work the DAG hid — an exact identity
    # (no clamping: with bandwidth admission, event <= barrier + total CPU
    # is a theorem, so the overlap is never negative).  The barrier engine
    # doesn't model round CPU (pre-refactor semantics; see filter_cpu_ms),
    # so there serial == sync and overlap == 0 — the identity holds in
    # both engines.
    sync_serial_ms: float = 0.0
    sync_overlap_ms: float = 0.0
    # the honest split of sync_overlap_ms against the per-transfer compute
    # timeline: sync_cpu_hidden_ms is the filter/compress CPU that ran off
    # the critical path (hidden behind other groups' in-flight WAN traffic),
    # sync_wan_overlap_ms = sync_overlap_ms - sync_cpu_hidden_ms is pure
    # cross-stage WAN overlap (barrier waiting the DAG removed).  Before
    # this split, compute-dominated rounds reported filter-CPU savings as
    # "makespan slack" — the two are different resources.
    sync_cpu_hidden_ms: float = 0.0
    sync_wan_overlap_ms: float = 0.0
    # streaming engine only: wall_ms is the measured inter-commit gap in the
    # stitched multi-epoch simulation (stream_commit_ms is the absolute
    # commit time); pipeline_overlap_ms = max(epoch_ms, exec_ms, sync_ms) -
    # wall_ms is the wall-clock the cross-epoch pipeline saved vs the
    # formula model (negative for epochs paying off an inherited backlog).
    pipeline_overlap_ms: float = 0.0
    stream_commit_ms: float = 0.0
    # abort breakdown (validate_epoch_detailed): read_aborts failed the
    # read-validation rule (stale read versions — nonzero only under
    # staleness_feedback, where reads are versioned against per-node views),
    # ww_aborts lost a written key first-writer-wins.  The rules can overlap
    # (a txn may fail both), so read_aborts + ww_aborts >= aborted.
    read_aborts: int = 0
    ww_aborts: int = 0
    # staleness_feedback only: how many epochs each node's snapshot view
    # lagged the global state when this epoch's transactions executed
    # (mean/max over nodes; 0 = every replica executed against fresh state)
    view_lag_mean: float = 0.0
    view_lag_max: int = 0


@dataclasses.dataclass
class RunStats:
    """A run's report.  ``epochs`` is the retained per-epoch list — the full
    run under ``EngineConfig(keep_epochs=True)`` (the default), only the
    trailing ``stats_window`` under ``keep_epochs=False``.  The run-level
    totals below read ``summary`` (the :class:`~repro.core.sinks.RunSummary`
    the engine accumulated online, byte-identical to folding the full epochs
    list) when present and fall back to folding ``epochs`` when constructed
    directly without one.  ``makespans_ms`` / ``p99_sync_ms`` are inherently
    per-epoch arrays and always read ``epochs`` — under ``keep_epochs=False``
    they describe the retained window only (``summary.sync_ms_mean`` /
    ``.sync_ms_std`` / ``.sync_ms_max`` are the bounded-memory stand-ins).
    """

    epochs: list[EpochStats]
    msg_matrix: np.ndarray
    plan_time_s: float
    state_digest: str
    value_digest: str
    # the serving plane's report (EngineConfig(serve=...), streaming only);
    # None when the plane is off
    serve: ServeStats | None = None
    # online run-level totals (repro.core.sinks.RunSummary), set by
    # GeoCluster.run; None for hand-constructed instances
    summary: "RunSummary | None" = None

    @property
    def committed(self) -> int:
        if self.summary is not None:
            return self.summary.committed
        return sum(e.committed for e in self.epochs)

    @property
    def total_txns(self) -> int:
        if self.summary is not None:
            return self.summary.n_txns
        return sum(e.n_txns for e in self.epochs)

    @property
    def aborted(self) -> int:
        if self.summary is not None:
            return self.summary.aborted
        return sum(e.aborted for e in self.epochs)

    @property
    def read_aborts(self) -> int:
        """Transactions failing read-set validation (stale read versions)."""
        if self.summary is not None:
            return self.summary.read_aborts
        return sum(e.read_aborts for e in self.epochs)

    @property
    def ww_aborts(self) -> int:
        """Transactions losing a written key first-writer-wins."""
        if self.summary is not None:
            return self.summary.ww_aborts
        return sum(e.ww_aborts for e in self.epochs)

    @property
    def abort_rate(self) -> float:
        t = self.total_txns
        return self.aborted / t if t else 0.0

    @property
    def read_abort_rate(self) -> float:
        t = self.total_txns
        return self.read_aborts / t if t else 0.0

    @property
    def wall_s(self) -> float:
        if self.summary is not None:
            return self.summary.wall_ms / 1e3
        return sum(e.wall_ms for e in self.epochs) / 1e3

    @property
    def throughput_tps(self) -> float:
        w = self.wall_s
        return self.committed / w if w > 0 else 0.0

    @property
    def wan_bytes(self) -> float:
        if self.summary is not None:
            return self.summary.wan_bytes
        return sum(e.wan_bytes for e in self.epochs)

    @property
    def makespans_ms(self) -> np.ndarray:
        """Per-epoch DAG critical paths — of the *retained* epochs only
        (the trailing window under ``keep_epochs=False``)."""
        return np.array([e.sync_ms for e in self.epochs], dtype=float)

    @property
    def white_stats(self) -> FilterStats:
        if self.summary is not None:
            return self.summary.filter_stats
        out = FilterStats()
        for e in self.epochs:
            if e.filter_stats is not None:
                out = out.merge(e.filter_stats)
        return out

    @property
    def p99_sync_ms(self) -> float:
        """p99 of :attr:`makespans_ms` — window-limited under
        ``keep_epochs=False``; use ``summary.sync_ms_max`` for a bounded-
        memory whole-run bound."""
        ms = self.makespans_ms
        if ms.size == 0:
            return 0.0
        return float(np.percentile(ms, 99))

    @property
    def overlap_ms(self) -> float:
        """Total CPU/WAN work hidden by the pipelined transmission DAG."""
        if self.summary is not None:
            return self.summary.sync_overlap_ms
        return sum(e.sync_overlap_ms for e in self.epochs)

    @property
    def pipeline_overlap_ms(self) -> float:
        """Total wall-clock the streaming cross-epoch pipeline saved vs the
        ``max(epoch, exec, sync)`` formula (0.0 for non-streaming runs)."""
        if self.summary is not None:
            return self.summary.pipeline_overlap_ms
        return sum(e.pipeline_overlap_ms for e in self.epochs)


@dataclasses.dataclass
class _EpochRound:
    """The timing-independent product of one epoch: the schedule to time,
    the commit outcome, and the planning/filtering context the stats need.
    (The epoch's latency matrix is *not* here — it is always
    ``trace[epoch % len(trace)]``, and retaining a copy per round held E
    duplicated matrices alive; see :class:`~repro.core.simulator.
    EpochLatencyCycle`.)"""

    epoch: int
    schedule: TransmissionSchedule
    n_txns: int
    committed: int
    aborted: int
    read_aborts: int
    ww_aborts: int
    ups: list[Update]
    exec_ms: float
    node_exec_ms: np.ndarray
    filter_cpu_ms: float
    fstats: FilterStats | None
    plan_method: str
    modeled_cpu_ms: float


def _compressed_size(updates: Sequence[Update], level: int) -> int:
    blob = b"".join(u.key.encode() + u.value for u in updates)
    if not blob:
        return 0
    return len(_zlib.compress(blob, level)) + 24 * len(updates)


def _batch_bytes(updates: Sequence[Update]) -> int:
    return sum(u.nbytes for u in updates)


def advance_views(
    n_nodes: int,
    views: list[DeltaCRDTStore],
    view_next: np.ndarray,
    pending_ups: dict[int, list[Update]],
    commit_at: Callable[[int, int], float],
    n_done: int,
    now_ms: float,
) -> None:
    """Merge every epoch the stitched simulation has delivered to each
    node by ``now_ms`` into that node's snapshot view.  Views advance a
    contiguous epoch prefix (a node merges epoch k only once its k-th
    inbound transfers have all delivered — the same per-node commit
    dependency ``stitch_schedules`` gates sends on).

    ``commit_at(k, i)`` reads the measured commit time of epoch ``k`` at
    node ``i`` for ``k < n_done`` (a point read so the caller may store
    the matrix in an evicting window); ``pending_ups`` maps epoch ->
    committed updates and is the *retention frontier's* backing store —
    entries every view has merged past (``< view_next.min()``) are
    released here, because no view will ever request them again.

    This is the frontier logic the eviction-safety theorem is about, so it
    lives at module level where both the engine (``GeoCluster``) and the
    model checker (:mod:`repro.analysis.modelcheck`) drive the *same*
    code."""
    for i in range(n_nodes):
        nxt = int(view_next[i])
        while nxt < n_done and commit_at(nxt, i) <= now_ms + 1e-9:
            views[i].apply_many(pending_ups[nxt])
            nxt += 1
        view_next[i] = nxt
    floor = int(view_next.min()) if len(view_next) else 0
    for k in [k for k in pending_ups if k < floor]:
        del pending_ups[k]


class GeoCluster:
    """Full-replica multi-master cluster over a simulated WAN."""

    def __init__(
        self,
        cfg: EngineConfig,
        *,
        control=None,
        bandwidth_mbps: np.ndarray | float = np.inf,
        loss: np.ndarray | float = 0.0,
        wan_mask: np.ndarray | None = None,
        seed: int = 0,
    ):
        """``wan_mask`` (bool n x n): which links are WAN; when given,
        per-epoch ``wan_bytes`` counts only those links — matching the
        paper's NIC-level inter-region egress measurement (Sec 6.1).  Cheap
        intra-region LAN traffic (the gather/scatter phases) is excluded,
        exactly as in the paper's bandwidth-utilization methodology.

        ``control`` is a ``repro.control.ControlPlane``; the engine no
        longer constructs a private Replanner — it pushes each epoch's
        latency matrix through the plane and takes the (damped) plan back,
        so every other subscriber (e.g. a device-plane Trainer sharing the
        instance) observes the same ``PlanChanged`` events.  When omitted,
        the engine builds its own plane from the config's replan
        parameters."""
        self.cfg = cfg
        self.bandwidth = bandwidth_mbps
        self.loss = loss
        self.wan_mask = wan_mask
        self.store = DeltaCRDTStore()  # replicated state (identical on all nodes)
        self.rng = np.random.default_rng(seed)
        # strategy resolution happens once, through the two-plane registry:
        # the engine never hard-codes a builder or filter implementation
        self._schedule_fn = _strategies.get("schedule", cfg.resolved_schedule_name)
        self._flat_schedule_fn = _strategies.get("schedule", "all_to_all")
        self._filter_fn = _strategies.get("filter", cfg.resolved_filter_name)
        # registry-dependent contract rules (grouping-engine builder
        # signature, flat engine runs all_to_all by definition) — fail
        # fast at attach, not mid-run; the rules themselves live in the
        # declarative config_check table
        validate_config(cfg, stage="cluster")
        self._schedule_takes_compute = False
        if cfg.grouping:
            # pipelined engine: builders that accept group_compute_ms get the
            # per-group filter/compress CPU charged on their exchange edges
            import inspect

            params = inspect.signature(self._schedule_fn).parameters
            self._schedule_takes_compute = "group_compute_ms" in params
        self.plan_time_s = 0.0
        self._payload_ewma = 0.0   # observed per-node epoch payload (bytes)
        self._keep_ewma = 1.0      # observed post-filter keep ratio
        self.control = self._wire_control(control)
        self.msg_matrix = np.zeros((cfg.n_nodes, cfg.n_nodes), dtype=int)

    def _wire_control(self, control):
        """Attach to (or build) the network control plane.

        The engine contributes its bandwidth/payload-aware plan ranking to
        the plane — but only when no better-informed planner is already
        bound (``bind_planner`` keeps the first non-default planner on a
        shared instance)."""
        from ..control.plane import ControlPlane

        cfg = self.cfg
        if control is None:
            control = ControlPlane(
                replan_threshold=cfg.replan_threshold,
                replan_sustain=cfg.replan_sustain,
                tiv=cfg.tiv,
                tiv_margin=cfg.tiv_margin,
            )
        control.bind_planner(self._plan_fn)
        return control

    def _plan_fn(self, lat: np.ndarray) -> GroupPlan:
        """Bandwidth/payload-aware plan ranking (Sec 4.1 "balance latency
        and resource utilization"), fed by per-epoch payload observations."""
        from .planner import best_plan

        cfg = self.cfg
        t0 = time.perf_counter()
        plan = best_plan(
            lat,
            tiv=cfg.tiv,
            tiv_margin=cfg.tiv_margin,
            method=cfg.planner,
            time_limit_s=cfg.planner_time_limit_s,
            payload_bytes=self._payload_ewma or None,
            bandwidth_mbps=self.bandwidth,
            filter_keep=self._keep_ewma if cfg.filtering else 1.0,
            barrier=cfg.barrier,  # rank plans by the makespan we will execute
            streaming=cfg.streaming,  # ... incl. cross-epoch pipelining
        )
        self.plan_time_s += time.perf_counter() - t0
        return plan

    @property
    def _replanner(self) -> Replanner:
        """Deprecated: the engine no longer owns a private Replanner."""
        warnings.warn(
            "GeoCluster._replanner is deprecated; use GeoCluster.control "
            "(a repro.control.ControlPlane) — e.g. control.plan, "
            "control.replan_count, control.on_node_failure()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.control.replanner

    # -- one epoch -------------------------------------------------------------

    def _prepare_epoch(
        self,
        epoch: int,
        txns_by_node: dict[int, list[Txn]],
        lat: np.ndarray,
        views: Sequence[DeltaCRDTStore] | None = None,
    ) -> "_EpochRound":
        """Everything timing-independent about one epoch: planning, filtering,
        schedule construction, deterministic validation and the CRDT commit.
        The simulator never touches the store, so commit content is identical
        whichever engine (barrier / event / streaming) later times the round.

        ``views`` (staleness_feedback only) are the per-node snapshot views;
        when given, each group's aggregator filters against *its own* view
        instead of the globally-merged store — a backlogged aggregator holds
        smaller versions, so its stale/null-effect rules fire less and filter
        efficacy degrades with network conditions (the rules stay sound: a
        version stale against an older snapshot is stale against any newer
        one).  Validation always runs against the globally-merged snapshot —
        every replica holds the full epoch's metadata by commit time.
        """
        cfg = self.cfg
        n = cfg.n_nodes
        snapshot = self.store  # epoch-start replicated snapshot

        all_txns = [t for ts in txns_by_node.values() for t in ts]
        n_txns = len(all_txns)
        node_exec_ms = np.array(
            [len(txns_by_node.get(i, [])) * cfg.txn_exec_us / 1e3
             for i in range(n)],
            dtype=float,
        )
        exec_ms = float(node_exec_ms.max()) if n else 0.0

        filter_cpu_ms = 0.0
        fstats: FilterStats | None = None

        if cfg.grouping:
            node_payload = np.zeros(n)
            for node, ts in txns_by_node.items():
                node_payload[node] = sum(
                    u.nbytes for t in ts for u in txn_updates(t)
                )
            # the bandwidth-aware planner needs the payload estimate *before*
            # the (damped) plan request, or the first latency-only plan
            # would persist until a latency deviation
            mean_payload = float(np.mean(node_payload)) if n else 0.0
            self._payload_ewma = (
                0.7 * self._payload_ewma + 0.3 * mean_payload
                if self._payload_ewma
                else mean_payload
            )
            plan = self.control.observe(lat)
            # Validation metadata (read/write sets) always flows globally, as
            # in GeoGauss; filtering strips white-data *payloads* only.  The
            # commit outcome is therefore bit-identical to the baseline.
            surviving = all_txns
            group_payload = np.zeros(plan.k)
            # per-group aggregator CPU (filter + compression) — the pipelined
            # DAG charges it on that group's exchange transfers so it overlaps
            # other groups' in-flight WAN traffic
            group_cpu_ms = np.zeros(plan.k)
            fstats = FilterStats()
            for j, (group, agg) in enumerate(zip(plan.groups, plan.aggregators)):
                gtxns = [t for i in group for t in txns_by_node.get(i, [])]
                # the aggregator filters against the state *it* holds: its
                # own (possibly stale) view under staleness_feedback, the
                # globally-merged store otherwise
                fsnap = snapshot if views is None else views[agg]
                t0 = time.perf_counter()
                fr = self._filter_fn(gtxns, fsnap)
                if cfg.filtering:
                    # the no_filter passthrough's byte accounting is not a
                    # filtering cost — keep the baseline's filter CPU at 0
                    if cfg.modeled_cpu:
                        dt_ms = (
                            fr.stats.total_bytes
                            * cfg.filter_cpu_ns_per_byte / 1e6
                        )
                    else:
                        dt_ms = (time.perf_counter() - t0) * 1e3
                    filter_cpu_ms += dt_ms
                    group_cpu_ms[j] += dt_ms
                fstats = fstats.merge(fr.stats)
                dropped = fr.stats.total_updates - fr.stats.kept_updates
                if cfg.compression:
                    t0 = time.perf_counter()
                    group_payload[j] = _compressed_size(
                        fr.kept, cfg.compression_level
                    ) + 24 * dropped
                    if cfg.modeled_cpu:
                        group_cpu_ms[j] += (
                            sum(u.nbytes for u in fr.kept)
                            * cfg.compress_cpu_ns_per_byte / 1e6
                        )
                    else:
                        group_cpu_ms[j] += (time.perf_counter() - t0) * 1e3
                else:
                    group_payload[j] = fr.stats.wire_bytes
            if cfg.compression:
                node_payload = np.array(
                    [
                        _compressed_size(
                            [u for t in txns_by_node.get(i, []) for u in txn_updates(t)],
                            cfg.compression_level,
                        )
                        for i in range(n)
                    ],
                    dtype=float,
                )
            sched_kw = {}
            modeled_cpu_ms = 0.0
            if self._schedule_takes_compute and not cfg.barrier:
                sched_kw["group_compute_ms"] = group_cpu_ms
                # only CPU the DAG actually charges may count as "hidden"
                # in the serialized reference below
                modeled_cpu_ms = float(group_cpu_ms.sum())
            schedule = self._schedule_fn(
                plan,
                node_payload,
                group_payload_bytes=group_payload,
                lat=lat,
                tiv=cfg.tiv,
                tiv_margin=cfg.tiv_margin,
                **sched_kw,
            )
            plan_method = plan.method
        else:
            surviving = all_txns
            payload = np.array(
                [
                    (
                        _compressed_size(
                            [u for t in txns_by_node.get(i, []) for u in txn_updates(t)],
                            cfg.compression_level,
                        )
                        if cfg.compression
                        else sum(
                            u.nbytes
                            for t in txns_by_node.get(i, [])
                            for u in txn_updates(t)
                        )
                    )
                    for i in range(n)
                ],
                dtype=float,
            )
            schedule = self._flat_schedule_fn(n, payload)
            plan_method = "none"
            modeled_cpu_ms = 0.0

        # feed filter observations to the bandwidth-aware planner
        if cfg.grouping and cfg.filtering and fstats is not None and fstats.total_bytes:
            keep = fstats.wire_bytes / fstats.total_bytes
            self._keep_ewma = 0.7 * self._keep_ewma + 0.3 * keep

        # deterministic global validation over surviving txns, then CRDT
        # merge.  Epoch commit sinks the *full* DAG (every transfer
        # delivered) — the engines change when bytes move, never which
        # bytes commit, so this is timing-independent.  Validation always
        # runs against the globally-merged epoch-start snapshot (every
        # replica holds the full epoch's write/read metadata by commit
        # time); under staleness_feedback the *read versions* inside the
        # transactions came from per-node views, which is what arms the
        # read rule.
        vres = validate_epoch_detailed(surviving, snapshot)
        ups = [
            u for t in surviving if t.txn_id in vres.committed
            for u in txn_updates(t)
        ]
        pre_aborted = n_txns - len(surviving)
        committed = len(vres.committed)
        self.store.apply_many(ups)

        return _EpochRound(
            epoch=epoch,
            schedule=schedule,
            n_txns=n_txns,
            committed=committed,
            aborted=pre_aborted + len(vres.aborted),
            read_aborts=len(vres.read_aborted),
            ww_aborts=len(vres.ww_aborted),
            ups=ups,
            exec_ms=exec_ms,
            node_exec_ms=node_exec_ms,
            filter_cpu_ms=filter_cpu_ms,
            fstats=fstats,
            plan_method=plan_method,
            modeled_cpu_ms=modeled_cpu_ms,
        )

    def _epoch_stats(
        self,
        rnd: "_EpochRound",
        sim: WANSimulator,
        res,
        *,
        wall_ms: float | None = None,
        pipeline_overlap_ms: float = 0.0,
        stream_commit_ms: float = 0.0,
        view_lag_mean: float = 0.0,
        view_lag_max: int = 0,
    ) -> EpochStats:
        """Assemble one epoch's stats from its (isolated) round simulation."""
        cfg = self.cfg
        schedule = rnd.schedule
        if cfg.barrier:
            # the barrier engine doesn't model CPU inside the round at all
            # (pre-refactor semantics; filter_cpu_ms reports it separately),
            # so serial == sync and nothing is hidden
            sync_serial_ms = res.makespan_ms
            sync_overlap_ms = 0.0
            cpu_hidden_ms = 0.0
            wan_overlap_ms = 0.0
        else:
            # serialized reference: barrier phase-sum + back-to-back CPU
            # (only the CPU the DAG modeled — phase-sum only, no second
            # full simulation).  The identity serial == sync + overlap is
            # exact: with bandwidth admission, event <= barrier + total CPU
            # is a theorem, so no clamping is needed.
            sync_serial_ms = sim.barrier_makespan_ms(schedule) + rnd.modeled_cpu_ms
            sync_overlap_ms = sync_serial_ms - res.makespan_ms
            # honest CPU/WAN split against the per-transfer timeline: CPU
            # "on the path" is compute that actually gated a critical-path
            # transfer's wire start (the gap between its dependencies
            # sinking and the wire), everything else was hidden behind
            # other groups' in-flight transfers
            cpu_on_path_ms = 0.0
            for i in res.critical_path:
                t = schedule.transfers[i]
                if t.compute_ms <= 0.0:
                    continue
                ready = max((float(res.finish_ms[d]) for d in t.deps),
                            default=0.0)
                gap = max(float(res.start_ms[i]) - ready, 0.0)
                cpu_on_path_ms += min(t.compute_ms, gap)
            cpu_hidden_ms = max(rnd.modeled_cpu_ms - cpu_on_path_ms, 0.0)
            wan_overlap_ms = sync_overlap_ms - cpu_hidden_ms
        if self.wan_mask is not None:
            wan_bytes = float((res.link_bytes * self.wan_mask).sum())
        else:
            wan_bytes = res.total_bytes
        if wall_ms is None:
            wall_ms = max(cfg.epoch_ms, rnd.exec_ms, res.makespan_ms)
        return EpochStats(
            epoch=rnd.epoch,
            n_txns=rnd.n_txns,
            committed=rnd.committed,
            aborted=rnd.aborted,
            sync_ms=res.makespan_ms,
            exec_ms=rnd.exec_ms,
            wall_ms=wall_ms,
            wan_bytes=wan_bytes,
            filter_stats=rnd.fstats,
            filter_cpu_ms=rnd.filter_cpu_ms,
            plan_method=rnd.plan_method,
            sync_serial_ms=sync_serial_ms,
            sync_overlap_ms=sync_overlap_ms,
            sync_cpu_hidden_ms=cpu_hidden_ms,
            sync_wan_overlap_ms=wan_overlap_ms,
            pipeline_overlap_ms=pipeline_overlap_ms,
            stream_commit_ms=stream_commit_ms,
            read_aborts=rnd.read_aborts,
            ww_aborts=rnd.ww_aborts,
            view_lag_mean=view_lag_mean,
            view_lag_max=view_lag_max,
        )

    def run_epoch(
        self,
        epoch: int,
        txns_by_node: dict[int, list[Txn]],
        lat: np.ndarray,
    ) -> EpochStats:
        cfg = self.cfg
        rnd = self._prepare_epoch(epoch, txns_by_node, lat)
        sim = WANSimulator(lat, self.bandwidth, loss=self.loss, rng=self.rng,
                           barrier=cfg.barrier, verify=cfg.verify_schedules)
        res = sim.run(rnd.schedule)
        self.msg_matrix += res.msg_matrix
        return self._epoch_stats(rnd, sim, res)

    # -- full run ----------------------------------------------------------------

    def run(
        self,
        generator,
        trace,
        *,
        txns_per_node: int = 20,
        n_epochs: int | None = None,
    ) -> RunStats:
        cfg = self.cfg
        n_epochs = n_epochs if n_epochs is not None else len(trace)
        # every run path pushes its finalized EpochStats through the
        # aggregator sink the moment the epoch's numbers are final; the
        # retained list and the online summary both come from it
        agg = RunAggregator(keep_epochs=cfg.keep_epochs,
                            window=cfg.stats_window)
        serve_stats = None
        if cfg.streaming:
            serve_stats = self._run_streaming(
                generator, trace, txns_per_node, n_epochs, agg
            )
        else:
            for e in range(n_epochs):
                lat = trace[e % len(trace)]
                txns = generator.epoch_txns(e, txns_per_node, snapshot=self.store)
                agg.on_epoch(self.run_epoch(e, txns, lat))
        return RunStats(
            epochs=agg.epochs,
            msg_matrix=self.msg_matrix.copy(),
            plan_time_s=self.plan_time_s,
            state_digest=self.store.digest(),
            value_digest=self.store.digest(values_only=True),
            serve=serve_stats,
            summary=agg.summary,
        )

    def _stream_prefix(self, rounds: list["_EpochRound"], lats):
        """Stitch the epochs prepared so far and run the streaming event
        simulation over them.  ``lats`` indexes each epoch's latency matrix
        (an :class:`~repro.core.simulator.EpochLatencyCycle`).  Returns
        (per-node commit-time matrix, stream RoundResult, stitched schedule).

        This is the O(E²) reference oracle (``stream_mode="resim"``): with
        feedback it re-simulates the whole prefix every epoch.  The default
        ``stream_mode="incremental"`` appends onto a
        :class:`~repro.core.stream.StreamingTimeline` instead, with
        byte-identical timings (tested against this method)."""
        cfg = self.cfg
        stitched = stitch_schedules(
            [r.schedule for r in rounds],
            node_exec_ms=[r.node_exec_ms for r in rounds],
            epoch_ms=cfg.epoch_ms,
            n=cfg.n_nodes,
        )
        stream_sim = WANSimulator(lats[0], self.bandwidth,
                                  loss=self.loss, rng=self.rng,
                                  verify=cfg.verify_schedules)
        stream = stream_sim.run(stitched, lats=lats)
        commits = node_commit_ms(stitched, stream, cfg.n_nodes, len(rounds))
        return commits, stream, stitched

    def _advance_views(
        self,
        views: list[DeltaCRDTStore],
        view_next: np.ndarray,
        pending_ups: dict[int, list[Update]],
        commit_at: Callable[[int, int], float],
        n_done: int,
        now_ms: float,
    ) -> None:
        advance_views(self.cfg.n_nodes, views, view_next, pending_ups,
                      commit_at, n_done, now_ms)

    def _run_streaming(
        self, generator, trace, txns_per_node: int, n_epochs: int,
        agg: RunAggregator,
    ) -> ServeStats | None:
        """Cross-epoch streaming: stitch every epoch's DAG and measure real
        per-epoch commit times from one event-driven simulation.

        The per-epoch loop still runs each round in isolation — that
        simulation is the reference the stats are split against (sync_ms,
        the serial/overlap split, byte accounting) and what
        ``pipeline_overlap_ms`` compares the measured wall-clock to.
        Commits are processed inside the loop exactly as in the
        non-streaming engine, so with ``staleness_feedback=False`` the
        final digests are byte-identical.

        With ``staleness_feedback=True`` the loop closes the timing -> OCC
        feedback: transactions of epoch ``e`` execute optimistically when
        they *arrive* (``e * epoch_ms`` — GeoGauss executes at cadence, it
        does not stall the CPU on remote state) against the executing
        node's snapshot view, which advances only as the stitched
        simulation delivers that node's inbound epoch transfers.  A node
        paying off a WAN backlog therefore versions its reads against an
        epoch ``e-k`` snapshot, and the read-validation rule aborts exactly
        the transactions whose reads the backlog made stale — abort rate
        becomes a function of network conditions.  (Write-set *sends*
        remain gated on the node's previous-epoch commit, as in the
        stitched timing DAG: execution is optimistic, transmission stays
        ordered.)

        The stream is timed incrementally by default
        (``stream_mode="incremental"``): each epoch appends onto a
        :class:`~repro.core.stream.StreamingTimeline` that simulates only
        the new events — with bandwidth admission an earlier epoch's
        measured times are unaffected by later arrivals, so the prefix
        times are final and the incremental timings are byte-identical to
        re-simulating the whole prefix (``stream_mode="resim"``, the O(E²)
        reference oracle).  That same finality is what makes the
        incremental path a *bounded-memory pipeline*: each epoch's
        ``EpochStats`` is assembled eagerly and pushed through the attached
        :class:`~repro.core.sinks.EpochSink`\\ s (the run aggregator, the
        serving plane's :class:`~repro.serve.plane.ServingSink`), per-round
        simulators and results are dropped on the spot, committed updates
        are retained only until the slowest view merges past them
        (``view_next.min()``), and the timeline's commit window is evicted
        at the same frontier.  The resim oracle necessarily retains the
        full prefix (it re-simulates it) and keeps the historical batch
        shape.
        """
        if self.cfg.stream_mode == "incremental":
            return self._run_streaming_incremental(
                generator, trace, txns_per_node, n_epochs, agg
            )
        return self._run_streaming_resim(
            generator, trace, txns_per_node, n_epochs, agg
        )

    def _run_streaming_incremental(
        self, generator, trace, txns_per_node: int, n_epochs: int,
        agg: RunAggregator,
    ) -> ServeStats | None:
        """The O(E)-time, frontier-bounded-memory streaming path (see
        :meth:`_run_streaming`)."""
        cfg = self.cfg
        feedback = cfg.staleness_feedback
        lat_cycle = EpochLatencyCycle(trace, max(n_epochs, 1))
        timeline = StreamingTimeline(
            cfg.n_nodes, bandwidth_mbps=self.bandwidth, loss=self.loss,
            epoch_ms=cfg.epoch_ms, verify=cfg.verify_schedules,
        )
        serve_sink = None
        sinks: list[EpochSink] = [agg]
        if cfg.serve is not None:
            from ..serve.plane import ServingSink

            serve_sink = ServingSink(cfg.serve, cfg.n_nodes, cfg.epoch_ms)
            sinks.append(serve_sink)
        views = view_next = None
        # committed updates awaiting view merges, epoch -> updates; entries
        # are released once every view's frontier passes them
        pending_ups: dict[int, list[Update]] = {}
        if feedback:
            views = [DeltaCRDTStore(i) for i in range(cfg.n_nodes)]
            view_next = np.zeros(cfg.n_nodes, dtype=int)
        prev_commit = 0.0
        for e in range(n_epochs):
            lat = lat_cycle[e]
            if feedback:
                self._advance_views(views, view_next, pending_ups,
                                    timeline.commit_at, timeline.n_epochs,
                                    e * cfg.epoch_ms)
                lag = e - view_next
                lag_mean = float(lag.mean()) if lag.size else 0.0
                lag_max = int(lag.max()) if lag.size else 0
                snapshot = views
            else:
                lag_mean, lag_max = 0.0, 0
                snapshot = self.store
            txns = generator.epoch_txns(e, txns_per_node, snapshot=snapshot)
            rnd = self._prepare_epoch(e, txns, lat, views=views)
            sim = WANSimulator(lat, self.bandwidth, loss=self.loss,
                               rng=self.rng, verify=cfg.verify_schedules)
            res = sim.run(rnd.schedule)
            self.msg_matrix += res.msg_matrix
            # O(this epoch's events): the timeline carries the stream
            # frontier; by the admission theorem this epoch's times are
            # final the moment the append returns, so the stats can be
            # extracted and pushed downstream immediately
            et = timeline.append_epoch(rnd.schedule, lat,
                                       node_exec_ms=rnd.node_exec_ms)
            commit = et.finish_max_ms
            wall = commit - prev_commit
            prev_commit = commit
            formula = max(cfg.epoch_ms, rnd.exec_ms, res.makespan_ms)
            stats = self._epoch_stats(
                rnd, sim, res,
                wall_ms=wall,
                pipeline_overlap_ms=formula - wall,
                stream_commit_ms=commit,
                view_lag_mean=lag_mean,
                view_lag_max=lag_max,
            )
            ctx = EpochContext(epoch=e, commit_row=et.commit_ms, lat=lat)
            for s in sinks:
                s.on_epoch(stats, ctx)
            if feedback:
                pending_ups[e] = rnd.ups
                # commit rows below the slowest view's merge frontier can
                # never be read again (_advance_views only reads forward of
                # view_next); drop them from the timeline's window
                timeline.evict_commit_rows(int(view_next.min()))
            else:
                # no feedback loop: nothing ever reads the commit window
                # (the serving sink already consumed this epoch's row)
                timeline.evict_commit_rows(timeline.n_epochs)
        if serve_sink is None or n_epochs == 0:
            return None
        # wall_ms covers the full client window even when the last commit
        # lands inside it
        return serve_sink.finish(
            wall_ms=max(prev_commit, n_epochs * cfg.epoch_ms)
        )

    def _run_streaming_resim(
        self, generator, trace, txns_per_node: int, n_epochs: int,
        agg: RunAggregator,
    ) -> ServeStats | None:
        """The O(E²) re-simulation oracle (see :meth:`_run_streaming`) —
        necessarily batch-shaped: it retains every round to re-stitch the
        whole prefix, and replays the final commit matrix through the
        serving plane at the end."""
        cfg = self.cfg
        feedback = cfg.staleness_feedback
        lat_cycle = EpochLatencyCycle(trace, max(n_epochs, 1))
        rounds: list[_EpochRound] = []
        sims: list[WANSimulator] = []
        results = []
        lags: list[tuple[float, int]] = []
        views = view_next = None
        pending_ups: dict[int, list[Update]] = {}
        commit_ms = np.zeros((0, cfg.n_nodes))
        stream = stitched = None
        if feedback:
            views = [DeltaCRDTStore(i) for i in range(cfg.n_nodes)]
            view_next = np.zeros(cfg.n_nodes, dtype=int)
        for e in range(n_epochs):
            lat = lat_cycle[e]
            if feedback:
                self._advance_views(views, view_next, pending_ups,
                                    lambda k, i, _c=commit_ms: float(_c[k, i]),
                                    commit_ms.shape[0], e * cfg.epoch_ms)
                lag = e - view_next
                lags.append((float(lag.mean()) if lag.size else 0.0,
                             int(lag.max()) if lag.size else 0))
                snapshot = views
            else:
                snapshot = self.store
            txns = generator.epoch_txns(e, txns_per_node, snapshot=snapshot)
            rnd = self._prepare_epoch(e, txns, lat, views=views)
            sim = WANSimulator(lat, self.bandwidth, loss=self.loss,
                               rng=self.rng, verify=cfg.verify_schedules)
            res = sim.run(rnd.schedule)
            self.msg_matrix += res.msg_matrix
            rounds.append(rnd)
            sims.append(sim)
            results.append(res)
            if feedback:
                pending_ups[e] = rnd.ups
                # measured staleness for the *next* epoch's views; the last
                # iteration's prefix is the full stream the stats consume
                commit_ms, stream, stitched = self._stream_prefix(
                    rounds, lat_cycle
                )
        if not rounds:
            return None

        if stream is None:
            commit_ms, stream, stitched = self._stream_prefix(
                rounds, lat_cycle
            )
        # per-epoch absolute commit marks in one grouped pass (the old
        # per-epoch `finish_ms[epoch_of == k].max()` scan was quadratic)
        epoch_of = np.array([t.epoch for t in stitched.transfers])
        commit_marks = np.full(len(rounds), -np.inf)
        np.maximum.at(commit_marks, epoch_of, stream.finish_ms)

        prev_commit = 0.0
        for k, (rnd, sim, res) in enumerate(zip(rounds, sims, results)):
            commit = float(commit_marks[k])
            wall = commit - prev_commit
            prev_commit = commit
            formula = max(cfg.epoch_ms, rnd.exec_ms, res.makespan_ms)
            lag_mean, lag_max = lags[k] if feedback else (0.0, 0)
            agg.on_epoch(
                self._epoch_stats(
                    rnd, sim, res,
                    wall_ms=wall,
                    pipeline_overlap_ms=formula - wall,
                    stream_commit_ms=commit,
                    view_lag_mean=lag_mean,
                    view_lag_max=lag_max,
                ),
                EpochContext(epoch=k, commit_row=commit_ms[k],
                             lat=lat_cycle[k]),
            )

        serve_stats = None
        if cfg.serve is not None:
            # the serving plane is a pure consumer of the measured timeline:
            # per-node view-advance times (the same commit matrix the OCC
            # feedback loop merges views at) + the trace RTTs for redirects.
            # wall_ms covers the full client window even when the last
            # commit lands inside it.
            from ..serve.plane import simulate_serving

            serve_stats = simulate_serving(
                cfg.serve,
                commit_ms,
                lat_cycle,
                cfg.epoch_ms,
                wall_ms=max(prev_commit, n_epochs * cfg.epoch_ms),
            )
        return serve_stats


# ---------------------------------------------------------------------------
# Raft / CockroachDB plane (Sec 5 "Extensions", Fig 11b)
# ---------------------------------------------------------------------------


class RaftCluster:
    """Leader-based replication with optional GeoCoCo relay of AppendEntries.

    Ranges are hashed to leaders; a write batch commits once a majority of
    replicas ack.  GeoCoCo hooks RaftTransport: the leader sends one copy per
    group to the aggregator, which relays to members; acks travel back the
    same path.  Quorum semantics are unchanged (the paper's non-intrusive
    integration).

    Commit latency runs the replication fan-out through the **event-driven
    simulator** (``leader_schedule`` -> per-follower delivery times + ack
    propagation back): with constrained bandwidth the leader's NIC
    serializes its appends, so the quorum time reflects contention — the
    closed-form hop sums (kept as a private reference) charge every hop an
    uncontended wire and agree with the event engine exactly on
    contention-free (infinite-bandwidth) matrices.  Results are memoized
    per ``(latency matrix, leader, payload)`` — one epoch's batches all see
    the same network, so per-txn recomputation was pure waste (the plan
    search is also cached per matrix).
    """

    def __init__(
        self,
        n_nodes: int,
        *,
        grouping: bool = True,
        tiv: bool = True,
        planner: str = "kcenter",
        bandwidth_mbps: np.ndarray | float = np.inf,
        loss: np.ndarray | float = 0.0,
        seed: int = 0,
    ):
        self.n = n_nodes
        self.grouping = grouping
        self.tiv = tiv
        self.planner = planner
        self.bandwidth = bandwidth_mbps
        self.loss = loss
        self.rng = np.random.default_rng(seed)
        self._commit_cache: dict[tuple, float] = {}
        self._plan_cache: dict[bytes, "GroupPlan"] = {}
        self.commit_cache_hits = 0

    # -- quorum helpers --------------------------------------------------------

    def _ack_ms(self, lat: np.ndarray) -> np.ndarray:
        """Per-node ack-return latency to the leader's column: TIV-effective
        on the grouped (overlay) path, direct otherwise — matching the
        deployment (Sec 5 deploys relays on the grouped WAN paths)."""
        from .latency import one_relay_effective

        if self.grouping and self.tiv:
            eff, _ = one_relay_effective(lat, margin=0.05)
            return eff
        return lat

    def _plan(self, lat: np.ndarray, key: bytes) -> "GroupPlan":
        plan = self._plan_cache.get(key)
        if plan is None:
            from .planner import best_plan

            plan = best_plan(lat, tiv=self.tiv, method=self.planner)
            self._plan_cache[key] = plan
        return plan

    def _quorum_ms(self, res, transfers, leader: int, ack: np.ndarray,
                   epoch: int | None = None) -> float:
        """Majority-quorum commit time from an event-engine result: each
        follower's delivery plus its ack back to the leader, quorum-th
        smallest (leader + quorum followers = majority).  ``epoch``
        restricts to one batch of a stitched multi-batch stream."""
        times = [
            float(res.finish_ms[i]) + float(ack[t.dst, leader])
            for i, t in enumerate(transfers)
            if t.dst != leader and t.src != t.dst
            and (epoch is None or t.epoch == epoch)
        ]
        times.sort()
        quorum = self.n // 2
        return float(times[quorum - 1]) if quorum >= 1 else 0.0

    def commit_latency_ms(
        self, lat: np.ndarray, leader: int, payload_bytes: float
    ) -> float:
        """Latency for one replicated batch to reach majority quorum,
        measured by the event engine (memoized per matrix/leader/payload)."""
        lat = np.asarray(lat, dtype=float)
        mat_key = lat.tobytes()
        key = (mat_key, int(leader), float(payload_bytes))
        hit = self._commit_cache.get(key)
        if hit is not None:
            self.commit_cache_hits += 1
            return hit
        sim = WANSimulator(lat, self.bandwidth, loss=self.loss, rng=self.rng)
        plan = self._plan(lat, mat_key) if self.grouping else None
        sched = leader_schedule(self.n, leader, payload_bytes, plan)
        res = sim.run(sched)
        val = self._quorum_ms(res, sched.transfers, leader, self._ack_ms(lat))
        self._commit_cache[key] = val
        return val

    def _closed_form_commit_latency_ms(
        self, lat: np.ndarray, leader: int, payload_bytes: float
    ) -> float:
        """The pre-event-engine hop-sum model, kept as the contention-free
        reference: every hop pays propagation + an *uncontended* wire, so it
        matches the event engine exactly when bandwidth is infinite (and
        undercounts the leader's NIC serialization otherwise).  Mirrors
        ``leader_schedule``'s paths: the leader relays directly to its own
        group's members."""
        n = self.n
        sim = WANSimulator(lat, self.bandwidth, loss=self.loss, rng=self.rng)
        ack = self._ack_ms(lat)
        times = []
        if not self.grouping:
            for f in range(n):
                if f != leader:
                    times.append(
                        sim._hop_time(leader, f, payload_bytes)
                        + ack[f, leader]
                    )
        else:
            plan = self._plan(np.asarray(lat, dtype=float),
                              np.asarray(lat, dtype=float).tobytes())
            for g, a in zip(plan.groups, plan.aggregators):
                tgt = a if leader not in g else leader
                first = (
                    sim._hop_time(leader, tgt, payload_bytes)
                    if tgt != leader else 0.0
                )
                for f in g:
                    if f == leader:
                        continue
                    hop = 0.0 if f == tgt else sim._hop_time(tgt, f, payload_bytes)
                    times.append(first + hop + ack[f, leader])
        times.sort()
        quorum = n // 2
        return float(times[quorum - 1]) if quorum >= 1 else 0.0

    def pipelined_commit_ms(
        self, lat: np.ndarray, leader: int, payload_bytes: float,
        batches: int,
    ) -> float:
        """Commit time of the *last* of ``batches`` replication batches
        pipelined through one stitched leader-schedule stream.

        The batches share one event simulation
        (:func:`~repro.core.schedule.stitch_schedules` chains the per-batch
        leader DAGs; bandwidth admission serializes same-NIC appends in
        batch order), so in-flight batches contend for the leader's NIC
        instead of replicating for free.  On contention-free
        (infinite-bandwidth) matrices every batch streams at propagation
        speed and the last batch commits exactly when a single batch would
        — recovering the historical independent-batch model.  Memoized per
        ``(matrix, leader, payload, batches)``.
        """
        if batches <= 1:
            return self.commit_latency_ms(lat, leader, payload_bytes)
        lat = np.asarray(lat, dtype=float)
        mat_key = lat.tobytes()
        key = (mat_key, int(leader), float(payload_bytes), int(batches))
        hit = self._commit_cache.get(key)
        if hit is not None:
            self.commit_cache_hits += 1
            return hit
        plan = self._plan(lat, mat_key) if self.grouping else None
        one = leader_schedule(self.n, leader, payload_bytes, plan)
        # incremental timeline: only the last batch's segment matters for
        # the quorum, and appending is O(batch) instead of re-simulating
        # the whole stitched stream (byte-identical — see repro.core.stream;
        # _pipelined_commit_ms_resim is the tested oracle)
        timeline = StreamingTimeline(self.n, bandwidth_mbps=self.bandwidth,
                                     loss=self.loss)
        for _ in range(batches):
            et = timeline.append_epoch(one, lat)
        val = self._quorum_ms(et, et.transfers, leader,
                              self._ack_ms(lat), epoch=batches - 1)
        self._commit_cache[key] = val
        return val

    def _pipelined_commit_ms_resim(
        self, lat: np.ndarray, leader: int, payload_bytes: float,
        batches: int,
    ) -> float:
        """O(batches²) reference oracle for :meth:`pipelined_commit_ms`:
        stitch every batch and re-run the full event simulation.  Kept
        uncached for the incremental-identity regression tests."""
        lat = np.asarray(lat, dtype=float)
        sim = WANSimulator(lat, self.bandwidth, loss=self.loss, rng=self.rng)
        plan = self._plan(lat, lat.tobytes()) if self.grouping else None
        one = leader_schedule(self.n, leader, payload_bytes, plan)
        stitched = stitch_schedules([one] * batches, n=self.n)
        res = sim.run(stitched)
        return self._quorum_ms(res, stitched.transfers, leader,
                               self._ack_ms(lat), epoch=batches - 1)

    def throughput(
        self,
        trace,
        *,
        payload_bytes: float = 64_000.0,
        batches_in_flight: int = 8,
        ops_per_batch: int = 100,
    ) -> float:
        """Modeled ops/s: ``batches_in_flight`` batches pipelined through
        one stitched leader-schedule stream per trace step.

        The window closes when the last in-flight batch reaches quorum, so
        ops/s = ops * batches / mean(last-batch commit).  The historical
        model multiplied a *single* batch's mean commit latency by
        ``batches_in_flight`` — linear scaling that ignored the leader's
        NIC: on finite-bandwidth matrices it overstated throughput by up to
        the full pipelining factor.  The stitched stream reduces to it
        exactly at ``batches_in_flight=1`` and on infinite-bandwidth
        matrices (no contention to model).
        """
        last = []
        for lat in trace:
            leader = int(self.rng.integers(0, self.n))
            last.append(self.pipelined_commit_ms(
                lat, leader, payload_bytes, batches_in_flight))
        if not last:
            return 0.0
        mean_last = float(np.mean(last))
        if mean_last <= 0.0:
            return 0.0
        return ops_per_batch * batches_in_flight / (mean_last / 1e3)
