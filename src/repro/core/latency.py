"""WAN latency / bandwidth models, trace generation, and TIV analysis.

This module provides the network substrate the paper's Planner consumes:

* an AWS-style 10-region latency matrix calibrated to the figures quoted in the
  paper (Stockholm-Frankfurt ~26 ms, Sao Paulo-Cape Town ~337 ms, N.California-
  Central Canada ~81 ms, N.California-Cape Town ~288 ms),
* synthetic geo-clustered matrices (Observation #1: geographic clustering),
* temporal jitter traces (episodic AR(1) + spikes, PCHIP-smoothed like the
  paper's trace-driven simulation setup, Sec 6.1),
* Triangle-Inequality-Violation statistics and relay-path search
  (Observation #3), and
* bandwidth matrices with the LAN >> WAN asymmetry described in Sec 2.2.

Everything here is pure numpy: the planner and simulator have no JAX
dependency, mirroring the paper's deployment (a control-plane sidecar).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "AWS_REGIONS",
    "aws_latency_matrix",
    "GeoClusterSpec",
    "geo_clustered_matrix",
    "LatencyTrace",
    "jitter_trace",
    "tiv_pairs",
    "tiv_fraction",
    "one_relay_effective",
    "all_pairs_shortest",
    "bandwidth_matrix",
    "validate_latency_matrix",
]

# ---------------------------------------------------------------------------
# AWS-style 10-region matrix (paper Fig. 2)
# ---------------------------------------------------------------------------

AWS_REGIONS: tuple[str, ...] = (
    "us-east-1",       # N. Virginia
    "us-west-1",       # N. California
    "ca-central-1",    # Central Canada
    "sa-east-1",       # Sao Paulo
    "eu-west-1",       # Ireland
    "eu-north-1",      # Stockholm
    "eu-central-1",    # Frankfurt
    "af-south-1",      # Cape Town
    "ap-northeast-1",  # Tokyo
    "ap-southeast-1",  # Singapore
)

# One-way link latencies in ms, symmetric.  Calibrated so that the pairs the
# paper quotes land on the paper's numbers and the rest follow great-circle
# distance plus typical transit detours (values cross-checked against public
# cloudping-style tables).
_AWS_LATENCY_MS = np.array(
    [
        #  use   usw   cac   sae   euw   eun   euc   afs   apn   aps
        [   0.0, 62.0, 16.0,115.0, 67.0,110.0, 88.0,225.0,145.0,215.0],  # us-east-1
        [  62.0,  0.0, 81.1,174.0,137.0,175.0,147.0,288.5,107.0,170.0],  # us-west-1
        [  16.0, 81.1,  0.0,125.0, 70.0,105.0, 92.0,235.0,144.0,210.0],  # ca-central-1
        [ 115.0,174.0,125.0,  0.0,177.0,219.0,200.0,337.0,256.0,318.0],  # sa-east-1
        [  67.0,137.0, 70.0,177.0,  0.0, 38.0, 25.0,158.0,199.0,174.0],  # eu-west-1
        [ 110.0,175.0,105.0,219.0, 38.0,  0.0, 26.0,189.0,222.0,182.0],  # eu-north-1
        [  88.0,147.0, 92.0,200.0, 25.0, 26.0,  0.0,154.0,217.0,162.0],  # eu-central-1
        [ 225.0,288.5,235.0,337.0,158.0,189.0,154.0,  0.0,272.0,180.0],  # af-south-1
        [ 145.0,107.0,144.0,256.0,199.0,222.0,217.0,272.0,  0.0, 69.0],  # ap-northeast-1
        [ 215.0,170.0,210.0,318.0,174.0,182.0,162.0,180.0, 69.0,  0.0],  # ap-southeast-1
    ]
)


def aws_latency_matrix() -> np.ndarray:
    """The 10-region AWS-style latency matrix (ms, symmetric, zero diagonal)."""
    return _AWS_LATENCY_MS.copy()


# ---------------------------------------------------------------------------
# Synthetic geo-clustered matrices (Observation #1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GeoClusterSpec:
    """Specification for a synthetic geo-clustered deployment.

    ``n_clusters`` regions are placed on a 2-D plane; member nodes scatter
    around their region center.  Latency ~= propagation (distance) +
    per-link transit penalty.  A random subset of links receives a
    multiplicative congestion inflation, which is what produces realistic
    Triangle Inequality Violations (a congested direct path can be beaten by
    two un-congested hops through a hub).
    """

    n_nodes: int
    n_clusters: int = 3
    intra_ms: float = 4.0           # typical intra-region latency scale
    plane_km: float = 12000.0       # spread of region centers
    ms_per_km: float = 0.015        # ~ c/1.5 fiber + routing slack
    congestion_frac: float = 0.25   # fraction of inter-region links inflated
    congestion_mult: tuple[float, float] = (1.3, 2.5)
    min_inter_ms: float = 20.0


def geo_clustered_matrix(
    spec: GeoClusterSpec, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """Generate a clustered latency matrix.

    Returns ``(latency_ms, cluster_ids)``; latency is symmetric, zero-diag.
    """
    n, c = spec.n_nodes, spec.n_clusters
    centers = rng.uniform(0.0, spec.plane_km, size=(c, 2))
    cluster_ids = np.sort(rng.integers(0, c, size=n))
    # guarantee every cluster non-empty when n >= c
    if n >= c:
        cluster_ids[:c] = np.arange(c)
        cluster_ids = np.sort(cluster_ids)
    jitter_km = spec.intra_ms / spec.ms_per_km / 2.0
    pos = centers[cluster_ids] + rng.normal(0.0, jitter_km / 3.0, size=(n, 2))
    d = np.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
    lat = d * spec.ms_per_km
    same = cluster_ids[:, None] == cluster_ids[None, :]
    # intra-cluster: small, roughly uniform around intra_ms
    intra = rng.uniform(0.5 * spec.intra_ms, 1.5 * spec.intra_ms, size=(n, n))
    intra = (intra + intra.T) / 2.0
    lat = np.where(same, intra, np.maximum(lat, spec.min_inter_ms))
    # congestion inflation on a subset of inter-cluster links -> TIV
    infl = np.ones((n, n))
    iu = np.triu_indices(n, k=1)
    inter_mask = ~same[iu]
    n_inter = int(inter_mask.sum())
    n_congested = int(round(spec.congestion_frac * n_inter))
    if n_congested > 0:
        idx = rng.choice(np.flatnonzero(inter_mask), size=n_congested, replace=False)
        mult = rng.uniform(*spec.congestion_mult, size=n_congested)
        rows, cols = iu[0][idx], iu[1][idx]
        infl[rows, cols] = mult
        infl[cols, rows] = mult
    lat = lat * infl
    np.fill_diagonal(lat, 0.0)
    return lat, cluster_ids


# ---------------------------------------------------------------------------
# Temporal traces (Sec 6.1: PCHIP-fitted, episodic dynamics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LatencyTrace:
    """A sequence of latency matrices over time (one per synchronization round)."""

    base: np.ndarray                 # (n, n) mean latency
    frames: np.ndarray               # (t, n, n) per-round matrices

    def __len__(self) -> int:
        return self.frames.shape[0]

    def __iter__(self) -> Iterator[np.ndarray]:
        return iter(self.frames)

    def __getitem__(self, i: int) -> np.ndarray:
        return self.frames[i]


def jitter_trace(
    base: np.ndarray,
    n_rounds: int,
    rng: np.random.Generator,
    *,
    rel_sigma: float = 0.08,
    ar_coeff: float = 0.9,
    spike_prob: float = 0.01,
    spike_mult: tuple[float, float] = (1.5, 3.0),
    spike_len: tuple[int, int] = (5, 30),
    knot_every: int = 8,
) -> LatencyTrace:
    """Generate an episodic, smoothly-varying latency trace.

    Model: per-link AR(1) log-multiplier sampled at knots every ``knot_every``
    rounds and PCHIP-interpolated between knots (matching the paper's
    piecewise-cubic-Hermite fitting of AWS traces), plus episodic spike events
    that multiply a link's latency for a sustained window ("episodic rather
    than continuous" dynamics, Sec 4.2/5).
    """
    from scipy.interpolate import PchipInterpolator

    n = base.shape[0]
    iu = np.triu_indices(n, k=1)
    n_links = iu[0].size
    n_knots = max(2, n_rounds // knot_every + 2)
    knots_t = np.linspace(0, n_rounds - 1, n_knots)
    # AR(1) in log-space at the knots
    z = np.zeros((n_knots, n_links))
    for t in range(1, n_knots):
        z[t] = ar_coeff * z[t - 1] + rng.normal(0.0, rel_sigma, size=n_links)
    interp = PchipInterpolator(knots_t, z, axis=0)
    mult = np.exp(interp(np.arange(n_rounds)))  # (rounds, links)
    # episodic spikes
    for l in range(n_links):
        t = 0
        while t < n_rounds:
            if rng.random() < spike_prob:
                ln = int(rng.integers(*spike_len))
                m = rng.uniform(*spike_mult)
                mult[t : t + ln, l] *= m
                t += ln
            t += 1
    frames = np.repeat(base[None, :, :], n_rounds, axis=0)
    frames[:, iu[0], iu[1]] *= mult
    frames[:, iu[1], iu[0]] = frames[:, iu[0], iu[1]]
    return LatencyTrace(base=base.copy(), frames=frames)


# ---------------------------------------------------------------------------
# Triangle-Inequality Violations (Observation #3)
# ---------------------------------------------------------------------------


def tiv_pairs(lat: np.ndarray, *, margin: float = 0.0) -> np.ndarray:
    """Boolean (n, n) matrix: True where some 1-relay path beats the direct link.

    ``margin`` requires the indirect path to win by at least that fraction
    (e.g. 0.05 = 5% faster) — the paper's overlay only deploys a relay when it
    provides "sufficient latency gain".
    """
    n = lat.shape[0]
    # best one-relay path: min_r lat[i, r] + lat[r, j]
    via = lat[:, :, None] + lat.T[None, :, :]          # (i, r, j) -> i->r->j
    via = via.transpose(0, 2, 1)                        # (i, j, r)
    eye = np.eye(n, dtype=bool)
    relay_block = eye[:, None, :] | eye[None, :, :]     # r == i or r == j
    via = np.where(relay_block, np.inf, via)
    best = via.min(axis=2)
    out = best < lat * (1.0 - margin)
    np.fill_diagonal(out, False)
    return out


def tiv_fraction(lat: np.ndarray, *, margin: float = 0.0) -> float:
    """Fraction of ordered node pairs violating the triangle inequality."""
    n = lat.shape[0]
    v = tiv_pairs(lat, margin=margin)
    return float(v.sum()) / float(n * (n - 1))


def one_relay_effective(lat: np.ndarray, *, margin: float = 0.0) -> tuple[np.ndarray, np.ndarray]:
    """Effective latency using at most one relay, plus the chosen relay.

    Returns ``(eff, relay)`` where ``relay[i, j] = -1`` for direct transmission
    and otherwise the relay node index.  This is the paper's overlay-based TIV
    exploitation (Sec 5, "Overlay-based Implementation"): user-space relays,
    falling back to the direct path when gain is below ``margin``.
    """
    n = lat.shape[0]
    via = lat[:, :, None] + lat.T[None, :, :]
    via = via.transpose(0, 2, 1)  # (i, j, r)
    eye = np.eye(n, dtype=bool)
    relay_block = eye[:, None, :] | eye[None, :, :]
    via = np.where(relay_block, np.inf, via)
    best_r = via.argmin(axis=2)
    best = np.take_along_axis(via, best_r[:, :, None], axis=2)[:, :, 0]
    use = best < lat * (1.0 - margin)
    eff = np.where(use, best, lat)
    relay = np.where(use, best_r, -1)
    np.fill_diagonal(eff, 0.0)
    np.fill_diagonal(relay, -1)
    return eff, relay


def all_pairs_shortest(lat: np.ndarray) -> np.ndarray:
    """Floyd-Warshall all-pairs shortest latency (unbounded relays).

    Used for the theoretical lower bound in the makespan CDF (Fig 9's
    "Low Bound"): no schedule can synchronize a pair faster than its shortest
    path.
    """
    d = lat.copy().astype(float)
    n = d.shape[0]
    for r in range(n):
        d = np.minimum(d, d[:, r : r + 1] + d[r : r + 1, :])
    return d


# ---------------------------------------------------------------------------
# Bandwidth
# ---------------------------------------------------------------------------


def bandwidth_matrix(
    cluster_ids: np.ndarray | None,
    n: int,
    rng: np.random.Generator,
    *,
    lan_mbps: float = 10000.0,
    wan_mbps: tuple[float, float] = (100.0, 1000.0),
) -> np.ndarray:
    """WAN/LAN-asymmetric bandwidth matrix (Mbps).

    Sec 2.2: WAN bandwidth is on average ~15x (up to 60-80x) below LAN.  The
    defaults give a 10-100x gap.  ``cluster_ids=None`` treats every pair as WAN.
    """
    bw = rng.uniform(*wan_mbps, size=(n, n))
    bw = (bw + bw.T) / 2.0
    if cluster_ids is not None:
        same = cluster_ids[:, None] == cluster_ids[None, :]
        bw = np.where(same, lan_mbps, bw)
    np.fill_diagonal(bw, np.inf)
    return bw


def validate_latency_matrix(lat: np.ndarray) -> None:
    if lat.ndim != 2 or lat.shape[0] != lat.shape[1]:
        raise ValueError(f"latency matrix must be square, got {lat.shape}")
    if not np.allclose(np.diag(lat), 0.0):
        raise ValueError("latency matrix diagonal must be zero")
    if (lat < 0).any():
        raise ValueError("latency matrix must be non-negative")
