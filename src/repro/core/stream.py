"""Appendable streaming timeline: O(E) long-horizon event simulation.

The staleness-feedback loop (``EngineConfig(staleness_feedback=True)``)
needs each epoch's measured per-node commit times *before* it can execute
the next epoch's transactions.  The original implementation re-simulated
the stitched prefix every epoch (``GeoCluster._stream_prefix``) — exact,
but O(E²) in simulated transfers, capping runs at tens of epochs.

:class:`StreamingTimeline` owns the running event-engine state instead —
the stitch frontier (:class:`~repro.core.schedule.StitchState`: per-node
commit indices, exec stages, the cadence clock-chain tail, the admission
rank offset), the previous epoch's delivered finish times, and the
per-directed-NIC clear floors (:class:`~repro.core.simulator.NicState`) —
and :meth:`append_epoch` simulates **only the appended epoch's events**.

Why the incremental times are byte-identical to the full re-simulation
(the PR-4 bandwidth-admission theorem doing double duty):

* every wire hop of epoch ``k+1`` has a strictly higher admission rank
  than everything already streamed (``rank_base`` grows monotonically),
  so admission keeps it off both of its NICs until every earlier flow
  there has drained — epoch ``k+1``'s flows never share a NIC *in time*
  with epoch ``<= k``'s, and (conversely) later flows never re-rate
  earlier ones, making the earlier epochs' times final;
* the event engine is lazy per flow (a flow's float arithmetic is touched
  only by events on its own two directed NICs — see
  :meth:`~repro.core.simulator.WANSimulator.simulate_segment`), so a
  flow's measured times are a pure function of its NIC-local history;
* every influence of the already-simulated prefix on the new epoch
  reduces to finitely many stored floats: the frontier dependencies'
  finish times (folded into per-transfer external ready floors) and each
  directed NIC's last drain time (the admission floor).  Replaying the
  segment against those floats performs the *same* float operations in
  the *same* canonical event order as the full run.

``tests/test_streaming.py`` / ``tests/test_property_dag.py`` pin the
identity (exact ``==`` on finish times and commit matrices, no
tolerances); ``benchmarks/bench_long_horizon.py`` gates it on the
abort-curve testbed and demonstrates the O(E) scaling at 1000 epochs.
The O(E²) oracle stays available behind
``EngineConfig(stream_mode="resim")``.

What incremental mode cannot support: ``stochastic_loss=True`` (the
retransmission RNG draws happen in event order, which differs between
incremental and full runs — rejected at construction), ``admission=False``
(later flows could then slow earlier ones and no prefix would ever be
final) and the barrier engine (no cross-epoch semantics).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .schedule import StitchState, Transfer, TransmissionSchedule
from .simulator import NicState, WANSimulator, epoch_commit_row

__all__ = ["StreamingTimeline", "EpochTimings"]


@dataclasses.dataclass
class EpochTimings:
    """Measured times of one appended epoch.

    ``commit_ms`` is the epoch's row of the cumulative per-node commit
    matrix (identical to ``node_commit_ms(...)[epoch]`` of the full
    re-simulation); ``finish_max_ms`` the segment's last delivery (what the
    streaming stats report as the epoch's absolute commit);
    ``start_ms`` / ``finish_ms`` index the segment ``transfers`` (global
    dependency indices, first at stream index ``offset``).
    """

    epoch: int
    commit_ms: np.ndarray
    finish_max_ms: float
    start_ms: np.ndarray
    finish_ms: np.ndarray
    transfers: list[Transfer]
    offset: int


class StreamingTimeline:
    """Appendable cross-epoch event simulation (see module docstring).

    ``append_epoch(schedule, lat, node_exec_ms)`` stitches the epoch onto
    the stream frontier and simulates only its events; delivered-transfer
    state is evicted down to the dependency frontier after every append.
    The cumulative commit matrix and per-epoch finish marks live in a
    sliding-window buffer: callers that only need recent rows (the
    staleness-feedback loop needs nothing below the slowest view's merge
    frontier, ``view_next.min()``) release older ones with
    :meth:`evict_commit_rows`, keeping memory O(segment + live window · n)
    instead of O(E·n).  With no eviction the full matrix is retained and
    :attr:`commit_ms` / :attr:`finish_max_ms` are exactly the historical
    surfaces.
    """

    def __init__(
        self,
        n: int,
        *,
        bandwidth_mbps: np.ndarray | float = np.inf,
        loss: np.ndarray | float = 0.0,
        retx_timeout_ms: float = 200.0,
        epoch_ms: float = 0.0,
        verify: bool = False,
    ):
        self.n = n
        self.verify = verify
        # the simulator carries the wire model (bandwidth/loss are
        # constructor-fixed, as in stitched runs); propagation comes from
        # each append's own latency matrix
        self._sim = WANSimulator(
            np.zeros((n, n)), bandwidth_mbps, loss=loss,
            retx_timeout_ms=retx_timeout_ms,
        )
        if self._sim.stochastic_loss:  # pragma: no cover - default False
            raise ValueError("incremental timelines reject stochastic_loss")
        self._stitch = StitchState(n, epoch_ms=epoch_ms)
        self._nic = NicState.zeros(n)
        # frontier state: finish times / repaired admission ranks / builder
        # phase ranks of exactly the indices the next epoch may depend on
        self._finish: dict[int, float] = {}
        self._rank: dict[int, int] = {}
        self._phase: dict[int, int] = {}
        self._verifier = None
        if verify:
            from ..analysis.schedule_check import StreamScheduleVerifier

            self._verifier = StreamScheduleVerifier(n_nodes=n)
        # cumulative per-node commit matrix + per-epoch finish marks, stored
        # as a sliding window: physical row 0 is absolute epoch _phys_base,
        # rows below the _evicted frontier are dead and reclaimed by
        # _ensure_capacity (compact-or-grow), so retained capacity is
        # O(live window) rather than O(E)
        self._commit = np.zeros((8, n))
        self._fmax = np.zeros(8)
        self._acc = np.full(n, -np.inf)
        self._phys_base = 0   # absolute epoch of physical row 0
        self._evicted = 0     # retention frontier: rows < _evicted are gone

    # -- read surface --------------------------------------------------------

    @property
    def n_epochs(self) -> int:
        return self._stitch.epoch

    @property
    def evicted_epochs(self) -> int:
        """Epochs whose commit rows have been released; reads below this
        frontier raise.  0 until :meth:`evict_commit_rows` is first used."""
        return self._evicted

    @property
    def commit_ms(self) -> np.ndarray:
        """The retained ``(n_epochs - evicted_epochs, n)`` cumulative
        per-node commit window — with no eviction, the same full matrix
        ``node_commit_ms(stitched, full_run, n)`` yields; row 0 is absolute
        epoch :attr:`evicted_epochs`."""
        lo = self._evicted - self._phys_base
        return self._commit[lo: self._stitch.epoch - self._phys_base]

    @property
    def finish_max_ms(self) -> list[float]:
        """Per retained epoch: the last delivery among that epoch's
        transfers (the absolute stream commit the stats loop consumes)."""
        lo = self._evicted - self._phys_base
        return self._fmax[lo: self._stitch.epoch - self._phys_base].tolist()

    def commit_at(self, epoch: int, node: int) -> float:
        """``commit_ms[epoch, node]`` by absolute epoch index (the feedback
        loop's point read — window-relocation-proof)."""
        if epoch < self._evicted:
            raise IndexError(
                f"commit row for epoch {epoch} was evicted "
                f"(frontier at {self._evicted})"
            )
        if epoch >= self._stitch.epoch:
            raise IndexError(
                f"epoch {epoch} not yet appended ({self._stitch.epoch} so far)"
            )
        return float(self._commit[epoch - self._phys_base, node])

    def commit_row(self, epoch: int) -> np.ndarray:
        """A copy of the cumulative commit row of an absolute epoch."""
        if epoch < self._evicted or epoch >= self._stitch.epoch:
            raise IndexError(
                f"epoch {epoch} outside retained window "
                f"[{self._evicted}, {self._stitch.epoch})"
            )
        return self._commit[epoch - self._phys_base].copy()

    # -- retention -----------------------------------------------------------

    def evict_commit_rows(self, before: int) -> None:
        """Release commit rows of epochs ``< before`` (monotone; clamped to
        the appended horizon).  Sound for the feedback loop once every
        node's view has merged past them: ``_advance_views`` only ever
        reads rows ``>= view_next.min()``, and an epoch's row is final the
        moment it is appended (the admission theorem), so nothing will
        update or reread a released row.  The memory is reclaimed lazily by
        the next capacity request (compact-or-grow)."""
        before = min(int(before), self._stitch.epoch)
        if before > self._evicted:
            self._evicted = before

    def _ensure_capacity(self, epoch: int) -> None:
        """Make physical room for an absolute epoch's row: slide the live
        window down over dead (evicted) rows when at least half the buffer
        is dead, else double.  Amortized O(1) per append; capacity stays
        O(max live window)."""
        cap = self._commit.shape[0]
        if epoch - self._phys_base < cap:
            return
        # rows physically written so far (the requested epoch's row isn't)
        filled = min(self._stitch.epoch - self._phys_base, cap)
        dead = self._evicted - self._phys_base
        if dead >= cap // 2:
            live = filled - dead
            self._commit[:live] = self._commit[dead:filled]
            self._fmax[:live] = self._fmax[dead:filled]
            self._phys_base = self._evicted
            filled = live
        if epoch - self._phys_base >= cap:
            new_cap = max(2 * cap, epoch - self._phys_base + 1)
            grown = np.zeros((new_cap, self.n))
            grown_f = np.zeros(new_cap)
            grown[:filled] = self._commit[:filled]
            grown_f[:filled] = self._fmax[:filled]
            self._commit = grown
            self._fmax = grown_f

    # -- append --------------------------------------------------------------

    def append_epoch(
        self,
        schedule: TransmissionSchedule,
        lat: np.ndarray,
        node_exec_ms: Sequence[float] | None = None,
    ) -> EpochTimings:
        """Stitch one epoch onto the stream and simulate only its events.

        Returns the epoch's :class:`EpochTimings`; times are byte-identical
        to re-simulating the whole stitched prefix.
        """
        k = self._stitch.epoch
        seg, phase_ranks = self._stitch.append(schedule, node_exec_ms)
        offset = self._stitch.size - len(seg)

        # localize dependencies: internal edges stay, external edges fold
        # into (a) the transfer's ready floor — the max of the stored
        # frontier finish times, exactly the float the full run's last
        # dependency delivery would supply — and (b) the admission-rank
        # repair (_admission_ranks resolved over the whole stream).
        deps_local: list[tuple[int, ...]] = []
        ext_ready = [0.0] * len(seg)
        rep_rank: list[int] = []
        for i, t in enumerate(seg):
            ds: list[int] = []
            r = 0
            ext = 0.0
            for d in t.deps:
                if d >= offset:
                    li = d - offset
                    ds.append(li)
                    if rep_rank[li] + 1 > r:
                        r = rep_rank[li] + 1
                else:
                    f = self._finish[d]
                    if f > ext:
                        ext = f
                    if self._rank[d] + 1 > r:
                        r = self._rank[d] + 1
            if phase_ranks[i] > r:
                r = phase_ranks[i]
            rep_rank.append(r)
            ext_ready[i] = ext
            deps_local.append(tuple(ds))

        if self._verifier is not None:
            violations = self._verifier.check_epoch(
                seg, phase_ranks, frontier=self._stitch.frontier(),
            )
            if violations:
                from ..analysis.schedule_check import ScheduleVerificationError

                raise ScheduleVerificationError(
                    violations, f"{schedule.label}@epoch{k}"
                )

        start, finish, _pred = self._sim.simulate_segment(
            seg,
            rank=np.asarray(rep_rank, dtype=int),
            deps=deps_local,
            ext_ready=ext_ready,
            nic=self._nic,
            lat=lat,
            tid_base=offset,
        )

        # evict delivered-transfer state down to the new frontier
        new_finish: dict[int, float] = {}
        new_rank: dict[int, int] = {}
        new_phase: dict[int, int] = {}
        for g in self._stitch.frontier():
            li = g - offset
            new_finish[g] = float(finish[li])
            new_rank[g] = rep_rank[li]
            new_phase[g] = phase_ranks[li]
        self._finish, self._rank, self._phase = new_finish, new_rank, new_phase

        # this epoch's commit row (node_commit_ms semantics: per-node max
        # delivery over owned transfers, cumulative over epochs, -inf -> 0)
        row = epoch_commit_row(seg, finish, self.n)
        np.maximum(self._acc, row, out=self._acc)
        self._ensure_capacity(k)
        p = k - self._phys_base
        self._commit[p] = np.where(np.isfinite(self._acc), self._acc, 0.0)
        fmax = float(finish.max()) if len(seg) else 0.0
        self._fmax[p] = fmax

        return EpochTimings(
            epoch=k,
            commit_ms=self._commit[p].copy(),
            finish_max_ms=fmax,
            start_ms=start,
            finish_ms=finish,
            transfers=seg,
            offset=offset,
        )
