"""Latency-aware Grouping Strategy Orchestrator (paper Sec 4.2 + Sec 5).

Implements:

* the exact MILP of Algorithm 1 via ``scipy.optimize.milp`` (HiGHS — the
  open-source stand-in for the paper's Gurobi),
* the K-center 2-approximation heuristic used at large scale (Sec 5),
* the baseline strategies the paper compares against in Fig. 12
  (hierarchical agglomerative clustering, KMeans on classical-MDS embeddings,
  random grouping, no grouping),
* the closed-form optimal group count ``k* = (N^2 / 2)^(1/3)`` with the
  guided search band (Sec 4.2, Eq. 4-5), and
* a damped ``Replanner`` that only regroups on sustained latency deviation
  (the "Re-group damping strategy").

All strategies return a :class:`GroupPlan`; the plan's paper-objective cost
``T = max_j(intra_j) + max(inter)`` is computed by :func:`plan_cost`.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Sequence

import numpy as np

from . import strategies as _strategies
from .latency import one_relay_effective, validate_latency_matrix

__all__ = [
    "GroupPlan",
    "plan_cost",
    "milp_grouping",
    "kcenter_grouping",
    "agglomerative_grouping",
    "kmeans_grouping",
    "random_grouping",
    "no_grouping",
    "singleton_grouping",
    "optimal_k",
    "k_search_band",
    "hierarchical_comm_cost",
    "best_plan",
    "Replanner",
    "STRATEGIES",
]


# ---------------------------------------------------------------------------
# Plan representation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """A grouping of ``n`` nodes into ``k`` groups with one aggregator each."""

    groups: tuple[tuple[int, ...], ...]
    aggregators: tuple[int, ...]
    method: str = ""
    solve_time_s: float = 0.0
    objective: float = float("nan")

    @property
    def k(self) -> int:
        return len(self.groups)

    @property
    def n(self) -> int:
        return sum(len(g) for g in self.groups)

    def group_of(self) -> np.ndarray:
        """Array mapping node id -> group index."""
        out = np.full(self.n, -1, dtype=int)
        for j, g in enumerate(self.groups):
            for i in g:
                out[i] = j
        return out

    def validate(self, n: int | None = None) -> None:
        nodes = [i for g in self.groups for i in g]
        if len(nodes) != len(set(nodes)):
            raise ValueError("node assigned to multiple groups")
        if n is not None and sorted(nodes) != list(range(n)):
            raise ValueError(f"plan covers {sorted(nodes)}, expected 0..{n-1}")
        if len(self.aggregators) != len(self.groups):
            raise ValueError("need exactly one aggregator per group")
        for j, (g, a) in enumerate(zip(self.groups, self.aggregators)):
            if a not in g:
                raise ValueError(f"aggregator {a} not a member of group {j}")
            if len(g) == 0:
                raise ValueError(f"group {j} is empty")

    def replace_aggregator(self, group_idx: int, new_agg: int) -> "GroupPlan":
        """Failover: swap the aggregator of one group (Sec 4.4)."""
        if new_agg not in self.groups[group_idx]:
            raise ValueError("new aggregator must be a group member")
        aggs = list(self.aggregators)
        aggs[group_idx] = new_agg
        return dataclasses.replace(self, aggregators=tuple(aggs), method=self.method + "+failover")

    def drop_node(self, node: int) -> "GroupPlan":
        """Remove a failed node; if it was an aggregator, promote a member."""
        groups: list[tuple[int, ...]] = []
        aggs: list[int] = []
        for g, a in zip(self.groups, self.aggregators):
            g2 = tuple(i for i in g if i != node)
            if not g2:
                continue
            a2 = a if a != node else g2[0]
            groups.append(g2)
            aggs.append(a2)
        return GroupPlan(tuple(groups), tuple(aggs), method=self.method + "+drop")


def _effective(lat: np.ndarray, tiv: bool, tiv_margin: float) -> np.ndarray:
    if not tiv:
        return lat
    eff, _ = one_relay_effective(lat, margin=tiv_margin)
    return eff


def plan_cost(
    lat: np.ndarray, plan: GroupPlan, *, tiv: bool = False, tiv_margin: float = 0.05
) -> float:
    """3-phase round cost: ``T = 2*max_j(intra_j) + max_{u,v in aggs}(L[u,v])``.

    ``intra_j`` is the worst member<->aggregator latency of group j (star
    topology) — paid twice per round (gather + scatter, Fig. 8); the inter
    term is the worst aggregator pair.  The paper's Eq. 1 uses a single
    intra term; the doubled form matches the executed 3-phase schedule and
    correctly degenerates to the flat round cost for singleton groups.

    TIV relays apply only to the inter-aggregator hop — the schedule never
    relays intra-group transfers (Sec 5 deploys relays on WAN paths).
    """
    intra = 0.0
    for g, a in zip(plan.groups, plan.aggregators):
        for i in g:
            if i != a:
                intra = max(intra, max(lat[i, a], lat[a, i]))
    eff = _effective(lat, tiv, tiv_margin)
    inter = 0.0
    for u, v in itertools.combinations(plan.aggregators, 2):
        inter = max(inter, max(eff[u, v], eff[v, u]))
    return 2.0 * intra + inter


# ---------------------------------------------------------------------------
# Optimal group count (Eq. 4-5)
# ---------------------------------------------------------------------------


def hierarchical_comm_cost(n: int, k: int) -> float:
    """Eq. 4: C_total = 2N(N/k - 1) + 2k(k - 1)."""
    if k <= 0:
        raise ValueError("k must be positive")
    return 2.0 * n * (n / k - 1.0) + 2.0 * k * (k - 1.0)


def optimal_k(n: int) -> float:
    """Eq. 5: k* = (N^2 / 2)^(1/3)."""
    return (n * n / 2.0) ** (1.0 / 3.0)


def k_search_band(n: int, *, tolerance: int = 1) -> list[int]:
    """Guided search band around k* (Sec 4.2, "The Setting of Group Number").

    Returns candidate group counts clipped to [2, n-1] (k=1 or k=n degenerate
    to flat schemes handled separately).
    """
    ks = optimal_k(n)
    lo = max(2, int(np.floor(ks)) - tolerance)
    hi = min(n - 1, int(np.ceil(ks)) + tolerance)
    if hi < lo:
        lo = hi = max(2, min(n - 1, int(round(ks))))
    return list(range(lo, hi + 1))


# ---------------------------------------------------------------------------
# MILP grouping (Algorithm 1)
# ---------------------------------------------------------------------------


def milp_grouping(
    lat: np.ndarray,
    k: int,
    *,
    tiv: bool = False,
    tiv_margin: float = 0.05,
    time_limit_s: float = 5.0,
    mip_rel_gap: float = 1e-4,
) -> GroupPlan:
    """Exact latency-aware grouping via mixed-integer linear programming.

    Decision variables (Algorithm 1): ``x[i,j]`` node-i-in-group-j, ``y[i,j]``
    node-i-aggregates-group-j; continuous ``l_j`` (max intra latency of group
    j), ``M >= l_j`` and ``Linter`` (max inter-aggregator latency).  Objective
    ``min 2*M + Linter`` (the executed 3-phase round pays intra twice).

    Linearization: the bilinear "i in group j AND a aggregates j" terms become
    ``l_j >= L[i,a] * (x[i,j] + y[a,j] - 1)``; the inter-aggregator max uses
    the implied binary ``isagg_u = sum_j y[u,j]`` with
    ``Linter >= L[u,v] * (isagg_u + isagg_v - 1)``.  TIV-effective latencies
    enter only the inter term (relays are deployed on WAN paths, Sec 5).
    """
    from scipy.optimize import LinearConstraint, Bounds, milp
    from scipy.sparse import lil_matrix

    validate_latency_matrix(lat)
    n = lat.shape[0]
    if not (1 <= k <= n):
        raise ValueError(f"k={k} out of range for n={n}")
    effs = np.maximum(lat, lat.T)          # intra: direct paths only
    eff_inter = _effective(lat, tiv, tiv_margin)
    effs_inter = np.maximum(eff_inter, eff_inter.T)

    t0 = time.perf_counter()
    # variable layout: x (n*k) | y (n*k) | l (k) | M | Linter
    nx = n * k
    nvar = 2 * nx + k + 2
    ix = lambda i, j: i * k + j
    iy = lambda i, j: nx + i * k + j
    il = lambda j: 2 * nx + j
    iM = 2 * nx + k
    iL = 2 * nx + k + 1

    c = np.zeros(nvar)
    c[iM] = 2.0   # intra paid twice (gather + scatter)
    c[iL] = 1.0

    rows: list[tuple[dict[int, float], float, float]] = []  # (coeffs, lb, ub)

    # each node in exactly one group
    for i in range(n):
        rows.append(({ix(i, j): 1.0 for j in range(k)}, 1.0, 1.0))
    # each group exactly one aggregator
    for j in range(k):
        rows.append(({iy(i, j): 1.0 for i in range(n)}, 1.0, 1.0))
    # y <= x
    for i in range(n):
        for j in range(k):
            rows.append(({iy(i, j): 1.0, ix(i, j): -1.0}, -np.inf, 0.0))
    # intra: l_j - L[i,a] x[i,j] - L[i,a] y[a,j] >= -L[i,a]
    for j in range(k):
        for i in range(n):
            for a in range(n):
                if i == a:
                    continue
                w = effs[i, a]
                if w <= 0.0:
                    continue
                rows.append(
                    ({il(j): 1.0, ix(i, j): -w, iy(a, j): -w}, -w, np.inf)
                )
    # M >= l_j
    for j in range(k):
        rows.append(({iM: 1.0, il(j): -1.0}, 0.0, np.inf))
    # inter: Linter - L[u,v](isagg_u + isagg_v) >= -L[u,v]
    if k >= 2:
        for u in range(n):
            for v in range(u + 1, n):
                w = effs_inter[u, v]
                if w <= 0.0:
                    continue
                coeffs: dict[int, float] = {iL: 1.0}
                for j in range(k):
                    coeffs[iy(u, j)] = coeffs.get(iy(u, j), 0.0) - w
                    coeffs[iy(v, j)] = coeffs.get(iy(v, j), 0.0) - w
                rows.append((coeffs, -w, np.inf))
    # symmetry breaking: aggregator of group j has index below aggregator of
    # group j+1 (cuts the k! group-relabeling symmetry)
    for j in range(k - 1):
        coeffs = {}
        for i in range(n):
            coeffs[iy(i, j)] = coeffs.get(iy(i, j), 0.0) + float(i)
            coeffs[iy(i, j + 1)] = coeffs.get(iy(i, j + 1), 0.0) - float(i)
        rows.append((coeffs, -np.inf, -1.0))

    A = lil_matrix((len(rows), nvar))
    lb = np.empty(len(rows))
    ub = np.empty(len(rows))
    for r, (coeffs, l, u) in enumerate(rows):
        for v, w in coeffs.items():
            A[r, v] = w
        lb[r] = l
        ub[r] = u

    integrality = np.zeros(nvar)
    integrality[: 2 * nx] = 1
    bounds = Bounds(
        lb=np.concatenate([np.zeros(2 * nx), np.zeros(k + 2)]),
        ub=np.concatenate([np.ones(2 * nx), np.full(k + 2, np.inf)]),
    )
    res = milp(
        c,
        constraints=LinearConstraint(A.tocsr(), lb, ub),
        integrality=integrality,
        bounds=bounds,
        options={"time_limit": time_limit_s, "mip_rel_gap": mip_rel_gap},
    )
    dt = time.perf_counter() - t0
    if res.x is None:
        raise RuntimeError(f"MILP grouping infeasible/failed: {res.message}")
    xv = res.x[:nx].reshape(n, k) > 0.5
    yv = res.x[nx : 2 * nx].reshape(n, k) > 0.5
    groups = tuple(tuple(np.flatnonzero(xv[:, j]).tolist()) for j in range(k))
    aggs = tuple(int(np.flatnonzero(yv[:, j])[0]) for j in range(k))
    plan = GroupPlan(groups, aggs, method="milp" + ("+tiv" if tiv else ""),
                     solve_time_s=dt, objective=float(res.fun))
    plan.validate(n)
    return plan


# ---------------------------------------------------------------------------
# K-center heuristic (Sec 5, "K-Center-Based Scalable Planner")
# ---------------------------------------------------------------------------


def _group_center(effs: np.ndarray, members: Sequence[int]) -> int:
    """1-center of a group: member minimizing the max latency to the others."""
    sub = effs[np.ix_(members, members)]
    return int(members[int(sub.max(axis=1).argmin())])


def kcenter_grouping(
    lat: np.ndarray,
    k: int,
    *,
    tiv: bool = False,
    tiv_margin: float = 0.05,
) -> GroupPlan:
    """Gonzalez farthest-point K-center: O(N*k), 2-approx on max intra latency.

    Clusters on direct latencies (intra transfers are never relayed); ``tiv``
    affects only the reported objective via :func:`plan_cost`.
    """
    validate_latency_matrix(lat)
    n = lat.shape[0]
    k = min(k, n)
    effs = np.maximum(lat, lat.T)
    t0 = time.perf_counter()
    # first center: global 1-center
    centers = [int(effs.max(axis=1).argmin())]
    dist = effs[centers[0]].copy()
    for _ in range(1, k):
        nxt = int(dist.argmax())
        centers.append(nxt)
        dist = np.minimum(dist, effs[nxt])
    assign = effs[:, centers].argmin(axis=1)
    groups = []
    aggs = []
    for j in range(k):
        members = np.flatnonzero(assign == j).tolist()
        if centers[j] not in members:  # ties can strand the center
            members.append(centers[j])
        members = sorted(set(members))
        groups.append(tuple(members))
        aggs.append(_group_center(effs, members))
    dt = time.perf_counter() - t0
    plan = GroupPlan(tuple(groups), tuple(aggs),
                     method="kcenter" + ("+tiv" if tiv else ""), solve_time_s=dt)
    plan.validate(n)
    return dataclasses.replace(plan, objective=plan_cost(lat, plan, tiv=tiv, tiv_margin=tiv_margin))


# ---------------------------------------------------------------------------
# Baseline strategies (Fig. 12)
# ---------------------------------------------------------------------------


def agglomerative_grouping(lat: np.ndarray, k: int) -> GroupPlan:
    """Complete-linkage hierarchical agglomerative clustering on latencies."""
    validate_latency_matrix(lat)
    n = lat.shape[0]
    t0 = time.perf_counter()
    effs = np.maximum(lat, lat.T)
    clusters: list[list[int]] = [[i] for i in range(n)]
    # complete-linkage distance between clusters
    d = effs.copy().astype(float)
    np.fill_diagonal(d, np.inf)
    cd = d.copy()
    active = list(range(n))
    while len(active) > k:
        sub = cd[np.ix_(active, active)]
        flat = int(sub.argmin())
        a_i, a_j = divmod(flat, len(active))
        ci, cj = active[a_i], active[a_j]
        if ci > cj:
            ci, cj = cj, ci
        clusters[ci] = clusters[ci] + clusters[cj]
        clusters[cj] = []
        active.remove(cj)
        for other in active:
            if other == ci:
                continue
            cd[ci, other] = cd[other, ci] = max(cd[ci, other], cd[cj, other])
    groups = []
    aggs = []
    for ci in active:
        members = sorted(clusters[ci])
        groups.append(tuple(members))
        aggs.append(_group_center(effs, members))
    dt = time.perf_counter() - t0
    plan = GroupPlan(tuple(groups), tuple(aggs), method="agglomerative", solve_time_s=dt)
    plan.validate(n)
    return dataclasses.replace(plan, objective=plan_cost(lat, plan))


def _mds_embed(effs: np.ndarray, dim: int = 4) -> np.ndarray:
    """Classical MDS embedding of a latency matrix (for KMeans baselines)."""
    n = effs.shape[0]
    d2 = effs ** 2
    j = np.eye(n) - np.ones((n, n)) / n
    b = -0.5 * j @ d2 @ j
    w, v = np.linalg.eigh(b)
    idx = np.argsort(w)[::-1][:dim]
    w = np.clip(w[idx], 0.0, None)
    return v[:, idx] * np.sqrt(w)[None, :]


def kmeans_grouping(
    lat: np.ndarray, k: int, rng: np.random.Generator | None = None, *, iters: int = 50
) -> GroupPlan:
    """Lloyd's KMeans on a classical-MDS embedding of the latency matrix."""
    validate_latency_matrix(lat)
    rng = rng or np.random.default_rng(0)
    n = lat.shape[0]
    t0 = time.perf_counter()
    effs = np.maximum(lat, lat.T)
    x = _mds_embed(effs)
    cent = x[rng.choice(n, size=k, replace=False)]
    assign = np.zeros(n, dtype=int)
    for _ in range(iters):
        d = np.linalg.norm(x[:, None, :] - cent[None, :, :], axis=-1)
        new_assign = d.argmin(axis=1)
        # keep clusters non-empty: give empty clusters the farthest point
        for j in range(k):
            if not (new_assign == j).any():
                far = int(d.min(axis=1).argmax())
                new_assign[far] = j
        if (new_assign == assign).all():
            break
        assign = new_assign
        for j in range(k):
            cent[j] = x[assign == j].mean(axis=0)
    groups, aggs = [], []
    for j in range(k):
        members = sorted(np.flatnonzero(assign == j).tolist())
        groups.append(tuple(members))
        aggs.append(_group_center(effs, members))
    dt = time.perf_counter() - t0
    plan = GroupPlan(tuple(groups), tuple(aggs), method=f"kmeans{k}", solve_time_s=dt)
    plan.validate(n)
    return dataclasses.replace(plan, objective=plan_cost(lat, plan))


def random_grouping(lat: np.ndarray, k: int, rng: np.random.Generator | None = None) -> GroupPlan:
    rng = rng or np.random.default_rng(0)
    n = lat.shape[0]
    t0 = time.perf_counter()
    perm = rng.permutation(n)
    splits = np.array_split(perm, k)
    groups = tuple(tuple(sorted(int(i) for i in s)) for s in splits if len(s))
    aggs = tuple(int(rng.choice(list(g))) for g in groups)
    dt = time.perf_counter() - t0
    plan = GroupPlan(groups, aggs, method="random", solve_time_s=dt)
    plan.validate(n)
    return dataclasses.replace(plan, objective=plan_cost(lat, plan))


def no_grouping(lat: np.ndarray) -> GroupPlan:
    """Flat all-to-all baseline expressed as k=N singleton groups."""
    n = lat.shape[0]
    groups = tuple((i,) for i in range(n))
    plan = GroupPlan(groups, tuple(range(n)), method="none")
    return dataclasses.replace(plan, objective=plan_cost(lat, plan))


singleton_grouping = no_grouping


def best_plan(
    lat: np.ndarray,
    *,
    tiv: bool = True,
    tiv_margin: float = 0.05,
    tolerance: int = 1,
    method: str = "milp",
    time_limit_s: float = 5.0,
    payload_bytes: float | None = None,
    bandwidth_mbps: float | np.ndarray | None = None,
    filter_keep: float = 1.0,
    barrier: bool = False,
    streaming: bool = False,
) -> GroupPlan:
    """GeoCoCo's guided planner: search k in the band around k*, keep the best.

    The flat (no-grouping) plan is always a candidate: when intra-group
    latency is not << inter (e.g. uniform-jitter WANs), hierarchy loses and
    GeoCoCo must fall back to direct transmission — the adaptive behavior
    the paper's robustness results (Fig. 17) rely on.

    When ``payload_bytes`` is given, candidates are ranked by the simulated
    round makespan (latency + NIC-contended serialization, with
    ``filter_keep`` modeling the aggregator-side payload reduction) instead
    of the latency-only MILP objective — the "balance latency and resource
    utilization" behavior of the Planner (Sec 4.1).  The makespan is the
    event-driven **transfer-DAG critical path** by default, so grouping
    decisions reward cross-stage overlap (a plan whose fast groups exchange
    while slow groups still gather scores better than the phase-sum would
    suggest); pass ``barrier=True`` to rank by the legacy barrier phase-sum
    instead (what a barrier engine will actually execute).  The MILP itself
    stays Algorithm 1's latency formulation.

    ``streaming=True`` (the streaming replication engine's ranking context)
    scores candidates by the makespan of **two stitched epochs**
    (:func:`~repro.core.schedule.stitch_schedules`) instead of one isolated
    round: a plan whose epoch-``e+1`` gathers pipeline under epoch-``e``
    scatters scores the throughput it will actually sustain, which can
    rank-invert plans that tie on the single-round critical path.

    The guided band is the ~order-of-magnitude planning-cost reduction vs
    exhaustive k in [2, N-1] claimed in Sec 6.4.
    """
    if streaming and barrier:
        raise ValueError(
            "streaming ranking runs the event engine; barrier=True has no "
            "cross-epoch semantics"
        )

    def rank(p: GroupPlan) -> float:
        if payload_bytes is None:
            return plan_cost(lat, p, tiv=tiv, tiv_margin=tiv_margin)
        from .schedule import hierarchical_schedule, stitch_schedules
        from .simulator import WANSimulator

        bw = np.inf if bandwidth_mbps is None else bandwidth_mbps
        sim = WANSimulator(lat, bw, barrier=barrier)
        gp = np.array(
            [sum(payload_bytes for _ in g) * filter_keep for g in p.groups]
        )
        sched = hierarchical_schedule(
            p, payload_bytes, group_payload_bytes=gp, lat=lat,
            tiv=tiv, tiv_margin=tiv_margin,
        )
        if streaming:
            sched = stitch_schedules([sched, sched], n=lat.shape[0])
        return sim.run(sched).makespan_ms

    try:
        plan_fn = _strategies.get("planner", method)
    except KeyError as e:
        raise ValueError(str(e)) from None
    cands = [(rank(no_grouping(lat)), no_grouping(lat))]
    for k in k_search_band(lat.shape[0], tolerance=tolerance):
        p = plan_fn(lat, k, tiv=tiv, tiv_margin=tiv_margin,
                    time_limit_s=time_limit_s)
        cands.append((rank(p), p))
    return min(cands, key=lambda t: t[0])[1]


# ---------------------------------------------------------------------------
# registry wiring: every grouping strategy is addressable by name with the
# uniform planner contract fn(lat, k, *, tiv, tiv_margin, time_limit_s, rng)
# ---------------------------------------------------------------------------


_strategies.register(
    "planner", "milp",
    lambda lat, k, *, tiv=False, tiv_margin=0.05, time_limit_s=5.0, rng=None:
        milp_grouping(lat, k, tiv=tiv, tiv_margin=tiv_margin,
                      time_limit_s=time_limit_s),
)
_strategies.register(
    "planner", "kcenter",
    lambda lat, k, *, tiv=False, tiv_margin=0.05, time_limit_s=5.0, rng=None:
        kcenter_grouping(lat, k, tiv=tiv, tiv_margin=tiv_margin),
)
_strategies.register(
    "planner", "agglomerative",
    lambda lat, k, *, tiv=False, tiv_margin=0.05, time_limit_s=5.0, rng=None:
        agglomerative_grouping(lat, k),
)
_strategies.register(
    "planner", "kmeans",
    lambda lat, k, *, tiv=False, tiv_margin=0.05, time_limit_s=5.0, rng=None:
        kmeans_grouping(lat, k, rng),
)
_strategies.register(
    "planner", "random",
    lambda lat, k, *, tiv=False, tiv_margin=0.05, time_limit_s=5.0, rng=None:
        random_grouping(lat, k, rng),
)
_strategies.register(
    "planner", "none",
    lambda lat, k=0, **_kw: no_grouping(lat),
)

# legacy view of the registry (kept for callers that index by name directly)
STRATEGIES: dict[str, Callable[..., GroupPlan]] = {
    name: fn for name, fn in _strategies.items("planner")
}


# ---------------------------------------------------------------------------
# Damped replanner (Sec 4.2 "Re-group damping strategy")
# ---------------------------------------------------------------------------


class Replanner:
    """Holds the current plan; regroups only on sustained latency deviation.

    A new plan is computed when the mean relative deviation of the observed
    latency matrix from the matrix used at planning time exceeds
    ``threshold`` (default 20%) for at least ``sustain`` consecutive
    observations — transient RTT noise is suppressed.

    **Force contract**: a forced replan request without a latency matrix
    (:meth:`force` with no argument, or :meth:`on_node_failure`) only sets a
    flag — the replan happens at the *next* :meth:`observe`, because there
    is nothing to plan against until a matrix arrives.  Event-driven callers
    that need the plan to react *immediately* (e.g.
    ``repro.control.ControlPlane.force_replan`` on a sustained-deviation or
    straggler signal) pass the last observed matrix to :meth:`force`, which
    replans before returning.
    """

    def __init__(
        self,
        plan_fn: Callable[[np.ndarray], GroupPlan],
        *,
        threshold: float = 0.20,
        sustain: int = 3,
    ):
        self.plan_fn = plan_fn
        self.threshold = threshold
        self.sustain = sustain
        self._plan: GroupPlan | None = None
        self._plan_lat: np.ndarray | None = None
        self._over = 0
        self._force = False
        self.replan_count = 0

    @property
    def plan(self) -> GroupPlan | None:
        return self._plan

    def deviation(self, lat: np.ndarray) -> float:
        if self._plan_lat is None:
            return float("inf")
        base = self._plan_lat
        mask = base > 0
        return float(np.abs(lat[mask] - base[mask]).mean() / base[mask].mean())

    def observe(self, lat: np.ndarray) -> GroupPlan:
        """Feed a fresh latency matrix; returns the (possibly updated) plan."""
        if self._plan is None or self._force:
            return self._replan(lat)
        if self.deviation(lat) > self.threshold:
            self._over += 1
            if self._over >= self.sustain:
                return self._replan(lat)
        else:
            self._over = 0
        return self._plan

    def _replan(self, lat: np.ndarray) -> GroupPlan:
        self._plan = self.plan_fn(lat)
        self._plan_lat = lat.copy()
        self._over = 0
        self._force = False
        self.replan_count += 1
        return self._plan

    def force(self, lat: np.ndarray | None = None) -> GroupPlan | None:
        """Request a replan.

        With ``lat`` the replan happens **immediately** and the new plan is
        returned; without it only a flag is set and the replan fires at the
        next :meth:`observe` (see the class docstring's force contract).
        """
        if lat is not None:
            return self._replan(lat)
        self._force = True
        return None

    def on_node_failure(self, node: int) -> GroupPlan | None:
        """Aggregator/member failover (Sec 4.4): drop the node immediately;
        the full replan happens at the next observation (the no-matrix arm
        of the force contract)."""
        if self._plan is None:
            return None
        self._plan = self._plan.drop_node(node)
        self.force()  # full regroup at next observe()
        return self._plan
