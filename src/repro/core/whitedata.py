"""Task-preserved white-data filtering (paper Sec 4.3).

*White data* — updates transmitted but eventually discarded without affecting
the receiver's final state — comes from (paper's taxonomy):

* **conflicting / aborted** transactions (OCC validation failures),
* **redundant content** (semantically identical updates repeatedly sent),
* **stale** updates (version already superseded at the receiver),
* **null or sparse** updates (no receiver-visible payload effect).

The filter runs at the group aggregator on local metadata only (O(1)
version-vector + hash checks per update, no global coordination) and drops
white data *before* it crosses the WAN.  It is **task-preserving**: merging
the filtered batch yields the same value state as merging the raw batch
(property-tested in ``tests/test_property_whitedata.py``).

Inter-group conflicts are intentionally *not* filtered (paper Sec 6.6): that
would require cross-aggregator digest exchange; the loser of an inter-group
conflict is aborted during global validation after the exchange, exactly as
in the baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from . import strategies as _strategies
from .crdt import DeltaCRDTStore, Update, Version
from .occ import Txn, txn_updates, validate_epoch

__all__ = [
    "FilterStats",
    "FilterResult",
    "filter_group_batch",
    "no_filter",
    "white_ratio",
]


@dataclasses.dataclass
class FilterStats:
    total_updates: int = 0
    total_bytes: int = 0
    kept_updates: int = 0
    kept_bytes: int = 0
    aborted_updates: int = 0
    aborted_bytes: int = 0
    duplicate_updates: int = 0
    duplicate_bytes: int = 0
    stale_updates: int = 0
    stale_bytes: int = 0
    null_updates: int = 0
    null_bytes: int = 0

    def merge(self, other: "FilterStats") -> "FilterStats":
        out = FilterStats()
        for f in dataclasses.fields(FilterStats):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        return out

    @property
    def white_bytes(self) -> int:
        return self.total_bytes - self.kept_bytes

    @property
    def white_byte_ratio(self) -> float:
        return self.white_bytes / self.total_bytes if self.total_bytes else 0.0

    @property
    def white_update_ratio(self) -> float:
        if not self.total_updates:
            return 0.0
        return 1.0 - self.kept_updates / self.total_updates

    @property
    def wire_bytes(self) -> int:
        """Bytes actually crossing the WAN: surviving payloads + validation
        tombstones (key+version metadata, ~24 B) for every dropped update.

        Dropping a conflicting transaction's *payload* is safe, but its
        write-set footprint must still reach global validation — otherwise a
        transaction that lost a key to the dropped one could be wrongly
        reinstated (first-writer-wins is only monotone when every writer's
        metadata is visible).  GeoGauss exchanges read/write-set metadata for
        epoch validation anyway; GeoCoCo strips the payloads only.
        """
        dropped = self.total_updates - self.kept_updates
        return self.kept_bytes + 24 * dropped


@dataclasses.dataclass
class FilterResult:
    kept: list[Update]
    aborted_txns: set[int]
    stats: FilterStats


def filter_group_batch(
    txns: Sequence[Txn],
    snapshot: DeltaCRDTStore,
    *,
    enable_abort: bool = True,
    enable_dedup: bool = True,
    enable_stale: bool = True,
    enable_null: bool = True,
) -> FilterResult:
    """Aggregator-side filtering of one group's epoch batch.

    ``snapshot`` is the aggregator's epoch-start replicated state (identical
    on all replicas under synchronized epochs, so the checks are sound).

    Pipeline (each rule O(1) per update):
      1. *intra-group OCC pre-validation* — transactions that lose a
         write-write conflict inside the group abort here; all their updates
         are white (sound: first-writer-wins is monotone, see ``occ.py``).
      2. *dedup* — identical ``(key, value)`` content from surviving
         transactions collapses to the earliest version (CRDT idempotence
         makes re-sends meaningless).
      3. *stale* — version not newer than the snapshot's current version.
      4. *null-effect* — value equals the snapshot's current value: the
         payload is stripped and only the 0-byte version bump is forwarded
         (hash check in the paper; byte-equality here).
    """
    stats = FilterStats()
    all_updates: list[Update] = []
    for t in txns:
        all_updates.extend(txn_updates(t))
    stats.total_updates = len(all_updates)
    stats.total_bytes = sum(u.nbytes for u in all_updates)

    aborted: set[int] = set()
    if enable_abort:
        _, aborted = validate_epoch(txns, snapshot)

    kept: list[Update] = []
    seen_content: dict[tuple[str, bytes], Version] = {}
    for u in all_updates:
        if u.txn_id in aborted:
            stats.aborted_updates += 1
            stats.aborted_bytes += u.nbytes
            continue
        if enable_stale and u.version <= snapshot.version_of(u.key):
            stats.stale_updates += 1
            stats.stale_bytes += u.nbytes
            continue
        if enable_dedup:
            ck = (u.key, u.value)
            prev = seen_content.get(ck)
            if prev is not None and prev <= u.version:
                stats.duplicate_updates += 1
                stats.duplicate_bytes += u.nbytes
                continue
            seen_content[ck] = u.version
        if enable_null and snapshot.get(u.key) == u.value:
            # Wire-format optimization: the payload equals the receiver's
            # epoch-start snapshot value (all replicas share it), so only the
            # version-bump metadata crosses the WAN and the receiver
            # reconstructs the full update locally.  Semantically the kept
            # update is still the full one — the CRDT layer never sees
            # stripped payloads, keeping the merge a clean lattice join.
            wire = u.meta_only().nbytes
            stats.null_updates += 1
            stats.null_bytes += u.nbytes - wire
            kept.append(u)
            stats.kept_updates += 1
            stats.kept_bytes += wire
            continue
        kept.append(u)
        stats.kept_updates += 1
        stats.kept_bytes += u.nbytes

    return FilterResult(kept=kept, aborted_txns=aborted, stats=stats)


def no_filter(txns: Sequence[Txn], snapshot: DeltaCRDTStore) -> FilterResult:
    """Baseline passthrough: every update is kept and paid on the wire.

    Registered so the engine resolves filtering-off through the same
    registry path as the real filter (``wire_bytes`` then equals the raw
    batch bytes — nothing dropped, no tombstone overhead)."""
    kept = [u for t in txns for u in txn_updates(t)]
    stats = FilterStats(
        total_updates=len(kept),
        total_bytes=sum(u.nbytes for u in kept),
        kept_updates=len(kept),
        kept_bytes=sum(u.nbytes for u in kept),
    )
    return FilterResult(kept=kept, aborted_txns=set(), stats=stats)


def white_ratio(stats: FilterStats) -> float:
    return stats.white_byte_ratio


# registry wiring: aggregator-side filters by name (two-plane registry —
# the device plane's `geococo` top-k exchange is the gradient analogue)
_strategies.register("filter", "whitedata", filter_group_batch)
_strategies.register("filter", "none", no_filter)
