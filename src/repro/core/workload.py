"""Workload generators: YCSB (Zipfian) and TPC-C-style mixes (paper Sec 6.1).

YCSB: key-value operations with Zipfian access skew controlled by theta;
read/write ratio configurable (Fig 18 varies theta in 0.5..0.9 under 95/5 and
50/50 mixes; Fig 14 / Table 1 sweep conflict ratios).

TPC-C: the paper's four custom mixes over the five official transaction
types — TPCC-A (write-intensive), TPCC-B (read-intensive), TPCC-C (balanced),
TPCC-D (real-time).  Transactions touch warehouse-scoped keys with a small
cross-warehouse probability, matching NewOrder's remote-item behavior.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from .crdt import DeltaCRDTStore, Version
from .occ import Txn

__all__ = [
    "ZipfianSampler",
    "YCSBConfig",
    "YCSBGenerator",
    "TPCC_MIXES",
    "TPCCConfig",
    "TPCCGenerator",
    "DiurnalLoad",
]


def _node_snapshot(snapshot, node: int) -> DeltaCRDTStore | None:
    """Resolve the snapshot a given node executes against.

    ``snapshot`` is either one globally-merged store (every replica reads
    fresh state — the pre-staleness model) or a per-node sequence of views
    (``EngineConfig(staleness_feedback=True)``: each replica's view advances
    only when the stitched simulation delivered that node's inbound epoch
    transfers, so reads are versioned against possibly-stale state).
    """
    if snapshot is None or isinstance(snapshot, DeltaCRDTStore):
        return snapshot
    return snapshot[node]


class ZipfianSampler:
    """Bounded Zipfian sampler: P(rank r) ∝ 1 / r^theta over n_keys items.

    theta=0 is uniform; theta→1+ concentrates on a hot head.  Ranks are
    shuffled onto key ids so that "hot" keys are spread across the keyspace.
    """

    def __init__(self, n_keys: int, theta: float, rng: np.random.Generator):
        if n_keys <= 0:
            raise ValueError("n_keys must be positive")
        ranks = np.arange(1, n_keys + 1, dtype=float)
        p = ranks ** (-theta)
        self.p = p / p.sum()
        self.perm = rng.permutation(n_keys)
        self.n_keys = n_keys

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        r = rng.choice(self.n_keys, size=size, p=self.p)
        return self.perm[r]

    def top_mass(self, k: int) -> float:
        """Probability mass of the ``k`` most popular keys.

        ``self.p`` is already rank-descending, so this is the head sum —
        the steady-state hit ratio of an ideal size-``k`` cache-aside tier
        over this distribution (the serving plane's cache model)."""
        if k <= 0:
            return 0.0
        return float(self.p[: min(k, self.n_keys)].sum())


@dataclasses.dataclass
class YCSBConfig:
    n_keys: int = 10_000
    theta: float = 0.7
    read_ratio: float = 0.5
    ops_per_txn: int = 4
    value_bytes: int = 100
    # fraction of write ops redirected to a tiny shared hot set — the knob the
    # benchmarks use to hit the paper's target conflict ratios exactly
    hot_write_frac: float = 0.0
    hot_set_size: int = 16
    # fraction of writes that re-write the key's current value (no-op UPSERTs;
    # the "null or sparse data" class of white data)
    rewrite_frac: float = 0.0
    # when True (and the generator is given node regions), each region has its
    # own hot set — the paper's workload-locality assumption (Sec 6.6):
    # conflicts concentrate within latency-proximate groups
    hot_locality: bool = False


class YCSBGenerator:
    """Generates per-node, per-epoch transaction batches."""

    def __init__(
        self,
        cfg: YCSBConfig,
        n_nodes: int,
        seed: int = 0,
        node_region: Sequence[int] | None = None,
    ):
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)
        self.sampler = ZipfianSampler(cfg.n_keys, cfg.theta, self.rng)
        self.node_region = (
            np.asarray(node_region) if node_region is not None else np.zeros(n_nodes, dtype=int)
        )
        self._txn_counter = 0
        # node-local monotone commit sequence: Version = (epoch, seq, node)
        # must be unique per transaction — a random draw can collide within
        # (node, epoch), making two conflicting writers both "win" a key
        self._seq = [0] * n_nodes

    def _value(self, rng: np.random.Generator) -> bytes:
        # structured (low-entropy) rows, like real DB records: an 8-byte
        # unique seed tiled to the row size — unique per write yet compressible
        seed = rng.bytes(8)
        reps = max(1, self.cfg.value_bytes // 8)
        return (seed * reps)[: self.cfg.value_bytes]

    def _write_value(self, snap: DeltaCRDTStore | None, key: str) -> bytes:
        """The payload for one write op: a fresh value, or (with probability
        ``rewrite_frac``, when the key exists in the node's view) a re-write
        of its current value.

        Randomness is drawn *unconditionally* so the RNG stream — and with
        it every subsequent key sample and read/write split — is
        independent of snapshot contents.  Per-node stale views
        (``staleness_feedback``) may therefore change read versions and
        rewrite *payloads* only, never which keys a transaction touches:
        that is what keeps write-write aborts invariant and the abort set
        monotone in staleness.
        """
        val = self._value(self.rng)
        if self.cfg.rewrite_frac > 0.0:
            rewrite = self.rng.random() < self.cfg.rewrite_frac
            cur = snap.get(key) if snap is not None else None
            if rewrite and cur is not None:
                return cur
        return val

    def epoch_txns(
        self,
        epoch: int,
        txns_per_node: int,
        snapshot: DeltaCRDTStore | Sequence[DeltaCRDTStore] | None = None,
    ) -> dict[int, list[Txn]]:
        """One epoch's transactions for every node: {node: [Txn, ...]}.

        ``snapshot`` is a single globally-merged store or a per-node sequence
        of snapshot views (see :func:`_node_snapshot`); reads are versioned
        against the executing node's view.
        """
        cfg = self.cfg
        out: dict[int, list[Txn]] = {}
        for node in range(self.n_nodes):
            snap = _node_snapshot(snapshot, node)
            txns: list[Txn] = []
            for _ in range(txns_per_node):
                keys = self.sampler.sample(self.rng, cfg.ops_per_txn)
                reads: list[tuple[str, Version]] = []
                writes: list[tuple[str, bytes]] = []
                for k in keys:
                    if self.rng.random() < cfg.read_ratio:
                        key = f"k{int(k)}"
                        ver = (
                            snap.version_of(key)
                            if snap is not None
                            else Version.ZERO
                        )
                        reads.append((key, ver))
                    else:
                        if (
                            cfg.hot_write_frac > 0.0
                            and self.rng.random() < cfg.hot_write_frac
                        ):
                            h = int(self.rng.integers(0, cfg.hot_set_size))
                            if cfg.hot_locality:
                                key = f"h{int(self.node_region[node])}:{h}"
                            else:
                                key = f"k{h}"
                        else:
                            key = f"k{int(k)}"
                        writes.append((key, self._write_value(snap, key)))
                seq = self._seq[node]
                self._seq[node] += 1
                txns.append(
                    Txn(
                        txn_id=self._txn_counter,
                        node=node,
                        epoch=epoch,
                        seq=seq,
                        read_set=tuple(reads),
                        write_set=tuple(dict(writes).items()),
                    )
                )
                self._txn_counter += 1
            out[node] = txns
        return out


# ---------------------------------------------------------------------------
# TPC-C mixes (paper Sec 6.1)
# ---------------------------------------------------------------------------

# (NewOrder, Payment, OrderStatus, Delivery, StockLevel)
TPCC_MIXES: dict[str, tuple[float, float, float, float, float]] = {
    # write-intensive: NewOrder+Payment > 90%
    "TPCC-A": (0.55, 0.37, 0.03, 0.03, 0.02),
    # read-intensive: OrderStatus + StockLevel dominate
    "TPCC-B": (0.08, 0.08, 0.42, 0.04, 0.38),
    # balanced: even
    "TPCC-C": (0.20, 0.20, 0.20, 0.20, 0.20),
    # real-time: OrderStatus-heavy with moderate writes
    "TPCC-D": (0.18, 0.14, 0.50, 0.08, 0.10),
}

_TXN_WRITES = {  # (n_write_keys, n_read_keys, value_bytes)
    "NewOrder": (10, 3, 120),
    "Payment": (3, 1, 80),
    "OrderStatus": (0, 4, 0),
    "Delivery": (6, 2, 100),
    "StockLevel": (0, 8, 0),
}
_TXN_TYPES = tuple(_TXN_WRITES)


@dataclasses.dataclass
class TPCCConfig:
    n_warehouses: int = 100
    mix: str = "TPCC-C"
    remote_prob: float = 0.10       # cross-warehouse access (NewOrder remote items)
    items_per_warehouse: int = 200


class TPCCGenerator:
    def __init__(self, cfg: TPCCConfig, n_nodes: int, seed: int = 0):
        if cfg.mix not in TPCC_MIXES:
            raise ValueError(f"unknown mix {cfg.mix!r}")
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)
        self._txn_counter = 0
        # node-local monotone commit sequence (see YCSBGenerator): a random
        # seq can collide within (node, epoch) and hand two conflicting
        # writers the same Version
        self._seq = [0] * n_nodes
        # tpmC accounting must stay O(1) in the horizon (the epoch-sink
        # pipeline holds the whole run in bounded memory): NewOrder txn ids
        # are kept for the *latest generated epoch only* — commit-time
        # intersection is per-epoch anyway — with a cumulative counter for
        # run totals
        self.neworder_ids: set[int] = set()
        self.neworder_count = 0
        # warehouses are partitioned across nodes (home warehouses)
        self.home = np.array_split(np.arange(cfg.n_warehouses), n_nodes)

    def _key(self, warehouse: int, item: int) -> str:
        return f"w{warehouse}:i{item}"

    def epoch_txns(
        self,
        epoch: int,
        txns_per_node: int,
        snapshot: DeltaCRDTStore | Sequence[DeltaCRDTStore] | None = None,
    ) -> dict[int, list[Txn]]:
        cfg = self.cfg
        probs = np.array(TPCC_MIXES[cfg.mix])
        out: dict[int, list[Txn]] = {}
        self.neworder_ids = set()
        for node in range(self.n_nodes):
            snap = _node_snapshot(snapshot, node)
            homes = self.home[node]
            txns: list[Txn] = []
            for _ in range(txns_per_node):
                ttype = _TXN_TYPES[int(self.rng.choice(5, p=probs))]
                n_w, n_r, vbytes = _TXN_WRITES[ttype]
                writes: list[tuple[str, bytes]] = []
                reads: list[tuple[str, Version]] = []
                for _ in range(n_w):
                    if self.rng.random() < cfg.remote_prob or len(homes) == 0:
                        w = int(self.rng.integers(0, cfg.n_warehouses))
                    else:
                        w = int(self.rng.choice(homes))
                    item = int(self.rng.integers(0, cfg.items_per_warehouse))
                    writes.append((self._key(w, item), self.rng.bytes(vbytes)))
                for _ in range(n_r):
                    w = (
                        int(self.rng.choice(homes))
                        if len(homes)
                        else int(self.rng.integers(0, cfg.n_warehouses))
                    )
                    item = int(self.rng.integers(0, cfg.items_per_warehouse))
                    key = self._key(w, item)
                    ver = (
                        snap.version_of(key)
                        if snap is not None
                        else Version.ZERO
                    )
                    reads.append((key, ver))
                seq = self._seq[node]
                self._seq[node] += 1
                txns.append(
                    Txn(
                        txn_id=self._txn_counter,
                        node=node,
                        epoch=epoch,
                        seq=seq,
                        read_set=tuple(reads),
                        write_set=tuple(dict(writes).items()),
                        )
                )
                self._txn_counter += 1
                # annotate NewOrder txns for tpmC accounting
                if ttype == "NewOrder":
                    self.neworder_ids.add(txns[-1].txn_id)
                    self.neworder_count += 1
            out[node] = txns
        return out


class DiurnalLoad:
    """Deterministic diurnal (time-varying) load wrapper for any generator.

    Scales the per-epoch transaction count sinusoidally —
    ``round(txns_per_node * (1 + amplitude * sin(2*pi*epoch/period_epochs
    + phase)))``, floored at 1 — so a long-horizon streaming run replays a
    day-night cycle: peak epochs push the WAN into backlog, trough epochs
    let replicas pay it off.  Purely a multiplier on the wrapped
    generator's ``epoch_txns``; key skew, read ratio and txn ids stay the
    wrapped generator's (the abort-trajectory benchmarks lean on the
    determinism: same seed, same trace, same cycle).
    """

    def __init__(
        self,
        inner,
        *,
        period_epochs: int,
        amplitude: float = 0.5,
        phase: float = 0.0,
    ):
        if period_epochs <= 0:
            raise ValueError("period_epochs must be positive")
        if not 0.0 <= amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        self.inner = inner
        self.period_epochs = int(period_epochs)
        self.amplitude = float(amplitude)
        self.phase = float(phase)

    def load_factor(self, epoch: int) -> float:
        """The multiplier applied at ``epoch`` (1 ± amplitude)."""
        ang = 2.0 * np.pi * epoch / self.period_epochs + self.phase
        return 1.0 + self.amplitude * float(np.sin(ang))

    def epoch_txns(
        self,
        epoch: int,
        txns_per_node: int,
        snapshot: DeltaCRDTStore | Sequence[DeltaCRDTStore] | None = None,
    ) -> dict[int, list[Txn]]:
        scaled = max(1, int(round(txns_per_node * self.load_factor(epoch))))
        return self.inner.epoch_txns(epoch, scaled, snapshot=snapshot)
