"""Transmission schedules (paper Sec 4.4: Consistency-Guaranteed Transmission).

A :class:`TransmissionSchedule` is an ordered list of *phases*; transfers
within a phase run in parallel, phases are barrier-synchronized (epoch
boundaries forbid cross-round pipelining — Sec 6.2 "we focus on per-round
performance").  Builders:

* :func:`all_to_all_schedule` — the flat baseline: ``n(n-1)`` point-to-point
  transfers in one phase.
* :func:`hierarchical_schedule` — GeoCoCo's 3-phase flow: members->aggregator,
  aggregator<->aggregator (optionally over TIV relay paths), aggregator->members.
* :func:`leader_schedule` — single-leader (Raft-ish) dissemination, used by the
  CockroachDB-plane model; GeoCoCo groups the followers.

Per-node message-count accounting backs the paper's round guarantee
(Eq. 6-7): ``C_geococo <= C_baseline = 2(N-1)``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from . import strategies as _strategies
from .latency import one_relay_effective
from .planner import GroupPlan

__all__ = [
    "Transfer",
    "TransmissionSchedule",
    "all_to_all_schedule",
    "hierarchical_schedule",
    "leader_schedule",
    "messages_per_node",
    "max_messages_per_node",
]


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One point-to-point payload movement.

    ``via >= 0`` marks an application-layer relay (overlay TIV exploitation):
    the simulator charges ``lat[src,via] + lat[via,dst]`` propagation and the
    bottleneck bandwidth of the two hops, and the relay node's message counters
    are charged one receive + one send.
    """

    src: int
    dst: int
    nbytes: float
    via: int = -1
    tag: str = ""


@dataclasses.dataclass
class TransmissionSchedule:
    phases: list[list[Transfer]]
    label: str = ""

    @property
    def n_transfers(self) -> int:
        return sum(len(p) for p in self.phases)

    @property
    def total_bytes(self) -> float:
        # relayed transfers traverse two WAN hops
        return float(
            sum(t.nbytes * (2.0 if t.via >= 0 else 1.0) for p in self.phases for t in p)
        )

    def all_transfers(self) -> Iterable[Transfer]:
        for p in self.phases:
            yield from p


def all_to_all_schedule(
    n: int, payload_bytes: np.ndarray | float, *, label: str = "all_to_all"
) -> TransmissionSchedule:
    """Flat baseline: every node sends its update batch to every other node.

    ``payload_bytes`` is a scalar or per-source vector (node i's batch size).
    """
    pay = np.broadcast_to(np.asarray(payload_bytes, dtype=float), (n,))
    phase = [
        Transfer(i, j, float(pay[i]), tag="a2a")
        for i in range(n)
        for j in range(n)
        if i != j
    ]
    return TransmissionSchedule([phase], label=label)


def hierarchical_schedule(
    plan: GroupPlan,
    payload_bytes: np.ndarray | float,
    *,
    group_payload_bytes: np.ndarray | None = None,
    lat: np.ndarray | None = None,
    tiv: bool = False,
    tiv_margin: float = 0.05,
    label: str = "geococo",
) -> TransmissionSchedule:
    """GeoCoCo's hierarchical 3-phase round (Fig. 8).

    Phase 1 (intra, gather):   each simple node -> its aggregator.
    Phase 2 (inter, exchange): each aggregator -> every other aggregator, with
        the *consolidated group payload* (post filtering/aggregation).  When
        ``tiv`` and ``lat`` are given, pairs with a profitable one-relay path
        are routed ``via`` that relay (Sec 5 overlay implementation).
    Phase 3 (intra, scatter):  each aggregator -> its simple nodes with the
        merged global result.

    ``group_payload_bytes[j]``, if given, is group j's post-filter consolidated
    payload; by default it is the sum of member payloads (no filtering, no
    dedup).  The phase-3 broadcast payload is the merged global state delta:
    the sum of all group payloads (every member must receive every surviving
    remote update, matching full replication).
    """
    # node ids need not be contiguous (e.g. after a drop_node failover)
    n = max(i for g in plan.groups for i in g) + 1
    pay = np.broadcast_to(np.asarray(payload_bytes, dtype=float), (n,))
    if group_payload_bytes is None:
        gp = np.array([sum(pay[i] for i in g) for g in plan.groups])
    else:
        gp = np.asarray(group_payload_bytes, dtype=float)
        if gp.shape != (plan.k,):
            raise ValueError(f"group_payload_bytes must have shape ({plan.k},)")

    relay = None
    if tiv and lat is not None:
        _, relay = one_relay_effective(lat, margin=tiv_margin)

    phase1: list[Transfer] = []
    for g, a in zip(plan.groups, plan.aggregators):
        for i in g:
            if i != a:
                phase1.append(Transfer(i, a, float(pay[i]), tag="gather"))

    phase2: list[Transfer] = []
    for j1, a1 in enumerate(plan.aggregators):
        for j2, a2 in enumerate(plan.aggregators):
            if j1 == j2:
                continue
            via = -1
            if relay is not None:
                via = int(relay[a1, a2])
            phase2.append(Transfer(a1, a2, float(gp[j1]), via=via, tag="exchange"))

    total = float(gp.sum())
    phase3: list[Transfer] = []
    for j, (g, a) in enumerate(zip(plan.groups, plan.aggregators)):
        # members receive the merged result minus what they already hold
        # locally (their own contribution stayed local): charge total - pay[i].
        for i in g:
            if i != a:
                phase3.append(
                    Transfer(a, i, max(total - float(pay[i]), 0.0), tag="scatter")
                )

    phases = [p for p in (phase1, phase2, phase3) if p]
    return TransmissionSchedule(phases, label=label)


def leader_schedule(
    n: int,
    leader: int,
    payload_bytes: float,
    plan: GroupPlan | None = None,
    *,
    label: str = "leader",
) -> TransmissionSchedule:
    """Single-leader replication (CRDB/Raft plane).

    Without a plan: leader -> each follower directly (flat AppendEntries
    fan-out).  With a plan: leader -> each group aggregator -> group members
    (GeoCoCo hooked into RaftTransport, Sec 5 "Extensions").
    """
    if plan is None:
        phase = [
            Transfer(leader, i, payload_bytes, tag="append")
            for i in range(n)
            if i != leader
        ]
        return TransmissionSchedule([phase], label=label)
    phase1: list[Transfer] = []
    phase2: list[Transfer] = []
    for g, a in zip(plan.groups, plan.aggregators):
        tgt = a if leader not in g else leader
        if tgt != leader:
            phase1.append(Transfer(leader, tgt, payload_bytes, tag="append"))
        for i in g:
            if i != tgt and i != leader:
                phase2.append(Transfer(tgt, i, payload_bytes, tag="relay"))
    phases = [p for p in (phase1, phase2) if p]
    return TransmissionSchedule(phases, label=label + "+geococo")


# registry wiring: transmission-schedule builders are addressable by name so
# the engine (and future planes: Raft, multi-cloud) resolve them uniformly
_strategies.register("schedule", "all_to_all", all_to_all_schedule)
_strategies.register("schedule", "hierarchical", hierarchical_schedule)
_strategies.register("schedule", "leader", leader_schedule)


# ---------------------------------------------------------------------------
# Round-count accounting (Eq. 6-7)
# ---------------------------------------------------------------------------


def messages_per_node(schedule: TransmissionSchedule, n: int) -> np.ndarray:
    """Total messages (sends + receives, relays counted) per node."""
    cnt = np.zeros(n, dtype=int)
    for t in schedule.all_transfers():
        cnt[t.src] += 1
        cnt[t.dst] += 1
        if t.via >= 0:
            cnt[t.via] += 2  # relay receives and forwards
    return cnt


def max_messages_per_node(schedule: TransmissionSchedule, n: int) -> int:
    return int(messages_per_node(schedule, n).max())
