"""Transmission schedules (paper Sec 4.4: Consistency-Guaranteed Transmission).

A :class:`TransmissionSchedule` is a dependency-tracked *transfer DAG*: each
:class:`Transfer` carries the indices of the transfers it must wait for
(aggregator exchanges depend on the member gathers they consolidate, scatters
depend on the exchanges that deliver the remote group payloads).  The
event-driven :class:`~repro.core.simulator.WANSimulator` starts every transfer
the moment its dependencies have been delivered, so rounds pipeline across
what used to be barrier phases.

``phases`` is retained as a **derived compatibility view**: builders record
the positional phase each transfer would have occupied in the pre-DAG
barrier schedule, and ``WANSimulator(barrier=True)`` executes that view with
the original phase-sum semantics — bit-identical to the pre-refactor
simulator.  Schedules constructed from an explicit list of phases (the
legacy constructor form ``TransmissionSchedule([[t, ...], ...])``) get full
barrier dependency edges, so they behave identically under both engines up
to intra-phase overlap.

Builders:

* :func:`all_to_all_schedule` — the flat baseline: ``n(n-1)`` point-to-point
  transfers, no dependencies (one phase).
* :func:`hierarchical_schedule` — GeoCoCo's 3-stage flow: members->aggregator,
  aggregator<->aggregator (optionally over TIV relay paths), aggregator->
  members, with real dependency edges between the stages.
* :func:`leader_schedule` — single-leader (Raft-ish) dissemination, used by the
  CockroachDB-plane model; each relay hop depends on its inbound append.

Per-node message-count accounting backs the paper's round guarantee
(Eq. 6-7): ``C_geococo <= C_baseline = 2(N-1)``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from . import strategies as _strategies
from .latency import one_relay_effective
from .planner import GroupPlan

__all__ = [
    "Transfer",
    "TransmissionSchedule",
    "all_to_all_schedule",
    "hierarchical_schedule",
    "leader_schedule",
    "stitch_schedules",
    "StitchState",
    "messages_per_node",
    "max_messages_per_node",
]


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One point-to-point payload movement in the transfer DAG.

    ``via >= 0`` marks an application-layer relay (overlay TIV exploitation):
    the simulator executes two chained hops — the second hop starts only when
    the first hop has been delivered at the relay — charging both hops'
    propagation and (contended) serialization, and the relay node's message
    counters are charged one receive + one send.

    ``deps`` are indices into the owning schedule's ``transfers`` list: this
    transfer may start only after every listed transfer has been *delivered*
    (propagation included).  ``compute_ms`` is a CPU stage paid at the source
    after the dependencies are met and before the wire — the pipelined
    replication engine uses it to model per-group filter/compression time
    that overlaps other groups' in-flight WAN transfers.

    ``src == dst`` marks a **local compute stage** (no wire, no NIC, no
    byte/message accounting): the streaming multi-epoch engine models
    per-node transaction execution and the epoch cadence clock this way.

    ``epoch`` tags the transfer's position in a stitched multi-epoch
    schedule (see :func:`stitch_schedules`); the event simulator resolves
    per-epoch propagation from it when given a latency-matrix stack.
    """

    src: int
    dst: int
    nbytes: float
    via: int = -1
    tag: str = ""
    deps: tuple[int, ...] = ()
    compute_ms: float = 0.0
    epoch: int = 0


@dataclasses.dataclass
class TransmissionSchedule:
    """A DAG of transfers with a derived barrier-phase compatibility view.

    ``transfers`` is topologically ordered (every dependency index points at
    an earlier transfer).  Construction accepts either the canonical flat
    list or the legacy nested list-of-phases form; the legacy form installs
    full barrier edges (every transfer of phase ``p`` depends on all of
    phase ``p-1``), preserving the original semantics for external callers.

    ``phase_of[i]`` records transfer i's positional phase for the barrier
    view.  Builders pass it explicitly so ``phases`` reproduces the pre-DAG
    phase layout exactly; when absent it is derived from ASAP dependency
    levels (``level = 1 + max(level[dep])``).
    """

    transfers: list[Transfer]
    label: str = ""
    phase_of: tuple[int, ...] | None = None

    def __post_init__(self):
        ts = self.transfers
        if ts and isinstance(ts[0], (list, tuple)):
            # legacy phases form: flatten + barrier dependency edges
            flat: list[Transfer] = []
            phase_of: list[int] = []
            prev: tuple[int, ...] = ()
            for p, phase in enumerate(ts):
                cur = []
                for t in phase:
                    if prev and not t.deps:
                        t = dataclasses.replace(t, deps=prev)
                    cur.append(len(flat))
                    flat.append(t)
                    phase_of.append(p)
                if cur:  # empty phases don't break the barrier chain
                    prev = tuple(cur)
            self.transfers = flat
            self.phase_of = tuple(phase_of)
        elif self.phase_of is not None:
            self.phase_of = tuple(self.phase_of)
        for i, t in enumerate(self.transfers):
            for d in t.deps:
                if not (0 <= d < i):
                    raise ValueError(
                        f"transfer {i} depends on {d}: dependencies must "
                        "reference earlier transfers (topological order)"
                    )
        if self.phase_of is not None and len(self.phase_of) != len(self.transfers):
            raise ValueError("phase_of must have one entry per transfer")

    # -- DAG accessors -------------------------------------------------------

    def verify(self, *, n_nodes: int | None = None):
        """Statically verify this DAG's engine invariants (acyclicity, dep
        bounds, phase monotonicity along dep edges, epoch contiguity,
        clock-chain linearity, payload/node sanity).  Returns the list of
        :class:`~repro.analysis.violations.Violation` — empty when sound.
        The constructor enforces only the topological-order subset; this is
        the full check the ``EngineConfig(verify_schedules=True)`` debug
        hook runs on every simulated schedule."""
        from ..analysis.schedule_check import verify_schedule

        return verify_schedule(self, n_nodes=n_nodes)

    @property
    def n_transfers(self) -> int:
        return len(self.transfers)

    @property
    def total_bytes(self) -> float:
        # relayed transfers traverse two WAN hops
        return float(
            sum(t.nbytes * (2.0 if t.via >= 0 else 1.0) for t in self.transfers)
        )

    def all_transfers(self) -> Iterable[Transfer]:
        yield from self.transfers

    def dep_levels(self) -> list[int]:
        """ASAP topological level of each transfer (0 = no dependencies)."""
        levels: list[int] = []
        for t in self.transfers:
            levels.append(1 + max((levels[d] for d in t.deps), default=-1))
        return levels

    # -- derived barrier-phase compatibility view ----------------------------

    def phase_indices(self) -> list[list[int]]:
        """Transfer indices per barrier phase (the ``phases`` view, but by
        position — aliased Transfer objects stay distinguishable)."""
        ranks = list(self.phase_of) if self.phase_of is not None \
            else self.dep_levels()
        n_phases = max(ranks, default=-1) + 1
        out: list[list[int]] = [[] for _ in range(n_phases)]
        for i, r in enumerate(ranks):
            out[r].append(i)
        return out

    @property
    def phases(self) -> list[list[Transfer]]:
        """Barrier-phase view: builder-recorded positional phases when
        available, ASAP dependency levels otherwise.  This is what
        ``WANSimulator(barrier=True)`` executes — for builder-emitted
        schedules it is exactly the pre-DAG phase layout."""
        return [[self.transfers[i] for i in p] for p in self.phase_indices()]


def all_to_all_schedule(
    n: int, payload_bytes: np.ndarray | float, *, label: str = "all_to_all"
) -> TransmissionSchedule:
    """Flat baseline: every node sends its update batch to every other node.

    ``payload_bytes`` is a scalar or per-source vector (node i's batch size).
    No dependencies — the flat round is one fully-concurrent wave.
    """
    pay = np.broadcast_to(np.asarray(payload_bytes, dtype=float), (n,))
    transfers = [
        Transfer(i, j, float(pay[i]), tag="a2a")
        for i in range(n)
        for j in range(n)
        if i != j
    ]
    return TransmissionSchedule(
        transfers, label=label, phase_of=(0,) * len(transfers)
    )


def hierarchical_schedule(
    plan: GroupPlan,
    payload_bytes: np.ndarray | float,
    *,
    group_payload_bytes: np.ndarray | None = None,
    group_compute_ms: np.ndarray | None = None,
    lat: np.ndarray | None = None,
    tiv: bool = False,
    tiv_margin: float = 0.05,
    label: str = "geococo",
) -> TransmissionSchedule:
    """GeoCoCo's hierarchical round (Fig. 8) as a dependency DAG.

    Stage 1 (intra, gather):   each simple node -> its aggregator.  No deps.
    Stage 2 (inter, exchange): each aggregator -> every other aggregator, with
        the *consolidated group payload* (post filtering/aggregation).  Each
        exchange depends on the gathers into its own source aggregator — a
        group whose members arrive early exchanges early, overlapping slower
        groups' gathers.  When ``tiv`` and ``lat`` are given, pairs with a
        profitable one-relay path are routed ``via`` that relay (Sec 5
        overlay implementation).
    Stage 3 (intra, scatter):  each aggregator -> its simple nodes with the
        merged global result.  Each scatter depends on every exchange *into*
        its aggregator plus the aggregator's own gathers (the merged state
        needs the local contributions too).

    ``group_payload_bytes[j]``, if given, is group j's post-filter consolidated
    payload; by default it is the sum of member payloads (no filtering, no
    dedup).  ``group_compute_ms[j]``, if given, is group j's aggregator-side
    CPU time (filter/compress) charged on that group's exchange transfers
    before they hit the wire — the pipelined engine's overlap model.  The
    stage-3 broadcast payload is the merged global state delta: the sum of
    all group payloads (every member must receive every surviving remote
    update, matching full replication).
    """
    # node ids need not be contiguous (e.g. after a drop_node failover)
    n = max(i for g in plan.groups for i in g) + 1
    pay = np.broadcast_to(np.asarray(payload_bytes, dtype=float), (n,))
    if group_payload_bytes is None:
        gp = np.array([sum(pay[i] for i in g) for g in plan.groups])
    else:
        gp = np.asarray(group_payload_bytes, dtype=float)
        if gp.shape != (plan.k,):
            raise ValueError(f"group_payload_bytes must have shape ({plan.k},)")
    gc = np.zeros(plan.k)
    if group_compute_ms is not None:
        gc = np.asarray(group_compute_ms, dtype=float)
        if gc.shape != (plan.k,):
            raise ValueError(f"group_compute_ms must have shape ({plan.k},)")

    relay = None
    if tiv and lat is not None:
        _, relay = one_relay_effective(lat, margin=tiv_margin)

    transfers: list[Transfer] = []
    ranks: list[int] = []
    gathers_into: dict[int, list[int]] = {}  # aggregator -> gather indices
    for g, a in zip(plan.groups, plan.aggregators):
        for i in g:
            if i != a:
                gathers_into.setdefault(a, []).append(len(transfers))
                transfers.append(Transfer(i, a, float(pay[i]), tag="gather"))
                ranks.append(0)
    has_gathers = bool(gathers_into)

    exchanges_into: dict[int, list[int]] = {}  # aggregator -> exchange indices
    for j1, a1 in enumerate(plan.aggregators):
        deps = tuple(gathers_into.get(a1, ()))
        for j2, a2 in enumerate(plan.aggregators):
            if j1 == j2:
                continue
            via = -1
            if relay is not None:
                via = int(relay[a1, a2])
            exchanges_into.setdefault(a2, []).append(len(transfers))
            transfers.append(Transfer(
                a1, a2, float(gp[j1]), via=via, tag="exchange",
                deps=deps, compute_ms=float(gc[j1]),
            ))
            ranks.append(1 if has_gathers else 0)
    has_exchanges = plan.k > 1

    total = float(gp.sum())
    for g, a in zip(plan.groups, plan.aggregators):
        deps = tuple(exchanges_into.get(a, ())) + tuple(gathers_into.get(a, ()))
        # members receive the merged result minus what they already hold
        # locally (their own contribution stayed local): charge total - pay[i].
        for i in g:
            if i != a:
                transfers.append(Transfer(
                    a, i, max(total - float(pay[i]), 0.0), tag="scatter",
                    deps=deps,
                ))
                ranks.append((1 if has_gathers else 0) + (1 if has_exchanges else 0))
    return TransmissionSchedule(transfers, label=label, phase_of=tuple(ranks))


def leader_schedule(
    n: int,
    leader: int,
    payload_bytes: float,
    plan: GroupPlan | None = None,
    *,
    label: str = "leader",
) -> TransmissionSchedule:
    """Single-leader replication (CRDB/Raft plane).

    Without a plan: leader -> each follower directly (flat AppendEntries
    fan-out).  With a plan: leader -> each group aggregator -> group members
    (GeoCoCo hooked into RaftTransport, Sec 5 "Extensions"); each second-hop
    relay depends only on its own inbound append — a nearby aggregator starts
    relaying while a distant one is still receiving.
    """
    if plan is None:
        transfers = [
            Transfer(leader, i, payload_bytes, tag="append")
            for i in range(n)
            if i != leader
        ]
        return TransmissionSchedule(
            transfers, label=label, phase_of=(0,) * len(transfers)
        )
    transfers: list[Transfer] = []
    ranks: list[int] = []
    relays: list[tuple[int, int, tuple[int, ...]]] = []
    for g, a in zip(plan.groups, plan.aggregators):
        tgt = a if leader not in g else leader
        deps: tuple[int, ...] = ()
        if tgt != leader:
            deps = (len(transfers),)
            transfers.append(Transfer(leader, tgt, payload_bytes, tag="append"))
            ranks.append(0)
        for i in g:
            if i != tgt and i != leader:
                relays.append((tgt, i, deps))
    has_appends = bool(transfers)
    for tgt, i, deps in relays:
        transfers.append(Transfer(tgt, i, payload_bytes, tag="relay", deps=deps))
        ranks.append(1 if has_appends else 0)
    return TransmissionSchedule(
        transfers, label=label + "+geococo", phase_of=tuple(ranks)
    )


# ---------------------------------------------------------------------------
# Cross-epoch streaming (GeoGauss-style pipelining of consecutive rounds)
# ---------------------------------------------------------------------------


def stitch_schedules(
    rounds: Sequence[TransmissionSchedule],
    *,
    node_exec_ms: Sequence[Sequence[float]] | None = None,
    epoch_ms: float = 0.0,
    n: int | None = None,
    label: str = "stream",
) -> TransmissionSchedule:
    """Stitch consecutive epochs' DAGs into one streaming schedule.

    The key property (the GeoGauss streaming model, paper Sec 2.1): epoch
    ``e+1``'s transfers out of node ``s`` depend only on **node s's epoch-e
    commit** — the delivery of every epoch-e transfer *into s* — never on a
    global epoch sink.  A node whose scatter arrived early executes and
    gathers epoch ``e+1`` while other nodes' epoch-e scatters are still in
    flight, so consecutive WAN rounds pipeline.

    Per epoch ``k`` the stitched DAG gains two kinds of local compute stages
    (``src == dst`` transfers — no wire, no accounting):

    * a ``clock`` chain (when ``epoch_ms > 0``): epoch ``k``'s execution
      cannot start before ``k * epoch_ms`` — transactions arrive at the
      epoch cadence, not earlier;
    * one ``exec`` stage per node: ``compute_ms = node_exec_ms[k][i]`` —
      node i's local transaction execution for epoch ``k``, after its
      epoch-``k-1`` commit and its own epoch-``k-1`` exec stage (a node
      executes epochs serially).  Every epoch-``k`` wire transfer with
      source ``i`` depends on it.

    Admission ranks (``phase_of``) are offset per epoch, so the event
    engine's bandwidth admission keeps epoch ``e+1`` exchanges from starving
    epoch-e scatters on a shared NIC while leaving the gather/scatter
    overlap intact (gathers ride member->aggregator NIC directions that
    scatters never touch).  A corollary of admission: an earlier epoch's
    measured times are final the moment that epoch is stitched — later
    epochs' flows can never slow them — which is what lets the
    staleness-feedback OCC loop re-simulate the stitched *prefix* as epochs
    append and trust the per-node commit times it already consumed
    (:func:`~repro.core.simulator.node_commit_ms` extracts exactly the
    per-node commit dependency set this builder gates sends on).

    Beyond the replication engine, :meth:`~repro.core.replication.RaftCluster.
    pipelined_commit_ms` stitches ``batches_in_flight`` copies of a
    ``leader_schedule`` (``epoch_ms=0``: no cadence clock) so in-flight
    Raft batches serialize on the leader's NIC instead of replicating for
    free.
    """
    if n is None:
        n = 0
        for sk in rounds:
            for t in sk.transfers:
                n = max(n, t.src + 1, t.dst + 1, t.via + 1)
        if node_exec_ms is not None:
            for row in node_exec_ms:
                n = max(n, len(row))
    if n <= 0:
        raise ValueError("cannot infer node count from empty schedules")

    st = StitchState(n, epoch_ms=epoch_ms)
    flat: list[Transfer] = []
    ranks: list[int] = []
    for k, sk in enumerate(rounds):
        row = node_exec_ms[k] if node_exec_ms is not None else None
        seg, seg_ranks = st.append(sk, row)
        flat.extend(seg)
        ranks.extend(seg_ranks)
    return TransmissionSchedule(flat, label=label, phase_of=tuple(ranks))


class StitchState:
    """The per-epoch step of :func:`stitch_schedules`, factored out so the
    incremental timeline (:class:`repro.core.stream.StreamingTimeline`) and
    the one-shot stitcher build *the same* stream structure by construction.

    Owns the cross-epoch frontier: per-node inbound commit indices
    (``prev_commit``), per-node exec-stage indices (``prev_exec``), the
    cadence clock-chain tail (``prev_clock``) and the running admission
    rank offset (``rank_base``).  Every :meth:`append` emits one epoch's
    stitched segment — transfers whose dependency indices are **global**
    (into the concatenated stream) and their admission ranks — and advances
    the frontier.  Concatenating the segments of ``k`` appends is exactly
    ``stitch_schedules(rounds[:k])``.
    """

    def __init__(self, n: int, *, epoch_ms: float = 0.0):
        if n <= 0:
            raise ValueError("node count must be positive")
        self.n = n
        self.epoch_ms = float(epoch_ms)
        self.epoch = 0                      # next epoch to be appended
        self.size = 0                       # transfers emitted so far
        self.rank_base = 0
        self.prev_commit: dict[int, list[int]] = {i: [] for i in range(n)}
        self.prev_exec: dict[int, int] = {}
        self.prev_clock: int | None = None

    def frontier(self) -> list[int]:
        """Global indices a future epoch's dependencies may reference: the
        last epoch's per-node commit transfers, exec stages and clock tail.
        Everything earlier is unreachable from appended epochs — the
        timeline evicts its finish-time state down to this set."""
        out: list[int] = []
        if self.prev_clock is not None:
            out.append(self.prev_clock)
        out.extend(self.prev_exec.values())
        for lst in self.prev_commit.values():
            out.extend(lst)
        return out

    def append(
        self, sk: TransmissionSchedule,
        node_exec_row: Sequence[float] | None = None,
    ) -> tuple[list[Transfer], list[int]]:
        k = self.epoch
        base = self.size
        seg: list[Transfer] = []
        ranks: list[int] = []
        if self.epoch_ms > 0.0 and k >= 1:
            clock_deps = () if self.prev_clock is None else (self.prev_clock,)
            self.prev_clock = base + len(seg)
            seg.append(Transfer(0, 0, 0.0, tag="clock", deps=clock_deps,
                                compute_ms=self.epoch_ms, epoch=k))
            ranks.append(self.rank_base)
        exec_idx: dict[int, int] = {}
        for i in range(self.n):
            deps: list[int] = []
            if self.prev_clock is not None:
                deps.append(self.prev_clock)
            if i in self.prev_exec:
                deps.append(self.prev_exec[i])
            deps.extend(self.prev_commit[i])
            cms = 0.0
            if node_exec_row is not None and i < len(node_exec_row):
                cms = float(node_exec_row[i])
            exec_idx[i] = base + len(seg)
            seg.append(Transfer(i, i, 0.0, tag="exec", deps=tuple(deps),
                                compute_ms=cms, epoch=k))
            ranks.append(self.rank_base + 1)
        off = base + len(seg)
        rk = list(sk.phase_of) if sk.phase_of is not None else sk.dep_levels()
        commit: dict[int, list[int]] = {i: [] for i in range(self.n)}
        for j, t in enumerate(sk.transfers):
            deps_t = tuple(d + off for d in t.deps) + (exec_idx[t.src],)
            if t.src != t.dst:
                commit[t.dst].append(base + len(seg))
            seg.append(dataclasses.replace(t, deps=deps_t, epoch=k))
            ranks.append(self.rank_base + 2 + rk[j])
        self.prev_commit = commit
        self.prev_exec = exec_idx
        self.rank_base += 2 + (max(rk) + 1 if rk else 0)
        self.size += len(seg)
        self.epoch += 1
        return seg, ranks


# registry wiring: transmission-schedule builders are addressable by name so
# the engine (and future planes: Raft, multi-cloud) resolve them uniformly
_strategies.register("schedule", "all_to_all", all_to_all_schedule)
_strategies.register("schedule", "hierarchical", hierarchical_schedule)
_strategies.register("schedule", "leader", leader_schedule)


# ---------------------------------------------------------------------------
# Round-count accounting (Eq. 6-7)
# ---------------------------------------------------------------------------


def messages_per_node(schedule: TransmissionSchedule, n: int) -> np.ndarray:
    """Total messages (sends + receives, relays counted) per node.  Local
    compute stages (``src == dst``) put nothing on the wire."""
    cnt = np.zeros(n, dtype=int)
    for t in schedule.all_transfers():
        if t.src == t.dst:
            continue
        cnt[t.src] += 1
        cnt[t.dst] += 1
        if t.via >= 0:
            cnt[t.via] += 2  # relay receives and forwards
    return cnt


def max_messages_per_node(schedule: TransmissionSchedule, n: int) -> int:
    return int(messages_per_node(schedule, n).max())
