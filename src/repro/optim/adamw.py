"""AdamW with cosine schedule, grad clipping and a dtype policy.

State dtype is configurable so very large models (deepseek-v3-671b) can run
a lean bf16 m/v policy that actually fits the per-device HBM budget — the
policy used is reported by the dry-run memory analysis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Any = jnp.float32     # m/v dtype (bf16 for lean policy)


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, td = jax.tree.flatten(params)
    flat_g = td.flatten_up_to(grads)
    flat_m = td.flatten_up_to(state["m"])
    flat_v = td.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = td.unflatten([o[0] for o in out])
    new_state = {
        "m": td.unflatten([o[1] for o in out]),
        "v": td.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
