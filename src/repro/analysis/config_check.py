"""Declarative config-compatibility checker.

One rule table replaces the ``raise ValueError`` sites that used to be
scattered across ``repro/core/replication.py`` (``EngineConfig.__post_init__``
and ``GeoCluster.__init__``) and ``repro/serve/config.py``: every flag's
constraints now live here, in one place, as data — so adding a feature flag
means adding a :class:`ConfigRule`, and tooling (tests, docs, the lint) can
enumerate the full compatibility matrix without reading constructor code.

Rules are keyed by the config class *name* (``EngineConfig`` /
``ServeConfig``) — deliberately stringly, so this module imports nothing
from ``repro.core`` or ``repro.serve`` and sits below both in the layering
(they call into it from their ``__post_init__``).

Each rule carries a ``stage``:

* ``config`` — checkable from the config object alone; runs at dataclass
  construction (``validate_config(cfg)``).
* ``cluster`` — needs the strategy registry (e.g. inspecting a registered
  schedule builder's signature); runs when the config is attached to an
  engine (``validate_config(cfg, stage="cluster")`` in
  ``GeoCluster.__init__``), preserving the historical fail-at-attach
  behavior for registry-dependent constraints.

Error-message compatibility is part of the contract: ``validate_config``
raises ``ValueError`` with the *first* violation's message, and the
messages are byte-for-byte the historical ones (the rejection tests in
``tests/test_streaming.py`` / ``test_staleness.py`` / ``test_serve.py`` /
``test_strategies_registry.py`` pass unchanged).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from .violations import Violation

__all__ = ["ConfigRule", "RULES", "check_config", "validate_config"]


@dataclasses.dataclass(frozen=True)
class ConfigRule:
    """One declarative compatibility constraint.

    ``check`` returns the violation message (or ``None`` when satisfied);
    ``kind`` is the constraint shape (``requires`` / ``mutually-exclusive``
    / ``range`` / ``contract``) — documentation and tooling metadata, not
    dispatch.
    """

    name: str                 # stable slug, e.g. "staleness-requires-streaming"
    applies_to: str           # config class name
    kind: str
    stage: str                # "config" (constructor) | "cluster" (attach)
    check: Callable[[Any], str | None]


def _requires(flag: str, prereq: str, message: str):
    """``flag`` set (truthy / not None) demands ``prereq`` set."""
    def check(cfg) -> str | None:
        flag_v = getattr(cfg, flag)
        if (flag_v is not None and flag_v is not False) \
                and not getattr(cfg, prereq):
            return message
        return None
    return check


def _mutually_exclusive(a: str, b: str, message: str):
    def check(cfg) -> str | None:
        if getattr(cfg, a) and getattr(cfg, b):
            return message
        return None
    return check


def _grouped_schedule_contract(cfg) -> str | None:
    # the grouping engine drives builders with hierarchical_schedule's
    # contract (plan, node payloads, group_payload_bytes, lat/tiv kwargs);
    # a registered builder without it would fail mid-run, so refuse at
    # engine attach.  Registry + inspect are runtime-only imports: this
    # module stays import-free of repro.core.
    if not cfg.grouping:
        return None
    import inspect

    from ..core import strategies as _strategies

    fn = _strategies.get("schedule", cfg.resolved_schedule_name)
    if "group_payload_bytes" not in inspect.signature(fn).parameters:
        return (
            f"schedule {cfg.resolved_schedule_name!r} cannot drive the "
            "grouping engine: it does not follow the hierarchical "
            "builder contract (missing 'group_payload_bytes')"
        )
    return None


def _flat_schedule_is_all_to_all(cfg) -> str | None:
    # the non-grouping engine runs the flat all-to-all round by definition;
    # a differently-named builder would be silently ignored and the run
    # mislabeled
    if not cfg.grouping and cfg.schedule_name not in (None, "all_to_all"):
        return (
            f"schedule {cfg.schedule_name!r} requires grouping=True "
            "(the flat engine always runs 'all_to_all')"
        )
    return None


def _serve_clients_nonneg(cfg) -> str | None:
    import numpy as np

    if np.any(np.asarray(cfg.clients_per_node, dtype=float) < 0.0):
        return "clients_per_node must be non-negative"
    return None


# ---------------------------------------------------------------------------
# The rule table.  Order matters within a class: validate_config raises the
# first violation, and the historical constructors checked in this order.
# ---------------------------------------------------------------------------

RULES: list[ConfigRule] = [
    # -- EngineConfig ------------------------------------------------------
    ConfigRule(
        "streaming-x-barrier", "EngineConfig", "mutually-exclusive", "config",
        _mutually_exclusive(
            "streaming", "barrier",
            "streaming=True requires the event engine: cross-epoch "
            "stitched DAGs have no barrier-phase semantics (set "
            "barrier=False, or drop streaming for the legacy "
            "max(epoch, exec, sync) formula)",
        ),
    ),
    ConfigRule(
        "staleness-requires-streaming", "EngineConfig", "requires", "config",
        _requires(
            "staleness_feedback", "streaming",
            "staleness_feedback=True requires streaming=True: per-node "
            "view staleness is measured from the stitched multi-epoch "
            "simulation's per-node commit times",
        ),
    ),
    ConfigRule(
        "serve-requires-streaming", "EngineConfig", "requires", "config",
        _requires(
            "serve", "streaming",
            "serve=ServeConfig(...) requires streaming=True: the serving "
            "plane reads per-node view staleness off the stitched "
            "multi-epoch simulation's measured commit times",
        ),
    ),
    ConfigRule(
        "stream-mode-value", "EngineConfig", "range", "config",
        lambda cfg: (
            "stream_mode must be 'incremental' (O(E) appendable timeline) "
            "or 'resim' (the O(E²) stitch-and-rerun reference oracle)"
            if cfg.stream_mode not in ("incremental", "resim") else None
        ),
    ),
    ConfigRule(
        "stats-window-nonnegative", "EngineConfig", "range", "config",
        lambda cfg: "stats_window must be >= 0"
        if cfg.stats_window < 0 else None,
    ),
    ConfigRule(
        "bounded-run-serve-retention", "EngineConfig", "requires", "config",
        lambda cfg: (
            "keep_epochs=False requires ServeConfig(keep_epochs=False): a "
            "bounded-memory run cannot retain the serving plane's full "
            "per-epoch list (run totals and latency percentiles are "
            "unaffected — they come from the online ServeTotals)"
            if (not cfg.keep_epochs and cfg.serve is not None
                and cfg.serve.keep_epochs) else None
        ),
    ),
    ConfigRule(
        "grouped-schedule-contract", "EngineConfig", "contract", "cluster",
        _grouped_schedule_contract,
    ),
    ConfigRule(
        "flat-engine-schedule", "EngineConfig", "contract", "cluster",
        _flat_schedule_is_all_to_all,
    ),
    # -- ServeConfig -------------------------------------------------------
    ConfigRule(
        "read-ratio-range", "ServeConfig", "range", "config",
        lambda cfg: "read_ratio must be in [0, 1]"
        if cfg.read_ratio < 0.0 or cfg.read_ratio > 1.0 else None,
    ),
    ConfigRule(
        "staleness-bound-range", "ServeConfig", "range", "config",
        lambda cfg: "max_staleness_ms must be >= 0"
        if cfg.max_staleness_ms < 0.0 else None,
    ),
    ConfigRule(
        "ops-rate-positive", "ServeConfig", "range", "config",
        lambda cfg: "ops_per_client_s must be positive"
        if cfg.ops_per_client_s <= 0.0 else None,
    ),
    ConfigRule(
        "clients-nonnegative", "ServeConfig", "range", "config",
        _serve_clients_nonneg,
    ),
    ConfigRule(
        "cache-keys-range", "ServeConfig", "range", "config",
        lambda cfg: "cache_keys must be in [0, n_keys]"
        if cfg.cache_keys < 0 or cfg.cache_keys > cfg.n_keys else None,
    ),
]

_STAGES = ("config", "cluster")


def check_config(cfg: Any, *, stage: str = "config") -> list[Violation]:
    """Run every rule for ``cfg``'s class up to ``stage``; return all
    violations as structured diagnostics (empty = compatible).

    ``stage="config"`` runs constructor-checkable rules only;
    ``stage="cluster"`` additionally runs registry-dependent contract
    rules (what ``GeoCluster.__init__`` enforces).
    """
    if stage not in _STAGES:
        raise ValueError(f"unknown stage {stage!r}; expected one of {_STAGES}")
    depth = _STAGES.index(stage)
    cls = type(cfg).__name__
    out: list[Violation] = []
    for rule in RULES:
        if rule.applies_to != cls or _STAGES.index(rule.stage) > depth:
            continue
        msg = rule.check(cfg)
        if msg is not None:
            out.append(Violation(rule.name, msg, file=cls))
    return out


def validate_config(cfg: Any, *, stage: str = "config") -> None:
    """Raise ``ValueError`` with the first violation's (historical) message;
    no-op when the config is compatible."""
    violations = check_config(cfg, stage=stage)
    if violations:
        raise ValueError(violations[0].message)
