"""Static verification layer: invariants checked for *all* inputs, not
sampled ones.

The repo's soundness story rests on theorems — ``event <= barrier`` under
bandwidth admission, byte-identical digests with the feedback features off,
monotone serving bounds — whose *preconditions* are structural properties of
builder outputs and config combinations.  The dynamic suite samples those
spaces; this package checks them exhaustively, before a single flow is
simulated:

* :mod:`repro.analysis.schedule_check` — :func:`verify_schedule`, a pure
  O(V + E) validator over any transfer DAG (acyclicity, dep bounds, phase
  monotonicity along dep edges — the admission theorem's precondition —
  epoch contiguity, clock-chain linearity, payload sanity, node bounds).
  Wired behind ``EngineConfig(verify_schedules=True)`` /
  ``WANSimulator(verify=True)``.
* :mod:`repro.analysis.config_check` — :func:`check_config`, one declarative
  rule table for every config-flag constraint (streaming-only features,
  mutually exclusive engines, schedule/builder contracts), replacing the
  scattered ``raise ValueError`` sites.
* :mod:`repro.analysis.lint` — repo-specific AST determinism lint
  (wall-clock outside measured branches, module-global RNG, unordered set
  and dict iteration in digest paths, float sums over unordered sources,
  mutable defaults, bare float ``==`` on simulated times, tracked
  bytecode).  CLI: ``python -m repro.analysis.lint src/ benchmarks/``.
* :mod:`repro.analysis.modelcheck` — bounded explicit-state model checker:
  exhaustive DAG-space sweeps machine-checking the admission theorem and
  verifier completeness, plus protocol interleaving checks (CRDT merge
  confluence, OCC epoch atomicity, abort-set monotonicity, streaming
  eviction safety) and a seeded-mutant selftest.  CLI:
  ``python -m repro.analysis.modelcheck --tier quick``.
* :mod:`repro.analysis.mutate` — schedule mutators (one per verifier
  rule) used by the mutation-corpus gate and the model checker's
  invalid-side sampling.

Everything here is stdlib-only at import time (numpy/registry imports are
deferred into the rules that need them), so the lint CLI and the CI gate
run without the simulation stack installed.  The model-checker exports
below are therefore lazy (PEP 562): importing :mod:`repro.analysis` does
not pull in numpy; touching ``run_tier`` etc. does.
"""

from .config_check import ConfigRule, check_config, validate_config
from .lint import lint_file, lint_paths
from .schedule_check import (
    ScheduleVerificationError,
    StreamScheduleVerifier,
    reset_verified_schedule_count,
    verified_schedule_count,
    verify_schedule,
)
from .violations import Violation, format_violations

__all__ = [
    "Violation",
    "format_violations",
    "verify_schedule",
    "ScheduleVerificationError",
    "StreamScheduleVerifier",
    "verified_schedule_count",
    "reset_verified_schedule_count",
    "ConfigRule",
    "check_config",
    "validate_config",
    "lint_file",
    "lint_paths",
    # lazy (numpy-backed) — resolved on first attribute access
    "ModelCheckReport",
    "TheoremReport",
    "THEOREMS",
    "check_admission",
    "check_confluence",
    "check_occ_atomicity",
    "check_abort_monotonicity",
    "check_eviction",
    "model_checked_count",
    "reset_model_checked_count",
    "rebuild_counterexample",
    "run_selftest",
    "run_tier",
    "scope_for",
    "MUTATORS",
    "mutate_schedule",
]

_LAZY = {
    "MUTATORS": "mutate",
    "mutate_schedule": "mutate",
    "ModelCheckReport": "modelcheck",
    "TheoremReport": "modelcheck",
    "THEOREMS": "modelcheck",
    "check_admission": "modelcheck",
    "check_confluence": "modelcheck",
    "check_occ_atomicity": "modelcheck",
    "check_abort_monotonicity": "modelcheck",
    "check_eviction": "modelcheck",
    "model_checked_count": "modelcheck",
    "reset_model_checked_count": "modelcheck",
    "rebuild_counterexample": "modelcheck",
    "run_selftest": "modelcheck",
    "run_tier": "modelcheck",
    "scope_for": "modelcheck",
}


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        )
    import importlib

    return getattr(importlib.import_module(f".{module}", __name__), name)
