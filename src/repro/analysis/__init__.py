"""Static verification layer: invariants checked for *all* inputs, not
sampled ones.

The repo's soundness story rests on theorems — ``event <= barrier`` under
bandwidth admission, byte-identical digests with the feedback features off,
monotone serving bounds — whose *preconditions* are structural properties of
builder outputs and config combinations.  The dynamic suite samples those
spaces; this package checks them exhaustively, before a single flow is
simulated:

* :mod:`repro.analysis.schedule_check` — :func:`verify_schedule`, a pure
  O(V + E) validator over any transfer DAG (acyclicity, dep bounds, phase
  monotonicity along dep edges — the admission theorem's precondition —
  epoch contiguity, clock-chain linearity, payload sanity, node bounds).
  Wired behind ``EngineConfig(verify_schedules=True)`` /
  ``WANSimulator(verify=True)``.
* :mod:`repro.analysis.config_check` — :func:`check_config`, one declarative
  rule table for every config-flag constraint (streaming-only features,
  mutually exclusive engines, schedule/builder contracts), replacing the
  scattered ``raise ValueError`` sites.
* :mod:`repro.analysis.lint` — repo-specific AST determinism lint
  (wall-clock outside measured branches, module-global RNG, unordered set
  iteration in digest paths, mutable defaults, bare float ``==`` on
  simulated times, tracked bytecode).  CLI:
  ``python -m repro.analysis.lint src/ benchmarks/``.

Everything here is stdlib-only at import time (numpy/registry imports are
deferred into the rules that need them), so the lint CLI and the CI gate
run without the simulation stack installed.
"""

from .config_check import ConfigRule, check_config, validate_config
from .lint import lint_file, lint_paths
from .schedule_check import (
    ScheduleVerificationError,
    StreamScheduleVerifier,
    reset_verified_schedule_count,
    verified_schedule_count,
    verify_schedule,
)
from .violations import Violation, format_violations

__all__ = [
    "Violation",
    "format_violations",
    "verify_schedule",
    "ScheduleVerificationError",
    "StreamScheduleVerifier",
    "verified_schedule_count",
    "reset_verified_schedule_count",
    "ConfigRule",
    "check_config",
    "validate_config",
    "lint_file",
    "lint_paths",
]
