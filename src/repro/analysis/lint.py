"""Determinism/soundness lint: repo-specific AST rules.

The repo's central promises — byte-identical digests across engines,
deterministic benchmark gates, simulated time never contaminated by host
wall-clock — are invariants *of the source*, not of any one run.  This
linter enforces them statically:

=====================  =====================================================
rule                   what it refuses
=====================  =====================================================
``wallclock``          ``time.time()`` / ``time.perf_counter()`` (and
                       friends) outside the explicit allowlist.  Wall-clock
                       belongs in exactly two kinds of places: genuinely
                       measured quantities (trainer step timing, planner
                       search cost, dry-run compile time, the benchmark
                       harness's own timers) and the explicitly *measured*-
                       CPU branch of the replication engine
                       (``modeled_cpu=False``).  Anywhere else it leaks
                       host load into simulated results.
``module-rng``         ``np.random.<draw>()`` module-level calls (global
                       RNG state).  Thread a ``np.random.Generator``
                       (``default_rng(seed)``) instead; constructors
                       (``default_rng``, ``SeedSequence``, bit generators)
                       are allowed.
``unordered-set-iter`` iterating a ``set``/``frozenset`` expression inside
                       a determinism-critical function (digest, epoch
                       validation / winner map, CRDT merge paths).  String
                       hashing is salted per process, so set order is not
                       reproducible across runs — wrap in ``sorted(...)``.
``unordered-dict-iter`` iterating a dict view (``.keys()``/``.values()``/
                       ``.items()``) or dict display inside a determinism-
                       critical function.  Dict order is insertion order,
                       and in merge/winner paths insertion order is arrival
                       order — content-deterministic digests must sort.
``float-sum-unordered`` ``sum()`` over an unordered iterable (set/dict
                       view) of simulated-time / byte quantities (``*_ms``,
                       ``*_s``, ``*_bytes``, ``nbytes``).  Float addition
                       is non-associative, so the accumulation order
                       changes the total — sort the iterable first.
``mutable-default``    mutable default arguments (``def f(x=[])``).
``float-time-eq``      bare ``==`` / ``!=`` between simulated-time scalars
                       (identifiers ending in ``_ms``).  Exact equality is
                       only meaningful against a literal ``0``; otherwise
                       compare with a tolerance or gate on ``<=``.
``tracked-bytecode``   ``*.pyc`` files tracked by git anywhere in the repo.
=====================  =====================================================

Suppression: a line containing ``lint: allow[<rule>]`` in a comment
suppresses that rule on that line; permanent exemptions live in the
per-rule allowlists below (path suffix, optionally ``::``-scoped to a
function/class qualname) with the reason recorded next to each entry.

Run it as a CLI (CI does, before tier-1)::

    PYTHONPATH=src python -m repro.analysis.lint src/ benchmarks/

or in-process (``tests/test_analysis.py`` asserts the repo is clean and
that each fixture under ``tests/fixtures/lint/`` trips its rule exactly
once)::

    from repro.analysis.lint import lint_paths
    violations = lint_paths(["src", "benchmarks"])
"""

from __future__ import annotations

import argparse
import ast
import re
import subprocess
import sys
from pathlib import Path

from .violations import Violation

__all__ = ["lint_file", "lint_paths", "main"]

# -- rule configuration ------------------------------------------------------

WALLCLOCK_CALLS = {
    "time", "perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns",
    "process_time", "process_time_ns",
}

# np.random.* attribute calls that construct seeded generator objects rather
# than drawing from the module-global RNG state
RNG_CONSTRUCTORS = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64", "RandomState",
}

# functions whose iteration order feeds digests / the OCC winner map / CRDT
# merge outcomes; set iteration inside any of these (or any function whose
# name mentions digest/winner) must be sorted
CRITICAL_FUNCS = {
    "digest", "value_state", "full_state", "merge_updates", "apply_many",
    "merge_store", "validate_epoch", "validate_epoch_detailed",
    "_validate_python", "_validate_numpy",
    "committed_updates", "_advance_views", "advance_views", "append_epoch",
}

# Allowlists: entries are a path suffix (posix), optionally "::"-scoped to a
# dotted qualname prefix.  Every entry records why wall-clock (etc.) is
# legitimate there — these are measured quantities, not simulated time.
ALLOWLIST: dict[str, tuple[str, ...]] = {
    "wallclock": (
        # device-plane step timing: real wall-clock IS the measurement
        "repro/train/trainer.py",
        # plan-search wall cost, reported as plan_cost_s (never enters the
        # simulated timeline)
        "repro/core/planner.py",
        # XLA compile / HLO analysis timing
        "repro/launch/dryrun.py",
        # replication engine: plan_time_s accounting ...
        "repro/core/replication.py::GeoCluster._plan_fn",
        # ... and the explicitly *measured*-CPU branch (modeled_cpu=False
        # charges real filter/zlib wall time; modeled_cpu=True is the
        # deterministic alternative)
        "repro/core/replication.py::GeoCluster._prepare_epoch",
        # the benchmark harness times its own modules' wall cost
        "benchmarks/common.py",
        "benchmarks/run.py",
        # plan-cost figures: planner wall time is the reported metric
        "benchmarks/bench_scaling_cost_benefit.py",
        "benchmarks/bench_grouping_strategies.py",
        # long-horizon scaling gate: the O(E) claim is about real wall
        # time, so the 2x-epochs ratio is a measured quantity
        "benchmarks/bench_long_horizon.py",
    ),
    "module-rng": (),
    "unordered-set-iter": (),
    "unordered-dict-iter": (),
    "float-sum-unordered": (),
    "mutable-default": (),
    "float-time-eq": (),
}

_PRAGMA = re.compile(r"lint:\s*allow\[([a-z-]+(?:\s*,\s*[a-z-]+)*)\]")


def _allowed(rule: str, rel_path: str, qualname: str) -> bool:
    for entry in ALLOWLIST.get(rule, ()):
        if "::" in entry:
            suffix, scope = entry.split("::", 1)
            if rel_path.endswith(suffix) and (
                qualname == scope or qualname.startswith(scope + ".")
            ):
                return True
        elif rel_path.endswith(entry):
            return True
    return False


def _pragma_rules(line: str) -> set[str]:
    m = _PRAGMA.search(line)
    if not m:
        return set()
    return {r.strip() for r in m.group(1).split(",")}


def _is_setish(node: ast.AST) -> bool:
    """Syntactically a set-typed expression: literal, comprehension,
    ``set()``/``frozenset()`` call, or a set-algebra BinOp over one."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
    ):
        return _is_setish(node.left) or _is_setish(node.right)
    return False


def _is_dictish(node: ast.AST) -> bool:
    """Syntactically a dict-typed expression: display, comprehension, or a
    ``dict()`` call."""
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return True
    return isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
        and node.func.id == "dict"


def _is_dict_view(node: ast.AST) -> bool:
    """A ``.keys()`` / ``.values()`` / ``.items()`` view call — the
    syntactic marker of dict iteration (a bare name can't be typed
    statically, exactly like the set rule)."""
    return isinstance(node, ast.Call) and not node.args \
        and not node.keywords and isinstance(node.func, ast.Attribute) \
        and node.func.attr in ("keys", "values", "items")


def _float_total_named(node: ast.AST) -> bool:
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    return name is not None and (
        name == "nbytes" or name.endswith(("_ms", "_s", "_bytes"))
    )


def _mentions_float_total(node: ast.AST) -> bool:
    return any(_float_total_named(sub) for sub in ast.walk(node))


def _time_like(node: ast.AST) -> bool:
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    return name is not None and name.endswith("_ms")


def _is_zero_literal(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value in (0, 0.0)


class _Linter(ast.NodeVisitor):
    def __init__(self, rel_path: str, lines: list[str]):
        self.rel_path = rel_path
        self.lines = lines
        self.scope: list[str] = []
        self.time_imports: set[str] = set()  # from time import perf_counter
        self.out: list[Violation] = []

    # -- helpers ------------------------------------------------------------

    def _report(self, rule: str, message: str, node: ast.AST) -> None:
        if _allowed(rule, self.rel_path, ".".join(self.scope)):
            return
        line = getattr(node, "lineno", None)
        if line is not None and 1 <= line <= len(self.lines) \
                and rule in _pragma_rules(self.lines[line - 1]):
            return
        self.out.append(Violation(
            rule, message, file=self.rel_path, line=line,
        ))

    def _in_critical_func(self) -> bool:
        for name in self.scope:
            if name in CRITICAL_FUNCS or "digest" in name or "winner" in name:
                return True
        return False

    # -- scope tracking ------------------------------------------------------

    def _visit_scoped(self, node) -> None:
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._visit_scoped(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_defaults(node)
        self._visit_scoped(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._check_defaults(node)
        self._visit_scoped(node)

    # -- rule: mutable-default ----------------------------------------------

    def _check_defaults(self, node) -> None:
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in ("list", "dict", "set", "bytearray")
            )
            if mutable:
                self._report(
                    "mutable-default",
                    f"function {node.name!r} has a mutable default "
                    "argument: it is shared across calls — default to "
                    "None and construct inside", d,
                )

    # -- rule: wallclock + module-rng ----------------------------------------

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "time":
            for alias in node.names:
                if alias.name in WALLCLOCK_CALLS:
                    self.time_imports.add(alias.asname or alias.name)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            # time.<clock>()
            if fn.attr in WALLCLOCK_CALLS and isinstance(fn.value, ast.Name) \
                    and fn.value.id == "time":
                self._report(
                    "wallclock",
                    f"time.{fn.attr}() reads the host wall-clock: simulated "
                    "results must not depend on host load (allowlist the "
                    "site if this is a genuinely measured quantity)", node,
                )
            # np.random.<draw>()
            if isinstance(fn.value, ast.Attribute) \
                    and fn.value.attr == "random" \
                    and isinstance(fn.value.value, ast.Name) \
                    and fn.value.value.id in ("np", "numpy") \
                    and fn.attr not in RNG_CONSTRUCTORS:
                self._report(
                    "module-rng",
                    f"np.random.{fn.attr}() draws from module-global RNG "
                    "state: thread a np.random.Generator "
                    "(default_rng(seed)) instead", node,
                )
        elif isinstance(fn, ast.Name) and fn.id in self.time_imports:
            self._report(
                "wallclock",
                f"{fn.id}() (imported from time) reads the host "
                "wall-clock: simulated results must not depend on host "
                "load", node,
            )
        self._check_float_sum(node)
        self.generic_visit(node)

    # -- rule: float-sum-unordered -------------------------------------------

    def _check_float_sum(self, node: ast.Call) -> None:
        fn = node.func
        if not (isinstance(fn, ast.Name) and fn.id == "sum" and node.args):
            return
        arg = node.args[0]
        if isinstance(arg, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            src = arg.generators[0].iter
            probe: ast.AST = arg.elt
        else:
            src = arg
            probe = arg
        if (_is_setish(src) or _is_dictish(src) or _is_dict_view(src)) \
                and _mentions_float_total(probe):
            self._report(
                "float-sum-unordered",
                "sum() over an unordered iterable of *_ms/*_s/*_bytes "
                "quantities: float addition is non-associative, so the "
                "accumulation order changes the total — sort the iterable "
                "first", node,
            )

    # -- rule: unordered-set-iter --------------------------------------------

    def _check_iter(self, iter_node: ast.AST) -> None:
        if not self._in_critical_func():
            return
        if _is_setish(iter_node):
            self._report(
                "unordered-set-iter",
                "iterating a set inside a determinism-critical function: "
                "string hashing is salted per process, so the order feeds "
                "nondeterminism into digest/winner-map paths — wrap in "
                "sorted(...)", iter_node,
            )
        elif _is_dictish(iter_node) or _is_dict_view(iter_node):
            self._report(
                "unordered-dict-iter",
                "iterating a dict view inside a determinism-critical "
                "function: dict order is insertion order, which in "
                "merge/winner paths is arrival order — wrap in "
                "sorted(...) so digests depend on content only", iter_node,
            )

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # -- rule: float-time-eq -------------------------------------------------

    def visit_Compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        for op, lhs, rhs in zip(node.ops, operands, operands[1:]):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            if _is_zero_literal(lhs) or _is_zero_literal(rhs):
                continue  # exact-zero checks are well-defined on floats
            if _time_like(lhs) or _time_like(rhs):
                self._report(
                    "float-time-eq",
                    "bare float ==/!= on a simulated-time value (*_ms): "
                    "compare with a tolerance, or gate on <= (exact "
                    "equality is only meaningful against literal 0)", node,
                )
                break
        self.generic_visit(node)


# -- drivers -----------------------------------------------------------------


def lint_file(path: str | Path, root: Path | None = None) -> list[Violation]:
    """Lint one Python source file; returns its violations."""
    p = Path(path)
    rel = p.resolve().relative_to(root.resolve()).as_posix() if root \
        else p.as_posix()
    src = p.read_text()
    try:
        tree = ast.parse(src, filename=str(p))
    except SyntaxError as e:
        return [Violation("syntax-error", str(e), file=rel, line=e.lineno)]
    linter = _Linter(rel, src.splitlines())
    linter.visit(tree)
    return linter.out


def _tracked_bytecode(paths: list[Path]) -> list[Violation]:
    """Flag git-tracked ``*.pyc`` anywhere in the repo(s) containing the
    linted paths.  Committed bytecode is both noise and a staleness hazard
    (it shadows nothing but diffs on every rebuild).  Skipped silently when
    git (or a repo) is absent."""
    roots: set[Path] = set()
    for p in paths:
        cur = p.resolve()
        if cur.is_file():
            cur = cur.parent
        while cur != cur.parent:
            if (cur / ".git").exists():
                roots.add(cur)
                break
            cur = cur.parent
    out: list[Violation] = []
    for root in sorted(roots):
        try:
            res = subprocess.run(
                ["git", "-C", str(root), "ls-files", "-z", "--", "*.pyc"],
                capture_output=True, text=True, timeout=30,
            )
        except (OSError, subprocess.TimeoutExpired):
            continue
        if res.returncode != 0:
            continue
        for f in res.stdout.split("\0"):
            if f:
                out.append(Violation(
                    "tracked-bytecode",
                    "git-tracked bytecode: remove it and keep __pycache__/ "
                    "in .gitignore", file=f,
                ))
    return out


def lint_paths(paths: list[str | Path]) -> list[Violation]:
    """Lint every ``*.py`` under the given files/directories (recursively,
    skipping ``__pycache__``), plus the tracked-bytecode repo check."""
    roots = [Path(p) for p in paths]
    files: list[Path] = []
    for p in roots:
        if p.is_file():
            files.append(p)
        else:
            files.extend(
                f for f in sorted(p.rglob("*.py"))
                if "__pycache__" not in f.parts
            )
    out: list[Violation] = []
    for f in files:
        out.extend(lint_file(f))
    out.extend(_tracked_bytecode(roots))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Determinism/soundness lint (repo-specific AST rules).",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    args = ap.parse_args(argv)
    violations = lint_paths(args.paths)
    for v in violations:
        print(v)
    n = len(violations)
    print(f"{n} violation(s)" if n else "clean", file=sys.stderr)
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
