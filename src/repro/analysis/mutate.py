"""Auto-generated single-rule mutants for the schedule verifier.

PR 7 seeded ``verify_schedule`` with nine hand-written mutation tests (one
per rule).  This module turns those into *generators*: given any valid
schedule, ``mutate_schedule(sched, rule, rng)`` derives a fresh mutant
breaking exactly that rule — so the catch-rate gate runs over every
builder x topology base instead of one hand-picked schedule each, and the
model checker (:mod:`repro.analysis.modelcheck`) can sample mutants from
its exhaustively enumerated DAG space to certify the *invalid* side of
verifier completeness.

Design notes:

* Mutants are built by cloning the schedule **without** re-running
  ``TransmissionSchedule.__post_init__`` (which would reject the very
  defects we are seeding, exactly like the constructor rejects forward
  deps) — the clone is a shallow copy with its own transfer list, so the
  base schedule is never touched (the 0-false-positive half of the gate
  re-verifies it after every mutation).
* A mutator returns ``None`` when the rule is not expressible on the base
  (e.g. ``clock-chain`` needs a stitched schedule with >= 2 clocks,
  ``phase-monotone`` needs an explicit ``phase_of``).  The test sweep
  asserts every rule is applicable *somewhere* in its base set.
* A mutant may trip secondary rules too (a back edge is both a ``cycle``
  and a ``topo-order`` defect); the contract is only that the *target*
  rule is among those reported.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["MUTATORS", "mutate_schedule"]


def _clone(sched):
    """Copy a TransmissionSchedule without constructor validation."""
    out = type(sched).__new__(type(sched))
    out.transfers = list(sched.transfers)
    out.label = sched.label
    out.phase_of = None if sched.phase_of is None else list(sched.phase_of)
    return out


def _wire_indices(sched) -> list[int]:
    return [i for i, t in enumerate(sched.transfers) if t.src != t.dst]


def _pick(rng, seq):
    return seq[int(rng.integers(0, len(seq)))]


# -- one mutator per verifier rule -------------------------------------------


def _mut_cycle(sched, rng, n_nodes=None):
    m = len(sched.transfers)
    if m < 2:
        return None
    out = _clone(sched)
    i = int(rng.integers(0, m - 1))
    j = int(rng.integers(i + 1, m))
    ti, tj = out.transfers[i], out.transfers[j]
    out.transfers[i] = dataclasses.replace(ti, deps=ti.deps + (j,))
    out.transfers[j] = dataclasses.replace(tj, deps=tj.deps + (i,))
    return out


def _mut_dep_bounds(sched, rng, n_nodes=None):
    m = len(sched.transfers)
    if m == 0:
        return None
    out = _clone(sched)
    i = int(rng.integers(0, m))
    bad = m + int(rng.integers(0, 7)) if rng.integers(0, 2) else -1
    t = out.transfers[i]
    out.transfers[i] = dataclasses.replace(t, deps=t.deps + (bad,))
    return out


def _mut_topo_order(sched, rng, n_nodes=None):
    # a forward reference that is NOT part of a cycle: i depends on a later
    # j, j keeps its deps — the edge set stays acyclic, so only the
    # topological-order rule (and possibly phase rules) fires
    m = len(sched.transfers)
    if m < 2:
        return None
    out = _clone(sched)
    i = int(rng.integers(0, m - 1))
    j = int(rng.integers(i + 1, m))
    t = out.transfers[i]
    out.transfers[i] = dataclasses.replace(t, deps=t.deps + (j,))
    return out


def _mut_phase_shape(sched, rng, n_nodes=None):
    if sched.phase_of is None or len(sched.phase_of) == 0:
        return None
    out = _clone(sched)
    if rng.integers(0, 2) and len(out.phase_of) > 1:
        out.phase_of = out.phase_of[:-1]          # length mismatch
    else:
        out.phase_of[int(rng.integers(0, len(out.phase_of)))] = -1
    return out


def _mut_phase_monotone(sched, rng, n_nodes=None):
    if sched.phase_of is None:
        return None
    m = len(sched.transfers)
    cands = [
        (i, d)
        for i, t in enumerate(sched.transfers)
        for d in t.deps
        if 0 <= d < m and sched.phase_of[d] < sched.phase_of[i]
    ]
    if not cands:
        return None
    i, d = _pick(rng, cands)
    out = _clone(sched)
    out.phase_of[d] = out.phase_of[i]             # collapse the strict gap
    return out


def _mut_negative_payload(sched, rng, n_nodes=None):
    m = len(sched.transfers)
    if m == 0:
        return None
    out = _clone(sched)
    i = int(rng.integers(0, m))
    t = out.transfers[i]
    variant = int(rng.integers(0, 3))
    if variant == 0:
        out.transfers[i] = dataclasses.replace(t, nbytes=-1.0)
    elif variant == 1:
        out.transfers[i] = dataclasses.replace(t, nbytes=float("inf"))
    else:
        out.transfers[i] = dataclasses.replace(t, compute_ms=-0.5)
    return out


def _mut_node_bounds(sched, rng, n_nodes=None):
    if n_nodes is None:
        return None
    m = len(sched.transfers)
    if m == 0:
        return None
    out = _clone(sched)
    wires = _wire_indices(sched)
    if wires and rng.integers(0, 2):
        # relay via one of its own endpoints
        i = _pick(rng, wires)
        t = out.transfers[i]
        out.transfers[i] = dataclasses.replace(t, via=t.src)
    else:
        i = int(rng.integers(0, m))
        t = out.transfers[i]
        out.transfers[i] = dataclasses.replace(
            t, dst=n_nodes + int(rng.integers(0, 3))
        )
    return out


def _mut_local_stage(sched, rng, n_nodes=None):
    cands = [i for i, t in enumerate(sched.transfers)
             if t.src != t.dst and t.nbytes > 0.0]
    if not cands:
        return None
    # fold a payload-carrying wire transfer onto its own source: the bytes
    # would silently vanish from the wire and every byte counter
    i = _pick(rng, cands)
    out = _clone(sched)
    t = out.transfers[i]
    out.transfers[i] = dataclasses.replace(t, dst=t.src, via=-1)
    return out


def _mut_epoch_monotone(sched, rng, n_nodes=None):
    m = len(sched.transfers)
    cands = [
        (i, d)
        for i, t in enumerate(sched.transfers)
        for d in t.deps
        if 0 <= d < m
    ]
    if not cands:
        return None
    i, d = _pick(rng, cands)
    out = _clone(sched)
    td = out.transfers[d]
    out.transfers[d] = dataclasses.replace(
        td, epoch=out.transfers[i].epoch + 1
    )
    return out


def _mut_epoch_contiguity(sched, rng, n_nodes=None):
    m = len(sched.transfers)
    if m == 0:
        return None
    out = _clone(sched)
    i = int(rng.integers(0, m))
    t = out.transfers[i]
    if rng.integers(0, 2):
        out.transfers[i] = dataclasses.replace(t, epoch=-2)
    else:
        max_epoch = max(tr.epoch for tr in sched.transfers)
        out.transfers[i] = dataclasses.replace(t, epoch=max_epoch + 2)
    return out


def _mut_clock_chain(sched, rng, n_nodes=None):
    clocks = [i for i, t in enumerate(sched.transfers) if t.tag == "clock"]
    if len(clocks) < 2:
        return None
    out = _clone(sched)
    pos = int(rng.integers(1, len(clocks)))
    i = clocks[pos]
    t = out.transfers[i]
    if rng.integers(0, 2):
        # unhook from the previous clock
        prev = clocks[pos - 1]
        out.transfers[i] = dataclasses.replace(
            t, deps=tuple(d for d in t.deps if d != prev)
        )
    else:
        # duplicate the previous clock's epoch (must strictly increase)
        out.transfers[i] = dataclasses.replace(
            t, epoch=sched.transfers[clocks[pos - 1]].epoch
        )
    return out


MUTATORS: dict[str, Callable] = {
    "cycle": _mut_cycle,
    "dep-bounds": _mut_dep_bounds,
    "topo-order": _mut_topo_order,
    "phase-shape": _mut_phase_shape,
    "phase-monotone": _mut_phase_monotone,
    "negative-payload": _mut_negative_payload,
    "node-bounds": _mut_node_bounds,
    "local-stage": _mut_local_stage,
    "epoch-monotone": _mut_epoch_monotone,
    "epoch-contiguity": _mut_epoch_contiguity,
    "clock-chain": _mut_clock_chain,
}


def mutate_schedule(sched, rule: str, rng, *, n_nodes: Optional[int] = None):
    """Derive a mutant of ``sched`` breaking ``rule`` (a ``verify_schedule``
    rule slug), or ``None`` when the rule is not expressible on this base.
    ``sched`` itself is never modified."""
    try:
        fn = MUTATORS[rule]
    except KeyError:
        raise ValueError(
            f"unknown rule {rule!r}; expected one of {sorted(MUTATORS)}"
        ) from None
    return fn(sched, rng, n_nodes=n_nodes)
