"""Bounded explicit-state model checker for the engine's soundness theorems.

The repo's correctness rests on four hand-proved theorems:

1. **Bandwidth admission** (PR 4): for every dependency-tracked transfer
   DAG, the event engine's makespan never exceeds the barrier engine's
   phase-sum — ``event <= barrier`` on CPU-free DAGs, and the
   compute-augmented bound ``event <= barrier + sum(compute_ms)``
   otherwise (the barrier engine ignores CPU by definition).
2. **OCC epoch atomicity + abort-set monotonicity** (PR 5): committed
   transactions of an epoch are equivalent to one atomic snapshot
   application (at most one committed writer per key, committed reads are
   snapshot-exact, and the merged post-state is invariant under *every*
   apply order), and versioning the same transaction stream's reads
   against older snapshot views only ever *adds* aborts (no-reinstatement
   first-writer-wins keeps the write-write set fixed).
3. **Streaming-frontier eviction safety** (PR 8): under every reachable
   commit-delivery interleaving, view advancement never reads a
   timeline commit row below the eviction frontier, views advance
   contiguous epoch prefixes, and pending update batches are released
   only below every view's frontier.
4. **Serving prefix sufficiency** (PR 9): the serving sink's merged-prefix
   pointers reproduce the batch full-matrix staleness numbers exactly,
   for every reachable commit interleaving.

Until now these were spot-checked by hypothesis sampling and benchmark
gates.  This module checks them *exhaustively* over every instance inside
small, documented scopes (bounded model checking: violations at small
scope are overwhelmingly where protocol bugs live), and additionally
certifies ``verify_schedule`` completeness on the enumerated DAG space:
every enumerated valid DAG is accepted, every single-rule mutant
(:mod:`repro.analysis.mutate`) and every instance of an exhaustively
enumerated invalid micro-box is rejected.

The PR-3-era "greedy loses on adversarial matrices" note becomes a
systematically generated counterexample corpus: the same enumeration run
with ``admission=False`` yields pinned instances with a strict
``event > barrier`` loss (up to ~43% at quick scope), reproducible via
:func:`rebuild_counterexample`.

What bounded scope does **not** cover: relayed transfers (``via >= 0``),
stochastic loss, n_nodes beyond the grid bounds, interleaved per-txn
serializability (write skew between committed transactions is *permitted*
by epoch OCC and only counted here — the guarantee is snapshot-epoch
atomicity, not strict serializability), and partial per-group view merges.

CLI (CI runs the quick tier ahead of tier-1)::

    PYTHONPATH=src python -m repro.analysis.modelcheck --tier quick
    PYTHONPATH=src python -m repro.analysis.modelcheck --tier deep   # opt-in
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import math
import sys
from typing import Callable, Iterable, Sequence

import numpy as np

from ..core.crdt import DeltaCRDTStore, Update, Version, merge_updates
from ..core.occ import Txn, validate_epoch_detailed, txn_updates
from ..core.schedule import Transfer, TransmissionSchedule
from ..core.simulator import WANSimulator
from ..core.stream import StreamingTimeline
from .mutate import MUTATORS
from .schedule_check import verify_schedule
from .violations import Violation

__all__ = [
    "DagGrid", "Scope", "scope_for",
    "TheoremReport", "ModelCheckReport",
    "check_admission", "check_confluence", "check_occ_atomicity",
    "check_abort_monotonicity", "check_eviction",
    "rebuild_counterexample", "run_selftest", "run_tier",
    "model_checked_count", "reset_model_checked_count",
    "main",
]

_REL_TOL = 1e-9
_ABS_TOL = 1e-6

# -- provenance counters (mirrors schedule_check.verified_schedule_count) ----

THEOREMS = (
    "admission", "confluence", "occ_atomicity", "abort_monotonicity",
    "eviction_prefix",
)

_CHECKED: dict[str, int] = {t: 0 for t in THEOREMS}


def model_checked_count(theorem: str | None = None) -> int:
    """Violation-free model-checked instances since process start / the
    last reset; the benchmark harness's provenance signal.  With
    ``theorem`` (one of :data:`THEOREMS`) the per-theorem count."""
    if theorem is not None:
        return _CHECKED[theorem]
    return sum(_CHECKED.values())


def reset_model_checked_count() -> None:
    for t in THEOREMS:
        _CHECKED[t] = 0


# -- scopes ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DagGrid:
    """One exhaustively enumerated slice of transfer-DAG space.

    Every combination of endpoint assignment (``endpoint_mode``:
    ``"all"`` = all n^2 ordered pairs including local compute stages,
    ``"wire"`` = off-diagonal only, ``"alternating"`` = the fixed
    0->1/1->0 pattern used to push transfer counts to 6), dependency
    structure (all subsets of earlier transfers, or the explicit
    ``dep_patterns`` slice), payload assignment (full cross product of
    ``payloads`` when ``cross_payloads``, else the cycled pattern) and
    compute pattern is enumerated — the grid is a cartesian box, so
    "exhaustive at scope" is a checkable claim, not a sample.
    """

    n: int
    m_min: int
    m_max: int
    payloads: tuple[float, ...]
    cross_payloads: bool
    compute_patterns: tuple[tuple[float, ...], ...]
    bw_names: tuple[str, ...]
    endpoint_mode: str = "all"
    dep_patterns: tuple[tuple[tuple[int, ...], ...], ...] | None = None
    greedy_arm: bool = False   # also run admission=False for the corpus


@dataclasses.dataclass(frozen=True)
class Scope:
    name: str
    dag_grids: tuple[DagGrid, ...]
    mutant_stride: int          # sample a mutant batch every k-th DAG (0=off)
    micro_completeness: bool    # exhaustive valid/invalid micro-box
    crdt_seqs: int              # versions per key = seqs * nodes
    crdt_nodes: int
    crdt_max_updates: int
    occ_full_max_txns: int      # all 36 txn shapes up to this many txns
    occ_reduced_txns: tuple[int, ...]   # reduced 12-shape space at these T
    mono_chain_len: int         # snapshot-prefix chain length (views = L+1)
    mono_txns: tuple[int, ...]
    evict_grids: tuple[tuple[int, int], ...]    # (n_nodes, epochs)


# the dependency-structure slice of the m=4 corpus grids: one fan-free
# two-root shape (where the worst greedy losses live), its mirror, a chain,
# and a full fan-in
_DEP_SLICE_M4 = (
    ((), (), (1,), (0,)),
    ((), (), (0,), (1,)),
    ((), (0,), (1,), (2,)),
    ((), (), (), (0, 1, 2)),
)

_PAYLOADS = (250_000.0, 25_000.0)
_CPU_BOTH = ((0.0,), (0.0, 0.4))
_CPU_OFF = ((0.0,),)

_SCOPES = {
    # the always-on CI tier: every grid fully enumerated, < ~60 s total
    "quick": Scope(
        name="quick",
        dag_grids=(
            DagGrid(2, 1, 3, _PAYLOADS, True, _CPU_BOTH,
                    ("uniform", "tri")),
            DagGrid(3, 1, 3, _PAYLOADS, False, _CPU_BOTH,
                    ("uniform", "tri")),
            DagGrid(3, 4, 4, _PAYLOADS, False, _CPU_OFF,
                    ("tri", "rand"), endpoint_mode="wire",
                    dep_patterns=_DEP_SLICE_M4, greedy_arm=True),
        ),
        mutant_stride=29,
        micro_completeness=True,
        crdt_seqs=2, crdt_nodes=2, crdt_max_updates=4,
        occ_full_max_txns=2, occ_reduced_txns=(3,),
        mono_chain_len=2, mono_txns=(1, 2),
        evict_grids=((2, 3), (3, 3), (2, 4)),
    ),
    # documented opt-in: pushes the DAG box to n=4 / m<=4 full deps and
    # m<=6 on the alternating-endpoint slice, full 36-shape OCC at T=3,
    # L=3 monotonicity chains, E=4 interleavings at n=3
    "deep": Scope(
        name="deep",
        dag_grids=(
            DagGrid(2, 1, 4, _PAYLOADS, True, _CPU_BOTH,
                    ("uniform", "tri")),
            DagGrid(3, 1, 3, _PAYLOADS, False, _CPU_BOTH,
                    ("uniform", "tri")),
            DagGrid(4, 1, 3, _PAYLOADS, False, _CPU_OFF,
                    ("uniform", "tri")),
            DagGrid(2, 5, 6, _PAYLOADS, False, _CPU_OFF,
                    ("uniform", "tri"), endpoint_mode="alternating"),
            DagGrid(3, 4, 4, _PAYLOADS, False, _CPU_OFF,
                    ("tri", "rand"), endpoint_mode="wire",
                    greedy_arm=True),
        ),
        mutant_stride=101,
        micro_completeness=True,
        crdt_seqs=2, crdt_nodes=2, crdt_max_updates=5,
        occ_full_max_txns=3, occ_reduced_txns=(4,),
        mono_chain_len=3, mono_txns=(1, 2, 3),
        evict_grids=((2, 3), (3, 3), (2, 4), (3, 4), (2, 5)),
    ),
    # the benchmark-provenance / test scope: same checks, tiny boxes
    "smoke": Scope(
        name="smoke",
        dag_grids=(
            DagGrid(2, 1, 2, _PAYLOADS, True, _CPU_BOTH,
                    ("uniform", "tri")),
            DagGrid(3, 4, 4, _PAYLOADS, False, _CPU_OFF,
                    ("tri",), endpoint_mode="wire",
                    dep_patterns=_DEP_SLICE_M4[:1], greedy_arm=True),
        ),
        mutant_stride=17,
        micro_completeness=False,
        crdt_seqs=2, crdt_nodes=1, crdt_max_updates=3,
        occ_full_max_txns=2, occ_reduced_txns=(),
        mono_chain_len=2, mono_txns=(2,),
        evict_grids=((2, 3),),
    ),
}


def scope_for(tier: str) -> Scope:
    try:
        return _SCOPES[tier]
    except KeyError:
        raise ValueError(
            f"unknown tier {tier!r}; expected one of {sorted(_SCOPES)}"
        ) from None


# -- reports -----------------------------------------------------------------


@dataclasses.dataclass
class TheoremReport:
    name: str
    instances: int
    violations: list[Violation]
    info: dict

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclasses.dataclass
class ModelCheckReport:
    tier: str
    theorems: list[TheoremReport]
    mutants_rejected: dict[str, bool]

    @property
    def ok(self) -> bool:
        return all(t.ok for t in self.theorems) and \
            all(self.mutants_rejected.values())

    def counts(self) -> dict[str, int]:
        return {t.name: t.instances for t in self.theorems}


# -- quantized network settings ----------------------------------------------


def _lat_matrix(n: int) -> np.ndarray:
    lat = np.zeros((n, n))
    for s in range(n):
        for d in range(n):
            if s != d:
                lat[s, d] = 1.0 + 0.25 * ((3 * s + d) % 4)
    return lat


def _bw_matrix(n: int, name: str) -> np.ndarray:
    """Quantized bandwidth settings: ``uniform`` (6 Mbps everywhere),
    ``tri`` (lower-triangle links starved at 4 Mbps vs 40 Mbps — the
    deterministic adversarial pattern), ``rand`` (seeded 4..10 Mbps, the
    PR-4 adversarial-matrix family)."""
    if name == "uniform":
        return np.full((n, n), 6.0)
    if name == "tri":
        bw = np.full((n, n), 40.0)
        for s in range(n):
            for d in range(n):
                if s > d:
                    bw[s, d] = 4.0
        return bw
    if name == "rand":
        return np.random.default_rng(0).uniform(4.0, 10.0, size=(n, n))
    raise ValueError(f"unknown bandwidth setting {name!r}")


# -- DAG enumeration ---------------------------------------------------------


def _subsets(k: int) -> list[tuple[int, ...]]:
    return [tuple(j for j in range(k) if mask >> j & 1)
            for mask in range(2 ** k)]


def _iter_dags(grid: DagGrid) -> Iterable[tuple[TransmissionSchedule, float]]:
    """Yield ``(schedule, total_compute_ms)`` for every instance in the
    grid's cartesian box.  Every yielded schedule is valid by construction
    (deps precede, local stages carry no payload, epochs all 0)."""
    n = grid.n
    if grid.endpoint_mode == "wire":
        pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
    else:
        pairs = [(s, d) for s in range(n) for d in range(n)]
    for m in range(grid.m_min, grid.m_max + 1):
        if grid.endpoint_mode == "alternating":
            ep_choices: Iterable = [tuple(
                (0, 1) if i % 2 == 0 else (1, 0) for i in range(m)
            )]
        else:
            ep_choices = itertools.product(pairs, repeat=m)
        if grid.dep_patterns is not None:
            dep_choices = [p for p in grid.dep_patterns if len(p) == m]
        else:
            dep_choices = list(
                itertools.product(*[_subsets(i) for i in range(m)])
            )
        if grid.cross_payloads:
            pay_choices = list(itertools.product(grid.payloads, repeat=m))
        else:
            pay_choices = [tuple(
                grid.payloads[i % len(grid.payloads)] for i in range(m)
            )]
        for ep in ep_choices:
            for deps in dep_choices:
                for pays in pay_choices:
                    for cpat in grid.compute_patterns:
                        cpu = 0.0
                        transfers = []
                        for i, ((s, d), dp) in enumerate(zip(ep, deps)):
                            c = cpat[i % len(cpat)]
                            cpu += c
                            transfers.append(Transfer(
                                s, d, pays[i] if s != d else 0.0,
                                deps=dp, compute_ms=c,
                            ))
                        yield (
                            TransmissionSchedule(transfers, label="mc"),
                            cpu,
                        )


def _describe(sched: TransmissionSchedule, n: int, bw_name: str) -> str:
    ts = [(t.src, t.dst, t.nbytes, t.deps, t.compute_ms)
          for t in sched.transfers]
    return f"n={n} bw={bw_name} transfers={ts}"


# -- theorem 1: bandwidth admission + verifier completeness + corpus ---------


def check_admission(
    scope: Scope,
    *,
    simulator_factory: Callable[..., WANSimulator] | None = None,
    mutant_seed: int = 20250807,
) -> TheoremReport:
    """Exhaustively machine-check ``event <= barrier + sum(compute_ms)``
    (and the plain ``event <= barrier`` on CPU-free instances) over every
    DAG in the scope's grids; certify verifier completeness on the same
    enumeration (valid side on every instance, invalid side on sampled
    single-rule mutants plus the exhaustive micro-box); and collect the
    ``admission=False`` greedy counterexample corpus on the adversarial
    grids."""
    sim_f = simulator_factory or WANSimulator
    violations: list[Violation] = []
    instances = 0
    valid_accepted = 0
    mutants_total = mutants_caught = 0
    corpus: list[dict] = []
    rng = np.random.default_rng(mutant_seed)
    counter = 0
    for grid in scope.dag_grids:
        lat = _lat_matrix(grid.n)
        for bw_name in grid.bw_names:
            bw = _bw_matrix(grid.n, bw_name)
            sim = sim_f(lat, bw)
            greedy = WANSimulator(lat, bw, admission=False) \
                if grid.greedy_arm else None
            for sched, cpu in _iter_dags(grid):
                counter += 1
                instances += 1
                if verify_schedule(sched, n_nodes=grid.n):
                    violations.append(Violation(
                        "verifier-valid-rejected",
                        "enumerated valid DAG rejected by verify_schedule: "
                        + _describe(sched, grid.n, bw_name),
                    ))
                else:
                    valid_accepted += 1
                barrier = sim.barrier_makespan_ms(sched)
                event = sim.run(sched).makespan_ms
                bound = barrier + cpu
                if event > bound * (1.0 + _REL_TOL) + _ABS_TOL:
                    violations.append(Violation(
                        "admission",
                        f"event {event:.6f} > barrier {barrier:.6f} + "
                        f"compute {cpu:.3f}: "
                        + _describe(sched, grid.n, bw_name),
                    ))
                else:
                    _CHECKED["admission"] += 1
                if greedy is not None and cpu == 0.0:
                    g = greedy.run(sched).makespan_ms
                    if g > barrier * (1.0 + _REL_TOL) + _ABS_TOL:
                        corpus.append({
                            "n_nodes": grid.n,
                            "bw": bw_name,
                            "barrier_ms": barrier,
                            "greedy_ms": g,
                            "loss": g / barrier - 1.0,
                            "transfers": [
                                [t.src, t.dst, t.nbytes, list(t.deps)]
                                for t in sched.transfers
                            ],
                        })
                if scope.mutant_stride and counter % scope.mutant_stride == 0:
                    for rule, fn in MUTATORS.items():
                        mut = fn(sched, rng, n_nodes=grid.n)
                        if mut is None:
                            continue
                        mutants_total += 1
                        got = {v.rule for v in
                               verify_schedule(mut, n_nodes=grid.n)}
                        if rule in got:
                            mutants_caught += 1
                        else:
                            violations.append(Violation(
                                "verifier-mutant-missed",
                                f"single-rule mutant for {rule!r} not "
                                "caught on "
                                + _describe(mut, grid.n, bw_name),
                            ))
    info: dict = {
        "valid_accepted": valid_accepted,
        "mutants": f"{mutants_caught}/{mutants_total}",
        "corpus_size": len(corpus),
        "corpus_max_loss": max((c["loss"] for c in corpus), default=0.0),
        "corpus": corpus,
    }
    if scope.micro_completeness:
        micro_total, micro_valid, micro_viol = _micro_box()
        violations.extend(micro_viol)
        info["micro_box"] = {
            "instances": micro_total, "valid": micro_valid,
        }
    return TheoremReport("admission", instances, violations, info)


def rebuild_counterexample(entry: dict):
    """Reconstruct ``(schedule, lat, bw)`` from a corpus entry, so a test
    (or a reader) can replay the strict ``event > barrier`` loss."""
    n = entry["n_nodes"]
    transfers = [
        Transfer(src, dst, nbytes, deps=tuple(deps))
        for src, dst, nbytes, deps in entry["transfers"]
    ]
    return (
        TransmissionSchedule(transfers, label="counterexample"),
        _lat_matrix(n),
        _bw_matrix(n, entry["bw"]),
    )


# -- verifier completeness micro-box -----------------------------------------


def _raw_schedule(transfers) -> TransmissionSchedule:
    # bypass constructor validation: the box deliberately contains invalid
    # instances the constructor would reject
    s = TransmissionSchedule.__new__(TransmissionSchedule)
    s.transfers = list(transfers)
    s.label = "micro"
    s.phase_of = None
    return s


def _reference_valid(transfers: Sequence[Transfer], n: int) -> bool:
    """Independent re-statement of the verifier's rule set on the
    clock-free / phase-free micro-box (the model in model checking)."""
    seen: set[int] = set()
    for i, t in enumerate(transfers):
        if not (math.isfinite(t.nbytes) and t.nbytes >= 0.0):
            return False
        if not (math.isfinite(t.compute_ms) and t.compute_ms >= 0.0):
            return False
        if not (0 <= t.src < n and 0 <= t.dst < n):
            return False
        if t.via >= n:
            return False
        if t.via >= 0 and t.via in (t.src, t.dst):
            return False
        if t.src == t.dst and (t.nbytes != 0.0 or t.via >= 0):
            return False
        if t.epoch < 0:
            return False
        for d in t.deps:
            if not 0 <= d < i:
                return False
            if transfers[d].epoch > t.epoch:
                return False
        seen.add(t.epoch)
    if seen and set(range(max(seen) + 1)) - seen:
        return False
    return True


def _micro_box() -> tuple[int, int, list[Violation]]:
    """Exhaustively compare ``verify_schedule`` against the independent
    reference predicate on a micro-box that crosses *valid and invalid*
    field values: n=2, m<=2, deps in {(), (-1,), (0,), (1,), (2,), (0,1)},
    nbytes in {-1, 0, 250k}, epoch in {0, 1}; via in {-1, 0, 1} at m=1."""
    n = 2
    endpoints = [(s, d) for s in range(n) for d in range(n)]
    dep_opts = [(), (-1,), (0,), (1,), (2,), (0, 1)]
    nbytes_opts = [-1.0, 0.0, 250_000.0]
    epoch_opts = [0, 1]
    violations: list[Violation] = []
    total = valid = 0

    def _one(transfers):
        nonlocal total, valid
        total += 1
        expected = _reference_valid(transfers, n)
        got = not verify_schedule(_raw_schedule(transfers), n_nodes=n)
        if expected:
            valid += 1
        if expected != got:
            ts = [(t.src, t.dst, t.nbytes, t.deps, t.via, t.epoch)
                  for t in transfers]
            violations.append(Violation(
                "verifier-completeness",
                f"micro-box disagreement (reference says "
                f"{'valid' if expected else 'invalid'}): {ts}",
            ))

    opts1 = [
        Transfer(s, d, nb, via=via, deps=dp, epoch=e)
        for (s, d) in endpoints for dp in dep_opts
        for nb in nbytes_opts for e in epoch_opts for via in (-1, 0, 1)
    ]
    for t in opts1:
        _one([t])
    opts2 = [
        Transfer(s, d, nb, deps=dp, epoch=e)
        for (s, d) in endpoints for dp in dep_opts
        for nb in nbytes_opts for e in epoch_opts
    ]
    for a in opts2:
        for b in opts2:
            _one([a, b])
    return total, valid, violations


# -- theorem 2a: CRDT merge confluence ---------------------------------------


def _uval(key: str, ver: Version) -> bytes:
    return f"{key}|{ver.epoch}.{ver.seq}.{ver.node}".encode()


def check_confluence(
    scope: Scope,
    *,
    store_factory: Callable[[], DeltaCRDTStore] = DeltaCRDTStore,
) -> TheoremReport:
    """All delivery orders converge: for every update subset at scope,
    every apply permutation, every redelivery, and every two-replica
    split/merge (both merge directions) produce one digest, and
    ``merge_updates`` is permutation-invariant."""
    keys = ("a", "b")
    versions = [
        Version(0, s, nd)
        for s in range(scope.crdt_seqs) for nd in range(scope.crdt_nodes)
    ]
    universe = [Update(k, _uval(k, v), v) for k in keys for v in versions]
    violations: list[Violation] = []
    instances = 0
    for r in range(1, scope.crdt_max_updates + 1):
        for combo in itertools.combinations(universe, r):
            instances += 1
            ref = store_factory()
            ref.apply_many(combo)
            ref_digest = ref.digest()
            ref_merge = merge_updates(combo)
            bad = None
            for perm in itertools.permutations(combo):
                s = store_factory()
                s.apply_many(perm)
                if s.digest() != ref_digest:
                    bad = f"apply order {perm} diverges"
                    break
                if merge_updates(perm) != ref_merge:
                    bad = f"merge_updates({perm}) diverges"
                    break
            if bad is None:
                s = store_factory()
                s.apply_many(combo)
                s.apply(combo[0])       # duplicated redelivery
                if s.digest() != ref_digest:
                    bad = "redelivery changed the state"
            if bad is None:
                for mask in range(2 ** r):
                    a, b = store_factory(), store_factory()
                    for j, u in enumerate(combo):
                        (a if mask >> j & 1 else b).apply(u)
                    a.merge_store(b)
                    if a.digest() != ref_digest:
                        bad = f"replica split {mask:0{r}b} a<-b diverges"
                        break
                    c, d = store_factory(), store_factory()
                    for j, u in enumerate(combo):
                        (c if mask >> j & 1 else d).apply(u)
                    d.merge_store(c)
                    if d.digest() != ref_digest:
                        bad = f"replica split {mask:0{r}b} b<-a diverges"
                        break
            if bad is None:
                _CHECKED["confluence"] += 1
            else:
                violations.append(Violation(
                    "confluence",
                    f"{bad}; updates={[(u.key, u.version) for u in combo]}",
                ))
    return TheoremReport(
        "confluence", instances, violations,
        {"universe": len(universe)},
    )


# -- theorem 2b: OCC epoch atomicity -----------------------------------------


def _occ_snapshots() -> list[tuple[str, DeltaCRDTStore]]:
    empty = DeltaCRDTStore()
    low = DeltaCRDTStore()
    low.apply(Update("x", _uval("x", Version(0, 0, 0)), Version(0, 0, 0)))
    low.apply(Update("y", _uval("y", Version(0, 0, 1)), Version(0, 0, 1)))
    mixed = DeltaCRDTStore()
    mixed.apply(Update("x", _uval("x", Version(0, 1, 1)), Version(0, 1, 1)))
    mixed.apply(Update("y", _uval("y", Version(0, 0, 0)), Version(0, 0, 0)))
    return [("empty", empty), ("low", low), ("mixed", mixed)]


def _stale_version(fresh: Version) -> Version:
    return Version(fresh.epoch - 1, fresh.seq, fresh.node)


def _txn_shapes(keys, *, full: bool):
    """(reads, writes) shapes; reads are (key, kind) with kind in
    fresh|stale.  Full: all 3^|keys| read configs x all write subsets.
    Reduced (for larger T): single-key reads x single-key writes."""
    if full:
        read_cfgs = []
        for kinds in itertools.product(("none", "fresh", "stale"),
                                       repeat=len(keys)):
            read_cfgs.append(tuple(
                (k, kind) for k, kind in zip(keys, kinds) if kind != "none"
            ))
        write_cfgs = []
        for r in range(len(keys) + 1):
            write_cfgs.extend(itertools.combinations(keys, r))
    else:
        read_cfgs = [(), (("x", "fresh"),), (("x", "stale"),),
                     (("y", "fresh"),)]
        write_cfgs = [(), ("x",), ("y",)]
    return [(r, tuple(w)) for r in read_cfgs for w in write_cfgs]


def _mk_txns(combo, snap: DeltaCRDTStore, seq_mode: str) -> list[Txn]:
    txns = []
    for t_idx, (reads, writes) in enumerate(combo):
        read_set = []
        for k, kind in reads:
            fresh = snap.version_of(k)
            read_set.append((k, fresh if kind == "fresh"
                             else _stale_version(fresh)))
        txns.append(Txn(
            txn_id=t_idx, node=t_idx % 3, epoch=1,
            seq=0 if seq_mode == "colliding" else t_idx,
            read_set=tuple(read_set),
            write_set=tuple((k, f"w{t_idx}|{k}".encode()) for k in writes),
        ))
    return txns


def _occ_spec(txns, snap):
    """Independent restatement of the validation rules (the docstring
    spec of repro.core.occ, re-derived)."""
    read_ab = frozenset(
        t.txn_id for t in txns
        if any(snap.version_of(k) > v for k, v in t.read_set)
    )
    winners: dict[str, tuple[Version, int]] = {}
    for t in txns:
        for k in t.writes_keys():
            c = (t.version, t.txn_id)
            if k not in winners or c < winners[k]:
                winners[k] = c
    ww = frozenset(
        t.txn_id for t in txns
        if any((t.version, t.txn_id) != winners[k]
               for k in t.writes_keys())
    )
    committed = frozenset(t.txn_id for t in txns) - read_ab - ww
    return committed, read_ab, ww


def check_occ_atomicity(scope: Scope) -> TheoremReport:
    """Exhaustive epoch-OCC exploration at scope: python/numpy mode
    equivalence, agreement with the independent rule spec, winner
    uniqueness, snapshot-exact committed reads, and order-invariant
    post-state (every apply permutation of the committed set merges to one
    digest — the snapshot-epoch atomicity GeoGauss guarantees).  Write
    skew between committed transactions is permitted (counted, not
    flagged): the theorem is epoch atomicity, not strict per-txn
    serializability."""
    violations: list[Violation] = []
    instances = 0
    write_skew = 0
    shape_sets = [(T, _txn_shapes(("x", "y"), full=True))
                  for T in range(1, scope.occ_full_max_txns + 1)]
    shape_sets += [(T, _txn_shapes(("x", "y"), full=False))
                   for T in scope.occ_reduced_txns]
    for snap_name, snap in _occ_snapshots():
        for T, shapes in shape_sets:
            for combo in itertools.product(shapes, repeat=T):
                for seq_mode in ("distinct", "colliding"):
                    instances += 1
                    txns = _mk_txns(combo, snap, seq_mode)
                    bad = _check_one_epoch(txns, snap)
                    if bad is None:
                        _CHECKED["occ_atomicity"] += 1
                        write_skew += _has_write_skew(txns, snap)
                    else:
                        violations.append(Violation(
                            "occ-atomicity",
                            f"{bad}; snapshot={snap_name} "
                            f"seq_mode={seq_mode} shapes={combo}",
                        ))
    return TheoremReport(
        "occ_atomicity", instances, violations,
        {"write_skew_instances": write_skew},
    )


def _check_one_epoch(txns, snap) -> str | None:
    rp = validate_epoch_detailed(txns, snap, mode="python")
    rn = validate_epoch_detailed(txns, snap, mode="numpy")
    if (rp.committed, rp.read_aborted, rp.ww_aborted) != \
            (rn.committed, rn.read_aborted, rn.ww_aborted):
        return f"python/numpy mode divergence: {rp} vs {rn}"
    if (rp.committed, rp.read_aborted, rp.ww_aborted) != \
            _occ_spec(txns, snap):
        return f"result diverges from the rule spec: {rp}"
    committed = [t for t in txns if t.txn_id in rp.committed]
    writers: dict[str, int] = {}
    for t in committed:
        for k in t.writes_keys():
            writers[k] = writers.get(k, 0) + 1
    if any(c > 1 for c in writers.values()):
        return f"winner uniqueness violated: {writers}"
    for t in committed:
        for k, v in t.read_set:
            if v != snap.version_of(k):
                return f"committed txn {t.txn_id} read {k} off-snapshot"
    ref = snap.snapshot()
    for t in sorted(committed, key=lambda t: (t.version, t.txn_id)):
        ref.apply_many(txn_updates(t))
    ref_digest = ref.digest()
    for perm in itertools.permutations(committed):
        s = snap.snapshot()
        for t in perm:
            s.apply_many(txn_updates(t))
        if s.digest() != ref_digest:
            return f"apply order {[t.txn_id for t in perm]} diverges"
    return None


def _has_write_skew(txns, snap) -> bool:
    rp = validate_epoch_detailed(txns, snap, mode="python")
    committed = [t for t in txns if t.txn_id in rp.committed]
    for a, b in itertools.combinations(committed, 2):
        a_reads = {k for k, _ in a.read_set}
        b_reads = {k for k, _ in b.read_set}
        if (set(a.writes_keys()) & b_reads) and \
                (set(b.writes_keys()) & a_reads):
            return True
    return False


# -- theorem 2c: abort-set monotonicity in staleness -------------------------


def check_abort_monotonicity(
    scope: Scope,
    *,
    validate: Callable | None = None,
) -> TheoremReport:
    """For every snapshot-prefix chain S0 c S1 c ... c SL and every txn
    shape combination, versioning the reads against an older view only
    ever adds aborts: aborted(Si) >= aborted(Sj) for i <= j, the
    read-abort set is monotone, and the write-write set is *identical*
    across views (no reinstatement keeps it a function of write sets
    alone).  ``validate`` swaps the validation function (the seeded
    reinstatement mutant must be caught here)."""
    vf = validate or (
        lambda txns, snap: validate_epoch_detailed(txns, snap, mode="python")
    )
    keys = ("x", "y")
    L = scope.mono_chain_len
    shapes = [(r, w)
              for r in _powerset(keys) for w in _powerset(keys)]
    violations: list[Violation] = []
    instances = 0
    for chain in itertools.product(keys, repeat=L):
        stores = [DeltaCRDTStore()]
        for j, k in enumerate(chain):
            s = stores[-1].snapshot()
            s.apply(Update(k, _uval(k, Version(0, j, 0)), Version(0, j, 0)))
            stores.append(s)
        snap = stores[-1]        # the epoch-start snapshot
        for T in scope.mono_txns:
            for combo in itertools.product(shapes, repeat=T):
                instances += 1
                results = []
                for view in stores:
                    txns = [Txn(
                        txn_id=t_idx, node=t_idx % 3, epoch=1, seq=t_idx,
                        read_set=tuple(
                            (k, view.version_of(k)) for k in reads
                        ),
                        write_set=tuple(
                            (k, f"w{t_idx}".encode()) for k in writes
                        ),
                    ) for t_idx, (reads, writes) in enumerate(combo)]
                    results.append(vf(txns, snap))
                bad = None
                for i in range(len(results)):
                    for j in range(i + 1, len(results)):
                        ri, rj = results[i], results[j]
                        if not ri.aborted >= rj.aborted:
                            bad = (f"aborted(S{i}) !>= aborted(S{j}): "
                                   f"{set(ri.aborted)} vs {set(rj.aborted)}")
                        elif not ri.read_aborted >= rj.read_aborted:
                            bad = f"read aborts not monotone (S{i}, S{j})"
                        elif ri.ww_aborted != rj.ww_aborted:
                            bad = (f"ww aborts differ across views "
                                   f"(S{i}, S{j}): reinstatement?")
                        if bad:
                            break
                    if bad:
                        break
                if bad is None:
                    _CHECKED["abort_monotonicity"] += 1
                else:
                    violations.append(Violation(
                        "abort-monotonicity",
                        f"{bad}; chain={chain} shapes={combo}",
                    ))
    return TheoremReport(
        "abort_monotonicity", instances, violations, {"views": L + 1},
    )


def _powerset(keys):
    out = []
    for r in range(len(keys) + 1):
        out.extend(itertools.combinations(keys, r))
    return out


# -- theorems 3 + 4: eviction safety + serving prefix sufficiency ------------


def _monotone_columns(E: int, hi: int) -> list[tuple[int, ...]]:
    """All per-node commit-step columns: non-decreasing, c[k] >= k+1
    (epoch k commits no earlier than the step after it is appended),
    c[k] <= hi (hi = E+1 means 'after the run horizon')."""
    out: list[tuple[int, ...]] = []

    def rec(k: int, lo: int, acc: tuple[int, ...]):
        if k == E:
            out.append(acc)
            return
        for v in range(max(lo, k + 1), hi + 1):
            rec(k + 1, v, acc + (v,))

    rec(0, 1, ())
    return out


def check_eviction(
    scope: Scope,
    *,
    evict_floor: Callable[[np.ndarray], int] | None = None,
) -> TheoremReport:
    """Explicit-state exploration of *every* reachable commit-delivery
    interleaving at scope, driving the real protocol pieces: a
    :class:`StreamingTimeline` whose measured commit matrix realizes the
    interleaving exactly (integer-valued exec stages; epoch_ms=1), the
    real :func:`repro.core.replication.advance_views` frontier logic, and
    a real :class:`repro.serve.plane.ServingSink`.

    Checked per interleaving: no view advancement ever reads a commit row
    below the eviction frontier (the frontier is evicted to
    ``view_next.min()`` after every epoch, exactly as the engine does);
    views advance the exact delivered epoch prefix with the exact merged
    CRDT content; pending update batches are released only below every
    view; the retained timeline surface is byte-identical to the full
    matrix; and the serving sink's per-epoch staleness mean/max equal the
    batch full-matrix computation exactly (prefix sufficiency).

    ``evict_floor`` swaps the eviction policy (the seeded over-eager
    ``min+1`` mutant must produce a frontier under-read here)."""
    from ..core.replication import advance_views
    from ..serve.config import ServeConfig
    from ..serve.plane import ServingSink

    floor_fn = evict_floor or (lambda vn: int(vn.min()))
    serve_cfg = ServeConfig()
    violations: list[Violation] = []
    instances = 0
    for n, E in scope.evict_grids:
        hi = E + 1
        cols = _monotone_columns(E, hi)
        lat = np.zeros((n, n))
        ups = [[Update(f"k{k}", b"v", Version(k, 0, 0))] for k in range(E)]
        prefix = [DeltaCRDTStore().digest()]
        acc = DeltaCRDTStore()
        for k in range(E):
            acc.apply_many(ups[k])
            prefix.append(acc.digest())
        for matrix in itertools.product(cols, repeat=n):
            instances += 1
            C = np.array(matrix, dtype=float).T      # (E, n) commit steps
            bad = _drive_interleaving(
                n, E, C, lat, ups, prefix, floor_fn, advance_views,
                ServingSink(serve_cfg, n, 1.0),
            )
            if bad is None:
                _CHECKED["eviction_prefix"] += 1
            else:
                violations.append(Violation(
                    "eviction-prefix",
                    f"{bad}; n={n} E={E} commit_steps={matrix}",
                ))
    return TheoremReport(
        "eviction_prefix", instances, violations,
        {"grids": list(scope.evict_grids)},
    )


def _drive_interleaving(
    n, E, C, lat, ups, prefix, floor_fn, advance_views, sink,
) -> str | None:
    tl = StreamingTimeline(n, epoch_ms=1.0)
    views = [DeltaCRDTStore(i) for i in range(n)]
    view_next = np.zeros(n, dtype=int)
    pending: dict[int, list[Update]] = {}
    empty_round = TransmissionSchedule([], label="mc")
    appended = 0

    def advance_and_check(now: float, n_done: int) -> str | None:
        try:
            advance_views(n, views, view_next, pending, tl.commit_at,
                          n_done, now)
        except IndexError as e:
            return f"frontier under-read at now={now}: {e}"
        except KeyError as e:
            return f"pending batch read after release at now={now}: {e}"
        floor = int(view_next.min())
        for i in range(n):
            expect = int(sum(1 for k in range(n_done) if C[k, i] <= now))
            if int(view_next[i]) != expect:
                return (f"view prefix of node {i} at now={now}: "
                        f"{int(view_next[i])} != {expect}")
            if views[i].digest() != prefix[expect]:
                return f"view content of node {i} diverges at now={now}"
        if set(pending) != {k for k in range(appended) if k >= floor}:
            return (f"pending release wrong at now={now}: "
                    f"{sorted(pending)} vs floor {floor}")
        return None

    for e in range(E):
        bad = advance_and_check(float(e), tl.n_epochs)
        if bad:
            return bad
        # realize commit_at(e, i) == C[e, i] exactly: the exec stage of
        # node i starts at max(clock e, previous exec finish) — all small
        # integers, so the float arithmetic is exact
        execs = [
            C[e, i] - max(float(e), C[e - 1, i] if e else 0.0)
            for i in range(n)
        ]
        et = tl.append_epoch(empty_round, lat, node_exec_ms=execs)
        appended += 1
        sink.push(e, et.commit_ms, lat)
        pending[e] = ups[e]
        tl.evict_commit_rows(floor_fn(view_next))
        for k in range(tl.evicted_epochs, appended):
            for i in range(n):
                if tl.commit_at(k, i) != C[k, i]:
                    return (f"retained surface diverges at ({k}, {i}): "
                            f"{tl.commit_at(k, i)} != {C[k, i]}")
    for now in range(E, E + 2):      # flush past the horizon
        bad = advance_and_check(float(now), appended)
        if bad:
            return bad
        tl.evict_commit_rows(floor_fn(view_next))
    # serving prefix sufficiency: the sink's pointer-derived staleness
    # equals the batch full-matrix computation, exactly
    st = sink.finish(wall_ms=float(E))
    for e, es in enumerate(st.epochs):
        now = float(e)
        ve = (C[: e + 1] <= now + 1e-9).sum(axis=0)
        stal = np.maximum(now - ve.astype(float), 0.0)
        if es.view_staleness_ms_mean != float(stal.mean()) or \
                es.view_staleness_ms_max != float(stal.max()):
            return (f"serving staleness diverges at epoch {e}: sink "
                    f"({es.view_staleness_ms_mean}, "
                    f"{es.view_staleness_ms_max}) vs batch "
                    f"({float(stal.mean())}, {float(stal.max())})")
    return None


# -- seeded mutants (checker self-test) --------------------------------------


class _ZeroRankSimulator(WANSimulator):
    """Broken admission ranking: every transfer gets rank 0, so admission
    never defers a later-phase flow — greedy behavior under the admission
    flag.  The sweep must find ``event > barrier`` on the adversarial
    grids."""

    def _admission_ranks(self, schedule):
        return np.zeros(schedule.n_transfers, dtype=int)


class _LastArrivalStore(DeltaCRDTStore):
    """Non-commutative merge: last *arrival* wins, ignoring the version
    order — the confluence check must see permutation divergence."""

    def apply(self, u: Update) -> bool:
        self._data[u.key] = (u.value, u.version)
        return True


def _reinstating_validate(txns, snap):
    """First-writer-wins *with* reinstatement: read-aborted writers are
    dropped from the winner map, so their write-write losers commit.
    Breaks abort-set monotonicity in staleness."""
    base = validate_epoch_detailed(txns, snap, mode="python")
    alive = [t for t in txns if t.txn_id not in base.read_aborted]
    winners: dict[str, tuple[Version, int]] = {}
    for t in alive:
        for k in t.writes_keys():
            c = (t.version, t.txn_id)
            if k not in winners or c < winners[k]:
                winners[k] = c
    ww = frozenset(
        t.txn_id for t in alive
        if any((t.version, t.txn_id) != winners[k]
               for k in t.writes_keys())
    )
    committed = frozenset(t.txn_id for t in txns) - base.read_aborted - ww
    return dataclasses.replace(
        base, committed=committed, ww_aborted=ww,
    )


_SELFTEST_SCOPE = Scope(
    name="selftest",
    dag_grids=(
        DagGrid(3, 4, 4, _PAYLOADS, False, _CPU_OFF, ("tri",),
                endpoint_mode="wire", dep_patterns=_DEP_SLICE_M4[:1]),
    ),
    mutant_stride=0,
    micro_completeness=False,
    crdt_seqs=2, crdt_nodes=1, crdt_max_updates=3,
    occ_full_max_txns=2, occ_reduced_txns=(),
    mono_chain_len=2, mono_txns=(2,),
    evict_grids=((2, 3),),
)


def run_selftest() -> dict[str, bool]:
    """Run each theorem check against its seeded mutant; ``True`` means
    the mutant was rejected (the checker found violations).  All four
    must be rejected for the checker itself to be trusted."""
    s = _SELFTEST_SCOPE
    return {
        "broken-admission-ranking": bool(check_admission(
            s, simulator_factory=_ZeroRankSimulator
        ).violations),
        "non-commutative-merge": bool(check_confluence(
            s, store_factory=_LastArrivalStore
        ).violations),
        "occ-reinstatement": bool(check_abort_monotonicity(
            s, validate=_reinstating_validate
        ).violations),
        "frontier-under-read": bool(check_eviction(
            s, evict_floor=lambda vn: int(vn.min()) + 1
        ).violations),
    }


# -- driver ------------------------------------------------------------------

_CHECKS: dict[str, Callable[[Scope], TheoremReport]] = {
    "admission": check_admission,
    "confluence": check_confluence,
    "occ_atomicity": check_occ_atomicity,
    "abort_monotonicity": check_abort_monotonicity,
    "eviction_prefix": check_eviction,
}


def run_tier(
    scope: Scope,
    *,
    only: Sequence[str] | None = None,
    selftest: bool = True,
) -> ModelCheckReport:
    names = list(_CHECKS) if only is None else list(only)
    for nm in names:
        if nm not in _CHECKS:
            raise ValueError(
                f"unknown theorem {nm!r}; expected one of {sorted(_CHECKS)}"
            )
    reports = [_CHECKS[nm](scope) for nm in names]
    mutants = run_selftest() if selftest else {}
    return ModelCheckReport(scope.name, reports, mutants)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.modelcheck",
        description="Bounded explicit-state model checker for the "
                    "engine's soundness theorems.",
    )
    ap.add_argument("--tier", default="quick",
                    choices=sorted(_SCOPES),
                    help="quick: the CI tier (< ~60 s); deep: opt-in "
                         "larger boxes (minutes); smoke: the benchmark-"
                         "provenance scope")
    ap.add_argument("--only", default=None,
                    help="comma-separated theorem subset "
                         f"(of {', '.join(_CHECKS)})")
    ap.add_argument("--no-selftest", action="store_true",
                    help="skip the seeded-mutant self-test")
    args = ap.parse_args(argv)
    only = args.only.split(",") if args.only else None
    report = run_tier(
        scope_for(args.tier), only=only, selftest=not args.no_selftest,
    )
    for t in report.theorems:
        status = "ok" if t.ok else f"{len(t.violations)} VIOLATION(S)"
        print(f"{t.name:22s} {t.instances:8d} instances  {status}")
        for key in ("valid_accepted", "mutants", "corpus_size",
                    "write_skew_instances"):
            if key in t.info:
                print(f"{'':22s} {key} = {t.info[key]}")
        if "corpus_max_loss" in t.info and t.info["corpus_size"]:
            print(f"{'':22s} corpus_max_loss = "
                  f"{t.info['corpus_max_loss'] * 100:.1f}%")
        if "micro_box" in t.info:
            print(f"{'':22s} micro_box = {t.info['micro_box']}")
        for v in t.violations[:10]:
            print(f"  {v}")
        if len(t.violations) > 10:
            print(f"  ... and {len(t.violations) - 10} more")
    for name, rejected in report.mutants_rejected.items():
        print(f"mutant {name:28s} {'rejected' if rejected else 'MISSED'}")
    print(f"model-checked instances: {model_checked_count()}",
          file=sys.stderr)
    print("ok" if report.ok else "FAILED", file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
