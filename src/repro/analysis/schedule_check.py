"""Static invariant verifier for transfer DAGs.

:func:`verify_schedule` checks, in one O(V + E) pass over any
:class:`~repro.core.schedule.TransmissionSchedule`, every structural
invariant the engines assume but until now only enforced dynamically
(by sampling: hypothesis properties, benchmark gates):

=================  ==========================================================
rule               invariant
=================  ==========================================================
``dep-bounds``     every dependency index is a valid transfer index
``topo-order``     dependencies reference strictly earlier transfers (the
                   topological-order contract ``dep_levels`` indexes by)
``cycle``          the dependency graph is acyclic (Kahn's algorithm over
                   the in-bounds edges, so it still terminates — and still
                   reports — on schedules with forward references)
``phase-monotone`` builder-recorded phases strictly increase along every
                   dependency edge — the *precondition of the bandwidth-
                   admission theorem* (``event <= barrier`` holds for any
                   schedule whose deps point at strictly earlier phases)
``phase-shape``    ``phase_of`` has one non-negative entry per transfer
``negative-payload``  ``nbytes`` and ``compute_ms`` are finite and >= 0
``node-bounds``    ``src``/``dst``/``via`` lie inside the latency matrix,
                   and a relay is never one of its own endpoints (either
                   would double-count its NIC)
``local-stage``    ``src == dst`` stages (exec/clock) carry no bytes and no
                   relay — the simulator skips their accounting entirely,
                   so a payload here would silently vanish from the wire
``epoch-monotone`` a transfer never depends on a *later* epoch
``epoch-contiguity``  stitched epoch tags cover ``0..max`` with no gaps
                   (``node_commit_ms`` allocates one row per epoch)
``clock-chain``    the cadence ``clock`` stages form one linear chain: at
                   most one per epoch, strictly increasing epochs, each
                   chained to exactly the previous clock
=================  ==========================================================

The verifier is pure — it never mutates the schedule and needs no network
state — so it runs identically on builder outputs, stitched streams and
hand-built test schedules.  ``WANSimulator(verify=True)`` (wired through
``EngineConfig(verify_schedules=True)``) calls it on every schedule before
simulating and raises :class:`ScheduleVerificationError` on any finding;
``tests/test_analysis.py`` sweeps it exhaustively over all builders x
benchmark topologies x stitched streaming schedules.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Any

from .violations import Violation, format_violations

__all__ = [
    "verify_schedule",
    "StreamScheduleVerifier",
    "ScheduleVerificationError",
    "verified_schedule_count",
    "reset_verified_schedule_count",
]

# module-level provenance counter: how many schedules this process has
# verified (benchmarks/run.py records it so results/benchmarks.json shows
# which numbers came from verified DAGs)
_VERIFIED_SCHEDULES = 0


def verified_schedule_count() -> int:
    """Schedules verified (with zero violations) since process start /
    the last reset — the benchmark harness's provenance signal."""
    return _VERIFIED_SCHEDULES


def reset_verified_schedule_count() -> None:
    global _VERIFIED_SCHEDULES
    _VERIFIED_SCHEDULES = 0


class ScheduleVerificationError(ValueError):
    """A schedule failed static verification (``verify_schedules=True``)."""

    def __init__(self, violations: list[Violation], label: str = ""):
        self.violations = violations
        head = f"schedule {label!r} " if label else "schedule "
        super().__init__(
            head + f"failed static verification ({len(violations)} "
            "violation(s)):\n" + format_violations(violations)
        )


def _check_transfer_fields(
    transfers, n_nodes: int | None, out: list[Violation]
) -> None:
    for i, t in enumerate(transfers):
        if not math.isfinite(t.nbytes) or t.nbytes < 0.0:
            out.append(Violation(
                "negative-payload",
                f"nbytes = {t.nbytes!r} must be finite and >= 0", index=i,
            ))
        if not math.isfinite(t.compute_ms) or t.compute_ms < 0.0:
            out.append(Violation(
                "negative-payload",
                f"compute_ms = {t.compute_ms!r} must be finite and >= 0",
                index=i,
            ))
        if n_nodes is not None:
            for field in ("src", "dst"):
                v = getattr(t, field)
                if not 0 <= v < n_nodes:
                    out.append(Violation(
                        "node-bounds",
                        f"{field} = {v} outside [0, {n_nodes})", index=i,
                    ))
            if t.via >= n_nodes:
                out.append(Violation(
                    "node-bounds",
                    f"via = {t.via} outside [0, {n_nodes})", index=i,
                ))
        if t.via >= 0 and t.via in (t.src, t.dst):
            out.append(Violation(
                "node-bounds",
                f"relay via = {t.via} is one of its own endpoints "
                f"({t.src} -> {t.dst}): the relay hop would double-count "
                "that node's NIC", index=i,
            ))
        if t.src == t.dst:
            # local compute stage: the simulator moves no bytes and skips
            # all accounting for it, so payload/relay here silently vanish
            if t.nbytes != 0.0:
                out.append(Violation(
                    "local-stage",
                    f"local stage (src == dst == {t.src}) carries "
                    f"nbytes = {t.nbytes!r}: these bytes would never reach "
                    "the wire or the byte counters", index=i,
                ))
            if t.via >= 0:
                out.append(Violation(
                    "local-stage",
                    f"local stage (src == dst == {t.src}) routes via "
                    f"{t.via}: local stages take no relay", index=i,
                ))


def _check_deps(transfers, out: list[Violation]) -> None:
    """dep-bounds + topo-order (the cycle check runs separately, on the
    in-bounds edge subset, so it still works with dangling references)."""
    m = len(transfers)
    for i, t in enumerate(transfers):
        for d in t.deps:
            if not 0 <= d < m:
                out.append(Violation(
                    "dep-bounds",
                    f"dependency {d} outside [0, {m})", index=i,
                ))
            elif d >= i:
                out.append(Violation(
                    "topo-order",
                    f"dependency {d} does not precede its dependent "
                    "(transfers must be topologically ordered)", index=i,
                ))


def _check_acyclic(transfers, out: list[Violation]) -> None:
    """Kahn's algorithm over the in-bounds dependency edges.  Topological
    order already implies acyclicity, but a mutated/hand-built schedule with
    forward references may still be a DAG — or a genuine cycle; this check
    tells the two apart."""
    m = len(transfers)
    indeg = [0] * m
    children: list[list[int]] = [[] for _ in range(m)]
    for i, t in enumerate(transfers):
        for d in t.deps:
            if 0 <= d < m:
                indeg[i] += 1
                children[d].append(i)
    queue = deque(i for i in range(m) if indeg[i] == 0)
    seen = 0
    while queue:
        i = queue.popleft()
        seen += 1
        for c in children[i]:
            indeg[c] -= 1
            if indeg[c] == 0:
                queue.append(c)
    if seen != m:
        stuck = [i for i in range(m) if indeg[i] > 0]
        out.append(Violation(
            "cycle",
            f"dependency cycle: {m - seen} transfer(s) can never become "
            f"ready (e.g. indices {stuck[:5]})", index=stuck[0],
        ))


def _check_phases(schedule, out: list[Violation]) -> None:
    phase_of = schedule.phase_of
    transfers = schedule.transfers
    if phase_of is None:
        return  # ASAP levels are strictly monotone by construction
    m = len(transfers)
    if len(phase_of) != m:
        out.append(Violation(
            "phase-shape",
            f"phase_of has {len(phase_of)} entries for {m} transfers",
        ))
        return
    for i, p in enumerate(phase_of):
        if p < 0:
            out.append(Violation(
                "phase-shape", f"phase {p} is negative", index=i,
            ))
    for i, t in enumerate(transfers):
        for d in t.deps:
            if 0 <= d < m and phase_of[d] >= phase_of[i]:
                out.append(Violation(
                    "phase-monotone",
                    f"phase {phase_of[i]} depends on transfer {d} of phase "
                    f"{phase_of[d]}: phases must strictly increase along "
                    "dependency edges (the bandwidth-admission theorem's "
                    "precondition)", index=i,
                ))


def _check_epochs(transfers, out: list[Violation]) -> None:
    m = len(transfers)
    seen: set[int] = set()
    for i, t in enumerate(transfers):
        if t.epoch < 0:
            out.append(Violation(
                "epoch-contiguity", f"epoch {t.epoch} is negative", index=i,
            ))
            continue
        seen.add(t.epoch)
        for d in t.deps:
            if 0 <= d < m and transfers[d].epoch > t.epoch:
                out.append(Violation(
                    "epoch-monotone",
                    f"epoch {t.epoch} depends on transfer {d} of later "
                    f"epoch {transfers[d].epoch}", index=i,
                ))
    if seen:
        missing = sorted(set(range(max(seen) + 1)) - seen)
        if missing:
            out.append(Violation(
                "epoch-contiguity",
                f"epoch tags are not contiguous: {missing[:5]} absent "
                f"below max epoch {max(seen)} (node_commit_ms allocates "
                "one row per epoch)",
            ))


def _check_clock_chain(transfers, out: list[Violation]) -> None:
    """Cadence ``clock`` stages must form one linear chain (stitched
    schedules): strictly increasing epochs, at most one per epoch, each
    clock chained to exactly the previous one through its deps."""
    m = len(transfers)
    clocks = [i for i, t in enumerate(transfers) if t.tag == "clock"]
    clock_set = set(clocks)
    prev = -1
    for pos, i in enumerate(clocks):
        t = transfers[i]
        if pos > 0:
            if t.epoch <= transfers[prev].epoch:
                out.append(Violation(
                    "clock-chain",
                    f"clock epochs must strictly increase: epoch {t.epoch} "
                    f"follows clock {prev} of epoch {transfers[prev].epoch}",
                    index=i,
                ))
            clock_deps = [d for d in t.deps if 0 <= d < m and d in clock_set]
            if clock_deps != [prev]:
                out.append(Violation(
                    "clock-chain",
                    f"clock must chain to exactly the previous clock "
                    f"({prev}); found clock deps {clock_deps}", index=i,
                ))
        prev = i


class StreamScheduleVerifier:
    """Incremental (per-epoch) mode of :func:`verify_schedule` for
    appendable stitched streams.

    The one-shot verifier is O(V + E) over the *whole* stream, so calling
    it per appended epoch would reintroduce the O(E²) cost the incremental
    timeline exists to remove.  This verifier carries the cross-epoch
    state instead (epoch counter, clock-chain tail, the previous epoch's
    dependency frontier with its phase ranks) and checks each appended
    segment in O(segment):

    * all one-shot per-transfer rules (payload/compute sanity, node
      bounds, local-stage purity) via the same ``_check_transfer_fields``;
    * ``dep-bounds`` / ``topo-order`` against *global* stream indices
      (which also implies acyclicity — every dependency is strictly
      earlier);
    * ``phase-monotone`` along every edge, external edges resolved through
      the retained frontier ranks;
    * ``stream-frontier`` (incremental-only rule): an external dependency
      must land in the previous epoch's frontier (per-node commit
      transfers, exec stages, clock tail) — anything older has been
      evicted and would make the fold-in of external finish times unsound;
    * ``epoch-contiguity`` (every segment transfer carries the current
      epoch tag — appending is what makes tags contiguous) and
      ``clock-chain`` (at most one clock per segment, chained to exactly
      the retained tail).

    Each clean segment counts toward :func:`verified_schedule_count`, the
    same provenance signal the one-shot verifier feeds.
    """

    def __init__(self, n_nodes: int | None = None):
        self.n_nodes = n_nodes
        self.epoch = 0
        self.size = 0                        # transfers verified so far
        self._prev_clock: int | None = None  # global index of the chain tail
        self._frontier_ranks: dict[int, int] = {}

    def check_epoch(
        self,
        transfers: Any,
        ranks: Any,
        *,
        frontier: Any,
    ) -> list[Violation]:
        """Verify one appended segment (global dep indices, admission
        ranks) and advance the carried state.  ``frontier`` is the global
        index set the *next* epoch may depend on (``StitchState.
        frontier()`` after this append).  Returns all violations found."""
        global _VERIFIED_SCHEDULES
        out: list[Violation] = []
        transfers = list(transfers)
        ranks = list(ranks)
        base = self.size
        hi = base + len(transfers)
        _check_transfer_fields(transfers, self.n_nodes, out)
        if len(ranks) != len(transfers):
            out.append(Violation(
                "phase-shape",
                f"segment has {len(ranks)} ranks for {len(transfers)} "
                "transfers",
            ))
            ranks = ranks + [0] * (len(transfers) - len(ranks))
        known = self._frontier_ranks
        clocks: list[int] = []
        for i, t in enumerate(transfers):
            gi = base + i
            if t.epoch != self.epoch:
                out.append(Violation(
                    "epoch-contiguity",
                    f"segment transfer carries epoch {t.epoch}, appending "
                    f"epoch {self.epoch} (tags are contiguous by "
                    "construction)", index=gi,
                ))
            if t.tag == "clock":
                clocks.append(gi)
            for d in t.deps:
                if not 0 <= d < hi:
                    out.append(Violation(
                        "dep-bounds",
                        f"dependency {d} outside [0, {hi})", index=gi,
                    ))
                    continue
                if d >= gi:
                    out.append(Violation(
                        "topo-order",
                        f"dependency {d} does not precede its dependent "
                        "(stream indices are topologically ordered)",
                        index=gi,
                    ))
                    continue
                if d >= base:
                    dep_rank = ranks[d - base]
                elif d in known:
                    dep_rank = known[d]
                else:
                    out.append(Violation(
                        "stream-frontier",
                        f"external dependency {d} is not in the previous "
                        "epoch's frontier (commit/exec/clock indices): its "
                        "finish time has been evicted", index=gi,
                    ))
                    continue
                if dep_rank >= ranks[i]:
                    out.append(Violation(
                        "phase-monotone",
                        f"phase {ranks[i]} depends on transfer {d} of "
                        f"phase {dep_rank}: phases must strictly increase "
                        "along dependency edges (the bandwidth-admission "
                        "theorem's precondition)", index=gi,
                    ))
        if len(clocks) > 1:
            out.append(Violation(
                "clock-chain",
                f"segment has {len(clocks)} clock stages; stitching emits "
                "at most one per epoch", index=clocks[1],
            ))
        for gi in clocks:
            t = transfers[gi - base]
            want = () if self._prev_clock is None else (self._prev_clock,)
            if tuple(t.deps) != want:
                out.append(Violation(
                    "clock-chain",
                    f"clock must chain to exactly the previous clock "
                    f"(deps {want}); found deps {tuple(t.deps)}", index=gi,
                ))
        if clocks:
            self._prev_clock = clocks[-1]
        # the frontier is always inside the segment just appended (the
        # stitcher rebuilds prev_commit/prev_exec/prev_clock every epoch)
        self._frontier_ranks = {
            g: ranks[g - base] for g in frontier if base <= g < hi
        }
        self.size = hi
        self.epoch += 1
        if not out:
            _VERIFIED_SCHEDULES += 1
        return out


def verify_schedule(
    schedule: Any, *, n_nodes: int | None = None
) -> list[Violation]:
    """Statically verify one transfer DAG.  Returns all violations found
    (empty list = the schedule satisfies every engine invariant).

    ``n_nodes`` (the latency-matrix dimension) enables the src/dst/via
    bounds checks; without it only matrix-independent invariants run.
    Pure and O(V + E): cheap enough to run on every simulated schedule
    behind ``EngineConfig(verify_schedules=True)``.
    """
    global _VERIFIED_SCHEDULES
    out: list[Violation] = []
    transfers = schedule.transfers
    _check_transfer_fields(transfers, n_nodes, out)
    _check_deps(transfers, out)
    _check_acyclic(transfers, out)
    _check_phases(schedule, out)
    _check_epochs(transfers, out)
    _check_clock_chain(transfers, out)
    if not out:
        _VERIFIED_SCHEDULES += 1
    return out
