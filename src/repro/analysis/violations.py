"""Shared diagnostic type for the static-analysis passes.

Every pass in :mod:`repro.analysis` — the schedule verifier, the config
compatibility checker and the determinism lint — reports findings as a flat
``list[Violation]`` so callers (the ``verify_schedules`` debug hook, pytest
assertions, the lint CLI) can format, filter and count them uniformly.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Violation", "format_violations"]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One static-analysis finding.

    ``rule`` is the stable machine-readable rule slug (tests key on it);
    ``message`` the human-readable diagnostic.  Location fields are pass-
    specific: the schedule verifier sets ``index`` (a transfer index), the
    lint sets ``file``/``line``, the config checker sets ``file`` to the
    config class name.
    """

    rule: str
    message: str
    index: int | None = None     # schedule verifier: transfer index
    file: str | None = None      # lint: source path; config: class name
    line: int | None = None      # lint: 1-based source line

    def __str__(self) -> str:
        loc = ""
        if self.file is not None:
            loc = f"{self.file}:{self.line}: " if self.line is not None \
                else f"{self.file}: "
        elif self.index is not None:
            loc = f"transfer {self.index}: "
        return f"{loc}[{self.rule}] {self.message}"


def format_violations(violations: list[Violation]) -> str:
    return "\n".join(str(v) for v in violations)
