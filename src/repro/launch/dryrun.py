"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes with ShapeDtypeStruct inputs (no allocation).

    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b \
        --shape train_4k --mesh multi --strategy hier
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun

Per cell, records memory_analysis, cost_analysis, and the trip-count-aware
HLO cost model (FLOPs / HBM bytes / per-axis collective link bytes) that
feeds EXPERIMENTS.md §Dry-run and §Roofline.  Failures here are bugs in the
sharding config, not in the models.

Tiers: ``--tier full`` forces 512 host devices (the production meshes; too
heavy for CI, opt-in), ``--tier reduced`` forces 16 devices on the same
axis layout — the CI tier.  ``--smoke`` swaps in the reduced model configs
so a reduced-tier cell compiles in seconds.  The device count is pinned via
XLA_FLAGS *before* jax is imported, so this module must not import jax at
module scope.
"""

import argparse
import json
import os
import time
import traceback

TIER_DEVICES = {"full": 512, "reduced": 16}


def _force_devices(tier: str) -> int:
    """Pin the host device count for ``tier``; must run before jax imports."""
    n = TIER_DEVICES[tier]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    return n


def run_cell(arch: str, shape_name: str, mesh_kind: str, strategy: str,
             density: float = 0.10, microbatches: int = 8,
             tier: str = "full", smoke: bool = False) -> dict:
    import jax.numpy as jnp
    from ..configs.base import SHAPES
    from ..configs.registry import get_config, get_smoke_config
    from ..dist.collectives import SyncConfig
    from ..launch.hlo_cost import analyze_hlo
    from ..launch.mesh import make_production_mesh
    from ..train.train_step import (
        TrainConfig,
        abstract_cache,
        abstract_opt_state,
        abstract_params,
        abstract_residuals,
        build_serve_step,
        build_train_step,
        input_specs,
    )

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                reduced=(tier == "reduced"))
    mesh_shape = dict(mesh.shape)

    # lean dtype policy for the very large models (fits the HBM budget)
    lean = cfg.name in ("deepseek-v3-671b", "llama-3.2-vision-90b")
    tcfg = TrainConfig(
        sync=SyncConfig(strategy=strategy, density=density),
        param_dtype=jnp.bfloat16 if lean else jnp.float32,
        microbatches=microbatches if shape.kind == "train" else 1,
    )

    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": mesh_shape, "strategy": strategy, "density": density,
        "tier": tier, "smoke": smoke,
        "kind": shape.kind, "param_dtype": str(tcfg.param_dtype.__name__),
        "microbatches": tcfg.microbatches,
    }
    t0 = time.perf_counter()
    batch = input_specs(cfg, shape)
    if shape.kind == "train":
        make_jit, _ = build_train_step(cfg, mesh, tcfg)
        step = make_jit(batch)
        lowered = step.lower(
            abstract_params(cfg, tcfg.param_dtype),
            abstract_opt_state(cfg, tcfg),
            abstract_residuals(cfg, tcfg),
            batch,
        )
    elif shape.kind == "prefill":
        make_jit, _ = build_serve_step(cfg, mesh, tcfg, kind="prefill")
        step = make_jit(batch)
        lowered = step.lower(abstract_params(cfg, tcfg.param_dtype), batch)
    else:  # decode
        make_jit, _ = build_serve_step(cfg, mesh, tcfg, kind="decode")
        cache = abstract_cache(cfg, shape)
        step = make_jit(cache, batch)
        lowered = step.lower(abstract_params(cfg, tcfg.param_dtype), cache, batch)
    rec["lower_s"] = round(time.perf_counter() - t0, 2)

    t0 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
        # donated args alias outputs; peak live ≈ args + temp
        "peak_gb": (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 1e9,
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # old jax: one dict per computation
        ca = ca[0] if ca else {}
    rec["cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    t0 = time.perf_counter()
    hlo = analyze_hlo(compiled.as_text(), mesh_shape)
    rec["hlo"] = hlo.to_json()
    # compact per-axis summary
    by_axes: dict[str, float] = {}
    for c in hlo.collectives:
        key = "+".join(c["axes"]) or "replica"
        by_axes[key] = by_axes.get(key, 0.0) + c["link_bytes"]
    rec["collective_link_bytes_by_axes"] = by_axes
    rec["analyze_s"] = round(time.perf_counter() - t0, 2)
    return rec


def main():
    from ..configs.base import SHAPES
    from ..configs.registry import ARCHS, cells

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="hier",
                    help="registered device_sync strategy (flat/hier/geococo/"
                         "...); validated against the registry at build time")
    ap.add_argument("--density", type=float, default=0.10)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--tier", default="full", choices=list(TIER_DEVICES),
                    help="full = 512-device production meshes (opt-in, "
                         "heavy); reduced = 16-device CI tier")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced model configs (CI-speed compiles)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    n_dev = _force_devices(args.tier)
    print(f"[tier] {args.tier}: {n_dev} forced host devices"
          + (" (smoke configs)" if args.smoke else ""))

    if args.all:
        todo = cells()
    else:
        if args.arch is None:
            raise SystemExit("need --arch or --all")
        archs = [args.arch]
        todo = [
            (a, s) for a, s in cells(tuple(archs))
            if args.shape is None or s.name == args.shape
        ]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch, shape in todo:
        for mesh_kind in meshes:
            tag = f"{arch}__{shape.name}__{mesh_kind}__{args.strategy}"
            if args.tier != "full":
                tag += f"__{args.tier}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape.name, mesh_kind, args.strategy,
                               args.density, args.microbatches,
                               tier=args.tier, smoke=args.smoke)
                rec["status"] = "ok"
                print(
                    f"    ok: compile {rec['compile_s']}s  "
                    f"peak {rec['memory']['peak_gb']:.1f} GB/dev  "
                    f"flops {rec['hlo']['flops']:.3e}  "
                    f"coll {rec['collective_link_bytes_by_axes']}", flush=True,
                )
            except Exception as e:
                n_fail += 1
                rec = {
                    "arch": arch, "shape": shape.name, "mesh": mesh_kind,
                    "strategy": args.strategy, "status": "fail",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-3000:],
                }
                print(f"    FAIL: {type(e).__name__}: {str(e)[:300]}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
