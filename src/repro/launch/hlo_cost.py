"""Roofline-grade cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which silently
drops ~(n_layers x) of the FLOPs for scan-over-layers models (verified on
this container: a 7-iteration scan of a 2048-FLOP matmul reports 2050
FLOPs).  This parser walks the optimized HLO, multiplies loop bodies by
their ``known_trip_count``, and produces:

* ``flops``        — dot/convolution FLOPs, trip-count aware,
* ``bytes``        — HBM-traffic estimate: operand+output bytes of every
  top-level (unfused) instruction, trip-count aware,
* ``collectives``  — per-op records {op, bytes, axes, count, link_bytes}
  with the mesh axis set inferred from replica groups (supports both
  explicit ``{{0,4},{1,5}}`` and iota ``[4,2]<=[2,2,2]T(0,2,1)`` forms),
  where ``link_bytes`` applies the ring-algorithm factor
  (all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n,
  all-to-all (n-1)/n, collective-permute 1).

All numbers are per device (HLO is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any

import numpy as np

__all__ = ["HLOCost", "analyze_hlo", "classify_groups"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
}

# bytes that traverse a link per device, as a multiple of the shard bytes
def _ring_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return float(n - 1) / n
    if op in ("collective-permute", "collective-broadcast"):
        return 1.0
    return 1.0


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str
    op: str
    args: list[str]
    attrs: str


@dataclasses.dataclass
class HLOCost:
    flops: float
    bytes: float
    collectives: list[dict]
    while_unknown_trip: int = 0

    def collective_bytes(self, axes: frozenset | None = None) -> float:
        """Sum of link-level bytes, optionally restricted to an axis set."""
        out = 0.0
        for c in self.collectives:
            if axes is None or set(c["axes"]) & set(axes):
                out += c["link_bytes"]
        return out

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collectives": self.collectives,
            "while_unknown_trip": self.while_unknown_trip,
        }


# ---------------------------------------------------------------------------
# shape parsing
# ---------------------------------------------------------------------------


def _shape_bytes(shape: str) -> float:
    """Bytes of one HLO shape string (tuples summed)."""
    total = 0.0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape: str) -> list[int]:
    m = re.search(r"\w+\[([\d,]*)\]", shape)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


# ---------------------------------------------------------------------------
# module parsing
# ---------------------------------------------------------------------------


def _split_computations(text: str) -> dict[str, list[str]]:
    """Map computation name -> its instruction lines.

    Header lines look like ``%region_0.2 (arg: (s32[], f32[4,16])) -> ... {``
    (parameter lists contain nested parens, so the name is simply the token
    before the first '(' — no full-signature regex).
    """
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in text.splitlines():
        s = line.strip()
        # signature headers contain '->' (long ENTRY signatures also contain
        # '=' inside /*index=N*/ comments, so '=' cannot be the filter)
        if s.endswith("{") and "->" in s and "(" in s and " = " not in s:
            head = s.split("(", 1)[0].strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.lstrip("%").strip()
            if name:
                cur = name
                comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and "=" in s:
            comps[cur].append(s)
    return comps


def _parse_instruction(line: str) -> Instruction | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    m = re.match(r"%?([\w.\-]+)\s*=\s*(.*)$", s)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # shape: balanced parens for tuples, else token up to first space
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape = rhs[: i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape = rhs[:sp]
        rest = rhs[sp + 1:]
    m2 = re.match(r"([\w\-]+)\(", rest)
    if not m2:
        return None
    op = m2.group(1)
    # balanced-paren arg scan
    start = rest.find("(")
    depth = 0
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    args_str = rest[start + 1: i]
    attrs = rest[i + 1:]
    args = [a.strip() for a in args_str.split(",") if a.strip()]
    return Instruction(name=name, shape=shape, op=op, args=args, attrs=attrs)


def _dot_flops(inst: Instruction, shapes: dict[str, str]) -> float:
    out_elems = 1.0
    for d in _shape_dims(inst.shape):
        out_elems *= d
    lhs = inst.args[0].lstrip("%") if inst.args else ""
    lhs_shape = shapes.get(lhs, "")
    lhs_dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    contract = 1.0
    if m and m.group(1) and lhs_dims:
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contract *= lhs_dims[di]
    return 2.0 * out_elems * contract


def _conv_flops(inst: Instruction, shapes: dict[str, str]) -> float:
    # output elems x 2 x (kernel spatial x in_channels)
    out_elems = 1.0
    for d in _shape_dims(inst.shape):
        out_elems *= d
    rhs = inst.args[1].lstrip("%") if len(inst.args) > 1 else ""
    k_dims = _shape_dims(shapes.get(rhs, ""))
    k = 1.0
    for d in k_dims[:-1]:  # crude: all but output-feature dim
        k *= d
    return 2.0 * out_elems * k


def classify_groups(attrs: str, mesh_shape: dict[str, int]) -> tuple[frozenset, int]:
    """Infer which mesh axes a collective spans from its replica groups.

    Returns (axes, group_size).  Device id layout is row-major over the mesh
    axes in order (e.g. id = ((pod*D)+data)*M + model).
    """
    sizes = list(mesh_shape.values())
    names = list(mesh_shape.keys())
    total = int(np.prod(sizes))

    group0: list[int] | None = None
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        group0 = [int(x) for x in m.group(1).split(",")]
    else:
        m = re.search(
            r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
            attrs,
        )
        if m:
            n_groups, per_group = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            ids = np.arange(int(np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            ids = ids.reshape(n_groups, per_group)
            group0 = ids[0].tolist()
    if not group0:
        return frozenset(), 1
    coords = []
    for dev in group0:
        c = []
        rem = dev
        for s in reversed(sizes):
            c.append(rem % s)
            rem //= s
        coords.append(tuple(reversed(c)))
    coords_arr = np.array(coords)
    axes = frozenset(
        names[i] for i in range(len(names))
        if len(set(coords_arr[:, i].tolist())) > 1
    )
    return axes, len(group0)


# ---------------------------------------------------------------------------
# main walk
# ---------------------------------------------------------------------------

_BYTES_OPS_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def analyze_hlo(text: str, mesh_shape: dict[str, int]) -> HLOCost:
    comps = _split_computations(text)
    parsed: dict[str, list[Instruction]] = {}
    shapes_by_comp: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        insts = []
        shapes: dict[str, str] = {}
        for l in lines:
            inst = _parse_instruction(l)
            if inst is None:
                continue
            insts.append(inst)
            shapes[inst.name] = inst.shape
        parsed[cname] = insts
        shapes_by_comp[cname] = shapes

    # entry = computation whose line had ENTRY; fall back to the largest
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in parsed:
        entry = max(parsed, key=lambda c: len(parsed[c])) if parsed else ""

    collectives: list[dict] = []
    unknown_trips = [0]

    def _sliced_params(cname: str) -> dict[int, float]:
        """Fusion parameters consumed only through dynamic-slice/gather:
        charge the slice size, not the full operand (scan xs indexing)."""
        out: dict[int, float] = {}
        if cname not in parsed:
            return out
        uses: dict[str, list[tuple[str, float]]] = {}
        for inst in parsed[cname]:
            for a in inst.args:
                uses.setdefault(a.lstrip("%"), []).append(
                    (inst.op, _shape_bytes(inst.shape))
                )
        for line in comps.get(cname, []):
            m = re.match(
                r"\s*(?:ROOT )?%?([\w.\-]+) = \S+ parameter\((\d+)\)", line
            )
            if not m:
                continue
            pname, idx = m.group(1), int(m.group(2))
            u = uses.get(pname, [])
            if u and all(op in ("dynamic-slice", "gather") for op, _ in u):
                out[idx] = sum(b for _, b in u)
        return out

    def comp_cost(cname: str, mult: float, seen: tuple = ()) -> tuple[float, float]:
        if cname not in parsed or cname in seen:
            return 0.0, 0.0
        flops = 0.0
        nbytes = 0.0
        shapes = shapes_by_comp[cname]
        for inst in parsed[cname]:
            if inst.op == "dot":
                flops += _dot_flops(inst, shapes)
            elif inst.op == "convolution":
                flops += _conv_flops(inst, shapes)
            if inst.op == "dynamic-slice":
                # reads only the slice (= output), not the sliced operand —
                # counting operands here would charge every scan iteration
                # the full xs array (a ~1000x overcount for long scans)
                nbytes += 2.0 * _shape_bytes(inst.shape)
            elif inst.op == "dynamic-update-slice":
                # reads+writes the update region; the big aliased buffer is
                # untouched outside the window
                upd = inst.args[1].lstrip("%") if len(inst.args) > 1 else ""
                nbytes += 2.0 * _shape_bytes(shapes.get(upd, ""))
            elif inst.op == "gather":
                nbytes += 2.0 * _shape_bytes(inst.shape)
            elif inst.op == "scatter":
                upd = inst.args[-1].lstrip("%") if inst.args else ""
                nbytes += 2.0 * _shape_bytes(shapes.get(upd, ""))
            elif inst.op not in _BYTES_OPS_SKIP and inst.op != "fusion":
                nbytes += _shape_bytes(inst.shape)
                for a in inst.args:
                    nbytes += _shape_bytes(shapes.get(a.lstrip("%"), ""))
            if inst.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                sliced: dict[int, float] = {}
                if m:
                    f_flops, _ = comp_cost(m.group(1), 1.0, seen + (cname,))
                    flops += f_flops
                    sliced = _sliced_params(m.group(1))
                nbytes += _shape_bytes(inst.shape)
                for i, a in enumerate(inst.args):
                    if i in sliced:
                        nbytes += sliced[i]
                    else:
                        nbytes += _shape_bytes(shapes.get(a.lstrip("%"), ""))
            elif inst.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                mt = re.search(r'known_trip_count[":{]+n[":]+(\d+)', inst.attrs)
                trip = int(mt.group(1)) if mt else 1
                if not mt:
                    unknown_trips[0] += 1
                if mb:
                    b_f, b_b = comp_cost(mb.group(1), mult * trip, seen + (cname,))
                    flops += b_f * trip
                    nbytes += b_b * trip
            elif inst.op in ("call", "conditional", "async-start"):
                for m in re.finditer(
                    r"(?:to_apply|branch_computations=\{|calls)=?%?([\w.\-]+)", inst.attrs
                ):
                    c_f, c_b = comp_cost(m.group(1), mult, seen + (cname,))
                    flops += c_f
                    nbytes += c_b
            if inst.op in _COLLECTIVES:
                operand_bytes = sum(
                    _shape_bytes(shapes.get(a.lstrip("%"), "")) for a in inst.args
                )
                out_bytes = _shape_bytes(inst.shape)
                axes, gsize = classify_groups(inst.attrs, mesh_shape)
                # shard bytes: for all-gather the OUTPUT is the full tensor;
                # use max(in, out)/gsize-free convention: link bytes below.
                base = max(operand_bytes, out_bytes)
                link = base * _ring_factor(inst.op, gsize)
                collectives.append({
                    "op": inst.op,
                    "bytes": base * mult,
                    "link_bytes": link * mult,
                    "axes": sorted(axes),
                    "group_size": gsize,
                    "count": mult,
                })
        return flops, nbytes

    flops, nbytes = comp_cost(entry, 1.0)
    return HLOCost(
        flops=flops, bytes=nbytes, collectives=collectives,
        while_unknown_trip=unknown_trips[0],
    )
