"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m repro.launch.roofline --dryrun results/dryrun

Per (arch x shape x mesh) cell, derives the three roofline terms from the
trip-count-aware HLO cost model recorded by the dry-run:

    t_compute    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16 / chip)
    t_memory     = HLO_bytes / HBM_bw                (819 GB/s / chip)
    t_collective = sum_axis link_bytes_axis / link_bw

Intra-pod axes (`data`, `model`) use the 50 GB/s ICI link figure; the `pod`
axis is the DCN boundary and is *also* reported at a clearly-labeled
25 GB/s/host supplementary estimate (DESIGN.md §8).  All HLO quantities are
per device, so no chip-count division is needed.

Also reports MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill) /
2*N_active*B (decode) and the useful-compute ratio, plus the dominant term
and a one-line "what would move it" note.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link (intra-pod: data, model axes)
DCN_BW = 25e9              # B/s per host (inter-pod `pod` axis, supplementary)

__all__ = ["roofline_terms", "model_flops", "build_table", "main"]


def model_flops(arch: str, shape_name: str, mesh_shape: dict) -> float:
    """Analytic useful FLOPs per device per step."""
    from ..configs.base import SHAPES
    from ..configs.registry import get_config
    from ..models.model import active_param_count

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = active_param_count(cfg)
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / chips


def roofline_terms(rec: dict) -> dict:
    hlo = rec["hlo"]
    by_axes = rec["collective_link_bytes_by_axes"]
    t_compute = hlo["flops"] / PEAK_FLOPS
    t_memory = hlo["bytes"] / HBM_BW
    ici_bytes = sum(v for k, v in by_axes.items() if k not in ("pod", "replica"))
    dcn_bytes = by_axes.get("pod", 0.0)
    t_coll_ici = ici_bytes / ICI_BW
    t_coll_dcn_at_ici = dcn_bytes / ICI_BW     # spec convention: one link figure
    t_coll = t_coll_ici + t_coll_dcn_at_ici
    t_coll_dcn_supp = dcn_bytes / DCN_BW       # supplementary DCN estimate
    terms = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_collective_ici_s": t_coll_ici,
        "t_collective_pod_s": t_coll_dcn_at_ici,
        "t_collective_pod_dcn25_s": t_coll_dcn_supp,
    }
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    terms["dominant"] = dom
    bound = max(t_compute, t_memory, t_coll)
    terms["roofline_step_s"] = bound
    terms["compute_fraction_of_bound"] = t_compute / bound if bound else 0.0
    mf = model_flops(rec["arch"], rec["shape"], rec["mesh_shape"])
    terms["model_flops"] = mf
    terms["useful_ratio"] = mf / hlo["flops"] if hlo["flops"] else 0.0
    # MFU at the roofline bound (what perfect overlap would achieve)
    terms["roofline_mfu"] = mf / (bound * PEAK_FLOPS) if bound else 0.0
    return terms


_NOTES = {
    "compute": "compute-bound: raise MXU utilization (tiling/fusion) or shrink redundant recompute (remat policy)",
    "memory": "HBM-bound: fuse elementwise chains, cut activation precision, reduce remat re-reads",
    "collective": "collective-bound: reshard to shrink the dominant axis traffic (TP block size, FSDP prefetch overlap, filtered/compact exchange)",
}


def build_table(dryrun_dir: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            rows.append({
                "arch": rec.get("arch"), "shape": rec.get("shape"),
                "mesh": rec.get("mesh"), "strategy": rec.get("strategy"),
                "status": "fail", "error": rec.get("error", "")[:200],
            })
            continue
        terms = roofline_terms(rec)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "strategy": rec["strategy"], "status": "ok",
            "peak_gb": rec["memory"]["peak_gb"],
            **{k: v for k, v in terms.items()},
            "note": _NOTES[terms["dominant"]],
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--mesh", default="single",
                    help="mesh for the main table (spec: single-pod)")
    args = ap.parse_args()
    rows = build_table(args.dryrun)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    sel = [r for r in rows if r.get("mesh") == args.mesh and r["status"] == "ok"]
    hdr = (f"{'arch':24s} {'shape':12s} {'strat':8s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'dom':>6s} {'MFU@roof':>8s} "
           f"{'useful':>7s} {'peakGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sel:
        print(
            f"{r['arch']:24s} {r['shape']:12s} {r['strategy']:8s} "
            f"{r['t_compute_s']:9.3f} {r['t_memory_s']:9.3f} "
            f"{r['t_collective_s']:9.3f} {r['dominant'][:6]:>6s} "
            f"{r['roofline_mfu']:8.1%} {r['useful_ratio']:7.2f} "
            f"{r['peak_gb']:7.1f}"
        )
    fails = [r for r in rows if r["status"] != "ok"]
    if fails:
        print(f"\n{len(fails)} failed cells:")
        for r in fails:
            print(f"  {r['arch']} {r['shape']} {r['mesh']}: {r['error'][:120]}")
    print(f"\nfull table -> {args.out}")


if __name__ == "__main__":
    main()
