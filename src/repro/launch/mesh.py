"""Production mesh construction.

The pod axis is the WAN-like (DCN) boundary GeoCoCo's communicator owns;
`data` x `model` is one pod's ICI torus.  Defined as functions (never
module-level constants) so importing this module touches no jax device
state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_small_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_small_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Reduced mesh for CPU integration tests (8 host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
