"""Production mesh construction.

The pod axis is the WAN-like (DCN) boundary GeoCoCo's communicator owns;
`data` x `model` is one pod's ICI torus.  Defined as functions (never
module-level constants) so importing this module touches no jax device
state.  Meshes are built through ``repro.dist.compat`` so the same call
works on the modern axis-typed API and on the 0.4.x toolchain.
"""

from __future__ import annotations

from ..dist import compat

__all__ = ["make_production_mesh", "make_small_mesh"]


def make_production_mesh(*, multi_pod: bool = False, reduced: bool = False):
    """Production mesh (512 devices), or the ``reduced`` 16-device tier —
    the same axis layout scaled down so the dry-run compiles in CI."""
    if reduced:
        shape = (2, 2, 4) if multi_pod else (4, 4)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_small_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Reduced mesh for CPU integration tests (8 host devices)."""
    return compat.make_mesh(shape, axes)
