"""Training entry point.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.train --arch minitron-8b --smoke \
        --mesh 2,2,2 --sync geococo --steps 100

On real hardware the same entry point runs the full configs; on this CPU
container use --smoke (reduced config) with a forced device count.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="2,2,2",
                    help="pod,data,model sizes (product = device count)")
    ap.add_argument("--sync", default="hier",
                    help="registered device_sync strategy (flat/hier/geococo/"
                         "...); validated against the registry once jax is up")
    ap.add_argument("--density", type=float, default=0.10)
    ap.add_argument("--control", action="store_true",
                    help="attach a repro.control ControlPlane: a monitored "
                         "inter-pod latency trace drives relay_psum ring "
                         "order + replans through typed network events")
    ap.add_argument("--control-noise", type=float, default=0.10,
                    help="probe noise sigma for the monitored view")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import os

    shape = tuple(int(x) for x in args.mesh.split(","))
    n_dev = 1
    for s in shape:
        n_dev *= s
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}"
    )

    import jax

    from ..configs.registry import get_config, get_smoke_config
    from ..data.pipeline import DataConfig
    from ..dist.collectives import SyncConfig
    from ..launch.mesh import make_small_mesh
    from ..optim.adamw import AdamWConfig
    from ..train.train_step import TrainConfig
    from ..train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = make_small_mesh(shape, axes)
    tcfg = TrainConfig(
        sync=SyncConfig(strategy=args.sync, density=args.density,
                        chunk=2048, min_leaf_size=4096),
        optim=AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5)),
    )
    run_cfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, seed=args.seed,
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
    )
    control = None
    n_pods = dict(mesh.shape).get("pod", 1)
    if args.control and n_pods > 1:
        import numpy as np

        from ..control import ControlPlane, MonitorView, TraceView
        from ..core.latency import aws_latency_matrix, jitter_trace

        # inter-pod WAN: the first n_pods AWS-style regions under jitter,
        # observed through full-mesh EWMA probing (not ground truth)
        base = aws_latency_matrix()[:n_pods, :n_pods]
        trace = jitter_trace(base, max(args.steps, 2),
                             np.random.default_rng(args.seed))
        view = MonitorView(TraceView(trace), noise=args.control_noise,
                           rng=np.random.default_rng(args.seed + 1))
        control = ControlPlane(view)
    trainer = Trainer(cfg, mesh, tcfg, run_cfg, data_cfg, control=control)
    if trainer.maybe_resume():
        print(f"resumed from step {trainer.step_idx}")
    hist = trainer.run()
    print(
        f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
        f"over {len(hist)} steps"
    )
    if control is not None:
        print(
            f"control plane: {control.round} rounds, "
            f"{control.replan_count} replans, relay order "
            f"{control.relay_order}, events {control.event_counts()}, "
            f"probe traffic {control.probe_bytes} B; "
            f"step rebuilds {trainer.sync_rebuilds}"
        )


if __name__ == "__main__":
    main()
