"""The network control plane: telemetry -> damped replan -> typed events.

One :class:`ControlPlane` instance feeds *both* synchronization planes
(paper Sec 4.2 "Delay Monitoring" + "Re-group damping"):

* the **WAN plane** (``repro.core.replication.GeoCluster``) observes
  :class:`~repro.control.events.PlanChanged` to route write-set rounds over
  the new grouping, and
* the **device plane** (``repro.train.trainer.Trainer``) observes
  :class:`~repro.control.events.RelayOrderChanged` to recompute
  ``relay_psum``'s ring order and rebuild its jitted step.

Event flow::

    NetworkView.sample()         probe traffic, EWMA / Vivaldi estimate
        -> link detector         sustained per-link deviation (damped)
        -> damped Replanner      regroup only on sustained matrix deviation
        -> relay-order search    TIV-effective bottleneck ring
        -> emit(events)          every subscriber, both planes

**Replan timing contract**: :meth:`ControlPlane.force_replan` replans
*immediately* against the most recent observation and emits events before
returning — unlike the bare :meth:`repro.core.planner.Replanner.force`
without a matrix, which only takes effect at the next ``observe()``.  Event
signals (a trainer straggler trip, a node failure) therefore never wait a
round for the plan to react.
"""

from __future__ import annotations

from typing import Callable, Iterable

import numpy as np

from ..core.latency import one_relay_effective
from ..core.planner import GroupPlan, Replanner, best_plan
from .events import (
    LinkDegraded,
    LinkRecovered,
    NetworkEvent,
    PlanChanged,
    RelayOrderChanged,
)
from .network import NetworkView, as_view

__all__ = ["ControlPlane", "relay_ring_order", "ring_cost"]


# ---------------------------------------------------------------------------
# TIV relay-order search
# ---------------------------------------------------------------------------


def _canonical_ring(order: list[int]) -> tuple[int, ...]:
    """Rotation/reflection-normalize a ring: start at the smallest node id,
    walk toward its smaller neighbor.  Equivalent rings map to one tuple."""
    n = len(order)
    if n <= 2:
        return tuple(sorted(order))
    s = order.index(min(order))
    rot = order[s:] + order[:s]
    if rot[1] > rot[-1]:
        rot = [rot[0]] + rot[1:][::-1]
    return tuple(rot)


def ring_cost(lat: np.ndarray, order: Iterable[int]) -> tuple[float, float]:
    """(max link, sum of links) of a relay ring on a latency matrix.

    The ring all-reduce proceeds in lockstep, so its per-step time is the
    slowest hop — minimize the max first (the paper's bottleneck objective),
    sum as tie-break.
    """
    o = list(order)
    n = len(o)
    hops = [float(lat[o[i], o[(i + 1) % n]]) for i in range(n)]
    return (max(hops), sum(hops)) if hops else (0.0, 0.0)


def _two_opt(
    eff: np.ndarray,
    order: list[int],
    *,
    touched: set[int] | None = None,
    on_eval: Callable[[], None] | None = None,
) -> list[int]:
    """2-opt refinement on the (max, sum) ring objective.

    ``touched`` restricts the neighborhood to moves whose removed/created
    ring edges involve one of the given nodes (the per-edge incremental
    path); ``None`` sweeps the full neighborhood.  ``on_eval`` is called
    once per candidate evaluated (search-cost accounting).
    """
    n = len(order)
    best_cost = ring_cost(eff, order)
    improved = True
    while improved:
        improved = False
        for a in range(n - 1):
            for b in range(a + 2, n):
                if a == 0 and b == n - 1:
                    continue  # reversing the whole ring is a no-op
                if touched is not None:
                    ends = {order[a], order[a + 1],
                            order[b], order[(b + 1) % n]}
                    if not (ends & touched):
                        continue  # move doesn't touch a signalled edge
                if on_eval is not None:
                    on_eval()
                cand = (order[: a + 1] + order[a + 1: b + 1][::-1]
                        + order[b + 1:])
                c = ring_cost(eff, cand)
                if c < best_cost:
                    order, best_cost = cand, c
                    improved = True
    return order


def _ring_metric(lat: np.ndarray, *, tiv: bool, tiv_margin: float) -> np.ndarray:
    """The symmetric hop-cost matrix the ring searches score against."""
    eff = lat
    if tiv:
        eff, _ = one_relay_effective(lat, margin=tiv_margin)
    return np.maximum(eff, eff.T)


def relay_ring_order(
    lat: np.ndarray, *, tiv: bool = False, tiv_margin: float = 0.05
) -> tuple[int, ...]:
    """Relay ring for ``relay_psum`` from a measured latency matrix.

    Searches a ring minimizing (max hop, sum of hops) — greedy
    nearest-neighbor seeded, 2-opt refined.  The ring itself is the TIV
    exploitation here: a pair whose direct link is congested simply never
    becomes ring-adjacent, traffic between them flows through the
    intermediate ring hops.

    ``tiv=False`` (default) scores hops on *direct* latencies — what
    ``relay_psum``'s ``ppermute`` actually executes.  Pass ``tiv=True``
    only for deployments whose ring hops really ride overlay relays
    (``one_relay_effective``); scoring relay-discounted hops while
    executing direct sends would place a relay-only-cheap pair adjacent
    and hand the ring its worst direct link as the bottleneck.

    The result is canonical (see :func:`_canonical_ring`), so equivalent
    rings compare equal and never fire spurious :class:`RelayOrderChanged`
    events.
    """
    n = lat.shape[0]
    if n <= 2:
        return tuple(range(n))
    eff = _ring_metric(lat, tiv=tiv, tiv_margin=tiv_margin)

    # greedy nearest-neighbor seed
    order = [0]
    left = set(range(1, n))
    while left:
        cur = order[-1]
        nxt = min(left, key=lambda j: (eff[cur, j], j))
        order.append(nxt)
        left.remove(nxt)

    return _canonical_ring(_two_opt(eff, order))


# ---------------------------------------------------------------------------
# ControlPlane
# ---------------------------------------------------------------------------


class ControlPlane:
    """Event-driven replanning over a :class:`NetworkView`.

    Parameters
    ----------
    view:
        Latency source for :meth:`step` (pull mode).  ``None`` is allowed:
        a driver (e.g. the replication engine iterating a trace) then pushes
        matrices through :meth:`observe` and the plane is purely reactive.
    plan_fn:
        ``fn(lat) -> GroupPlan``.  ``None`` installs a default
        :func:`~repro.core.planner.best_plan` search; a consumer with better
        context (the engine's bandwidth/payload-aware ranking) may
        :meth:`bind_planner` over the default exactly once.
    replan_threshold / replan_sustain:
        The damped Replanner's sustained-deviation policy (Sec 4.2).
    degrade_factor / recover_factor / degrade_sustain / link_alpha:
        Per-link detector: a link is degraded after ``degrade_sustain``
        consecutive samples above ``degrade_factor`` x its EWMA baseline,
        recovered after the same number below ``recover_factor`` x baseline.
        The baseline freezes while a link is degraded (otherwise it would
        chase the spike and self-"recover").
    tiv / ring_tiv:
        ``tiv`` governs the *plan* search (the WAN plane's inter-aggregator
        hops ride overlay relays, Sec 5).  ``ring_tiv`` governs the relay
        *ring* search and defaults to False because ``relay_psum`` executes
        direct hops — see :func:`relay_ring_order`.
    rank_payload_bytes / rank_bandwidth_mbps / barrier / rank_streaming:
        Replan-scoring context for the built-in default planner.  With a
        payload estimate, candidate plans are ranked by the simulated round
        makespan — the event-driven transfer-DAG critical path by default
        (``barrier=True`` scores the legacy phase-sum), so replans reward
        grouping that overlaps gather/exchange/scatter stages.
        ``rank_streaming=True`` scores two *stitched* epochs instead of one
        isolated round, so replans additionally reward cross-epoch
        pipelining (epoch e+1 gathers streaming under epoch e scatters) —
        the ranking a streaming replication engine executes.  Consumers
        with live context (the replication engine's payload-EWMA planner)
        still override via :meth:`bind_planner`.
    """

    def __init__(
        self,
        view: NetworkView | np.ndarray | None = None,
        *,
        plan_fn: Callable[[np.ndarray], GroupPlan] | None = None,
        replan_threshold: float = 0.20,
        replan_sustain: int = 3,
        degrade_factor: float = 1.5,
        recover_factor: float = 1.15,
        degrade_sustain: int = 3,
        link_alpha: float = 0.2,
        tiv: bool = True,
        ring_tiv: bool = False,
        tiv_margin: float = 0.05,
        planner: str = "kcenter",
        planner_time_limit_s: float = 5.0,
        rank_payload_bytes: float | None = None,
        rank_bandwidth_mbps: float | np.ndarray | None = None,
        barrier: bool = False,
        rank_streaming: bool = False,
    ):
        if rank_streaming and barrier:
            # fail at construction, not mid-run at the first replan
            raise ValueError(
                "rank_streaming=True scores the event engine; barrier=True "
                "has no cross-epoch semantics"
            )
        self.view = as_view(view) if view is not None else None
        self.tiv = tiv
        self.ring_tiv = ring_tiv
        self.tiv_margin = tiv_margin
        self._default_planner = plan_fn is None
        if plan_fn is None:
            plan_fn = lambda lat: best_plan(  # noqa: E731
                lat, tiv=tiv, tiv_margin=tiv_margin, method=planner,
                time_limit_s=planner_time_limit_s,
                payload_bytes=rank_payload_bytes,
                bandwidth_mbps=rank_bandwidth_mbps,
                barrier=barrier,
                streaming=rank_streaming,
            )
        self.replanner = Replanner(
            plan_fn, threshold=replan_threshold, sustain=replan_sustain
        )
        self.degrade_factor = degrade_factor
        self.recover_factor = recover_factor
        self.degrade_sustain = degrade_sustain
        self.link_alpha = link_alpha

        self._subs: list[tuple[Callable[[NetworkEvent], None], tuple | None]] = []
        self._round = 0
        self._last_lat: np.ndarray | None = None
        self._relay_order: tuple[int, ...] | None = None
        self._base: np.ndarray | None = None
        self._over = self._under = None
        self._degraded = None
        self.events: list[NetworkEvent] = []
        # relay-order search accounting: full recomputes vs per-edge
        # incremental refinements, and 2-opt candidate evaluations on the
        # incremental path (the scaling metric past ~64 pods)
        self.relay_full_searches = 0
        self.relay_incremental_searches = 0
        self.relay_incremental_evals = 0

    # -- planner binding --------------------------------------------------------

    def bind_planner(
        self, plan_fn: Callable[[np.ndarray], GroupPlan], *, override: bool = False
    ) -> bool:
        """Install a consumer's plan function over the built-in default.

        Returns True when installed.  A non-default planner (explicit
        ``plan_fn`` at construction, or a previous bind) is kept unless
        ``override=True`` — so on a shared plane, the first engine's
        payload-aware planner wins and later consumers just subscribe.
        """
        if self._default_planner or override:
            self.replanner.plan_fn = plan_fn
            self._default_planner = False
            return True
        return False

    # -- subscriptions ----------------------------------------------------------

    def subscribe(
        self,
        fn: Callable[[NetworkEvent], None],
        *,
        events: tuple[type, ...] | None = None,
    ) -> Callable[[NetworkEvent], None]:
        """Register ``fn`` for all events (or only the given event types)."""
        self._subs.append((fn, events))
        return fn

    def unsubscribe(self, fn: Callable[[NetworkEvent], None]) -> None:
        self._subs = [(f, ev) for f, ev in self._subs if f is not fn]

    def _emit(self, event: NetworkEvent) -> None:
        self.events.append(event)
        for fn, types in list(self._subs):
            if types is None or isinstance(event, types):
                fn(event)

    # -- state ------------------------------------------------------------------

    @property
    def plan(self) -> GroupPlan | None:
        return self.replanner.plan

    @property
    def relay_order(self) -> tuple[int, ...] | None:
        return self._relay_order

    @property
    def round(self) -> int:
        return self._round

    @property
    def replan_count(self) -> int:
        return self.replanner.replan_count

    @property
    def last_latency(self) -> np.ndarray | None:
        return None if self._last_lat is None else self._last_lat.copy()

    @property
    def probe_bytes(self) -> int:
        return 0 if self.view is None else self.view.probe_bytes

    def event_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[type(e).__name__] = out.get(type(e).__name__, 0) + 1
        return out

    # -- link detector ----------------------------------------------------------

    def _detect_links(self, lat: np.ndarray) -> list[NetworkEvent]:
        if self._base is None:
            n = lat.shape[0]
            self._base = lat.copy()
            self._over = np.zeros((n, n), dtype=int)
            self._under = np.zeros((n, n), dtype=int)
            self._degraded = np.zeros((n, n), dtype=bool)
            return []
        base = np.where(self._base > 0, self._base, np.inf)
        over = lat > self.degrade_factor * base
        under = lat <= self.recover_factor * np.where(np.isinf(base), 0.0, base)
        self._over = np.where(over, self._over + 1, 0)
        self._under = np.where(under, self._under + 1, 0)
        newly_deg = ~self._degraded & (self._over >= self.degrade_sustain)
        newly_rec = self._degraded & (self._under >= self.degrade_sustain)
        fired: list[NetworkEvent] = []
        for cls, mask in ((LinkDegraded, newly_deg), (LinkRecovered, newly_rec)):
            for i, j in zip(*np.where(np.triu(mask, k=1))):
                fired.append(cls(
                    round=self._round, i=int(i), j=int(j),
                    baseline_ms=float(self._base[i, j]),
                    observed_ms=float(lat[i, j]),
                ))
        self._degraded |= newly_deg
        self._degraded &= ~newly_rec
        # EWMA baseline tracks only healthy links
        a = self.link_alpha
        track = ~self._degraded
        self._base = np.where(track, (1 - a) * self._base + a * lat, self._base)
        return fired

    # -- the control round ------------------------------------------------------

    def step(self) -> GroupPlan:
        """Pull mode: sample the view once and process the round."""
        if self.view is None:
            raise RuntimeError(
                "ControlPlane has no NetworkView; push matrices via observe()"
            )
        return self.observe(self.view.sample())

    def observe(self, lat: np.ndarray) -> GroupPlan:
        """Push mode: process one measured/estimated latency matrix.

        Runs the damped link detector and Replanner, updates the relay
        order when a sustained signal fired, and emits events *before*
        returning the (possibly updated) plan — so by the time the WAN
        plane schedules its round, the device plane has already seen the
        same events.
        """
        self._round += 1
        lat = np.asarray(lat, dtype=float)
        self._last_lat = lat.copy()
        link_events = self._detect_links(lat)
        prev_plan = self.replanner.plan
        plan = self.replanner.observe(lat)
        plan_changed = plan is not prev_plan
        for ev in link_events:
            self._emit(ev)
        if plan_changed:
            self._emit(PlanChanged(
                round=self._round, plan=plan, previous=prev_plan,
                reason="initial" if prev_plan is None else "sustained-deviation",
            ))
        # relay order follows the same damping: recompute only on a
        # sustained signal (replan or link transition), never on raw jitter.
        # A plan change (or a missing ring) triggers the full search; a
        # link-only signal takes the per-edge incremental path — only 2-opt
        # moves whose ring edges touch the degraded/recovered endpoints are
        # re-evaluated, so the search cost scales with the signal, not n^2.
        if plan_changed or self._relay_order is None:
            self._update_relay_order(lat, reason=(
                "plan-changed" if plan_changed else "link-event"
            ))
        elif link_events:
            self._incremental_relay_update(lat, link_events, reason="link-event")
        return plan

    def _set_relay_order(self, order: tuple[int, ...], *, reason: str) -> None:
        if order != self._relay_order:
            prev = self._relay_order
            self._relay_order = order
            self._emit(RelayOrderChanged(
                round=self._round, order=order, previous=prev, reason=reason,
            ))

    def _update_relay_order(self, lat: np.ndarray, *, reason: str) -> None:
        self.relay_full_searches += 1
        order = relay_ring_order(
            lat, tiv=self.ring_tiv, tiv_margin=self.tiv_margin
        )
        self._set_relay_order(order, reason=reason)

    def _incremental_relay_update(
        self, lat: np.ndarray, link_events: Iterable[NetworkEvent], *,
        reason: str,
    ) -> None:
        """Per-edge incremental 2-opt: refine the current ring against the
        fresh matrix, evaluating only moves whose removed/created ring edges
        touch an endpoint of a degraded or recovered link.  The damping
        contract is unchanged — this still fires only on sustained link
        transitions — but the ring is repaired locally instead of re-planned
        globally."""
        self.relay_incremental_searches += 1
        order = list(self._relay_order)
        if len(order) <= 3:  # every 3-node ring is equivalent; nothing to repair
            return
        touched = {e.i for e in link_events} | {e.j for e in link_events}
        eff = _ring_metric(lat, tiv=self.ring_tiv, tiv_margin=self.tiv_margin)

        def count():
            self.relay_incremental_evals += 1

        order = _two_opt(eff, order, touched=touched, on_eval=count)
        self._set_relay_order(_canonical_ring(order), reason=reason)

    # -- forced transitions -----------------------------------------------------

    def force_replan(self, *, reason: str = "forced") -> GroupPlan | None:
        """Replan *immediately* against the latest observation.

        This is the event-driven path (straggler trips, operator action):
        the plan and relay order update now, and events fire before this
        returns — not at the next ``observe()``.  With no observation yet,
        samples the view once when available, otherwise returns None (there
        is nothing to plan against).
        """
        if self._last_lat is None:
            if self.view is None:
                return None
            self._round += 1
            self._last_lat = self.view.sample()
        prev = self.replanner.plan
        plan = self.replanner.force(self._last_lat)
        self._emit(PlanChanged(
            round=self._round, plan=plan, previous=prev, reason=reason,
        ))
        self._update_relay_order(self._last_lat, reason=reason)
        return plan

    def on_node_failure(self, node: int) -> GroupPlan | None:
        """Failover (Sec 4.4): drop the node from the current plan *now* and
        emit the degraded plan; the full regroup happens at the next
        observation (when a matrix reflecting the failure arrives), per the
        Replanner's documented force contract."""
        prev = self.replanner.plan
        plan = self.replanner.on_node_failure(node)
        if plan is None:
            return None
        self._emit(PlanChanged(
            round=self._round, plan=plan, previous=prev,
            reason=f"node-failure:{node}",
        ))
        return plan
