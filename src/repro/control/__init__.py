"""``repro.control`` — the network control plane feeding both sync planes.

The paper's claim is that *adapting* grouping and relay routing to real-time
network conditions (Sec 4.2 delay monitoring, re-group damping, TIV relays)
is what unlocks the WAN-cost reduction.  This package is that adaptation
layer as one event-driven API:

* :class:`~repro.control.network.NetworkView` — one ``sample()/estimate()``
  interface over ground-truth traces (:class:`TraceView`), full-mesh EWMA
  probing (:class:`MonitorView`) and Vivaldi coordinates
  (:class:`VivaldiView`), with probe-cost accounting;
* :class:`~repro.control.plane.ControlPlane` — owns the damped
  :class:`~repro.core.planner.Replanner` and the TIV relay-order search,
  and emits typed :class:`~repro.control.events.NetworkEvent`\\ s;
* both planes subscribe: ``GeoCluster`` (WAN write sets) reacts to
  :class:`PlanChanged`, ``Trainer`` (device-plane gradients) reacts to
  :class:`RelayOrderChanged` through each ``device_sync`` strategy's
  declared reaction in the shared registry.
"""

from .events import (
    LinkDegraded,
    LinkRecovered,
    NetworkEvent,
    PlanChanged,
    RelayOrderChanged,
)
from .network import MonitorView, NetworkView, TraceView, VivaldiView, as_view
from .plane import ControlPlane, relay_ring_order, ring_cost

__all__ = [
    "NetworkEvent",
    "LinkDegraded",
    "LinkRecovered",
    "PlanChanged",
    "RelayOrderChanged",
    "NetworkView",
    "TraceView",
    "MonitorView",
    "VivaldiView",
    "as_view",
    "ControlPlane",
    "relay_ring_order",
    "ring_cost",
]
