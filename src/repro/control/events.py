"""Typed network-control events (paper Sec 4.2 "Delay Monitoring" + damping).

The :class:`~repro.control.plane.ControlPlane` turns raw latency samples into
a small vocabulary of events that *both* synchronization planes consume:

* :class:`LinkDegraded` / :class:`LinkRecovered` — a single link's sustained
  departure from (return to) its EWMA baseline.  Transient RTT noise never
  fires these: the detector requires ``sustain`` consecutive over-threshold
  samples, the same damping policy as the replanner.
* :class:`PlanChanged` — the damped Replanner produced a new
  :class:`~repro.core.planner.GroupPlan` (sustained deviation, node failure,
  or a forced replan from e.g. the trainer's straggler signal).
* :class:`RelayOrderChanged` — the TIV relay-order search produced a new
  relay ring; the device plane maps this onto ``relay_psum``'s ``order``.

Events are frozen dataclasses: subscribers may hold them, compare them, and
(in tests) assert both planes received the *same instance* from one
ControlPlane.
"""

from __future__ import annotations

import dataclasses

from ..core.planner import GroupPlan

__all__ = [
    "NetworkEvent",
    "LinkDegraded",
    "LinkRecovered",
    "PlanChanged",
    "RelayOrderChanged",
]


@dataclasses.dataclass(frozen=True, kw_only=True)
class NetworkEvent:
    """Base class for all control-plane events.

    ``round`` is the ControlPlane's observation counter at emission time;
    ``reason`` carries the trigger ("sustained-deviation", "node-failure",
    "straggler@step12", ...).
    """

    round: int
    reason: str = ""


@dataclasses.dataclass(frozen=True, kw_only=True)
class LinkDegraded(NetworkEvent):
    """Link (i, j) exceeded ``degrade_factor`` x its EWMA baseline for
    ``degrade_sustain`` consecutive samples."""

    i: int
    j: int
    baseline_ms: float
    observed_ms: float


@dataclasses.dataclass(frozen=True, kw_only=True)
class LinkRecovered(NetworkEvent):
    """A previously-degraded link returned under ``recover_factor`` x its
    baseline for ``degrade_sustain`` consecutive samples."""

    i: int
    j: int
    baseline_ms: float
    observed_ms: float


@dataclasses.dataclass(frozen=True, kw_only=True)
class PlanChanged(NetworkEvent):
    """The damped Replanner installed a new grouping plan."""

    plan: GroupPlan
    previous: GroupPlan | None = None


@dataclasses.dataclass(frozen=True, kw_only=True)
class RelayOrderChanged(NetworkEvent):
    """The TIV relay-order search produced a new relay ring.

    ``order`` is canonical (rotation/reflection-normalized), so two
    equivalent rings never produce a spurious event.
    """

    order: tuple[int, ...]
    previous: tuple[int, ...] | None = None
