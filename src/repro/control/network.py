"""NetworkView: one estimation interface over every latency source.

The paper's delay-monitoring machinery exists at three fidelity/cost points —
ground-truth traces (simulation), full-mesh EWMA probing
(:class:`~repro.core.monitor.LatencyMonitor`), and Vivaldi network
coordinates (:class:`~repro.core.monitor.VivaldiSystem`, Sec 5's >=
hundreds-of-nodes regime).  :class:`NetworkView` unifies them behind one
``sample()/estimate()`` contract with probe-cost accounting, so the
ControlPlane, the benchmarks, and the replication engine never care which
regime produced the matrix:

* ``sample()`` advances time one control round (pays probe traffic) and
  returns a fresh estimate;
* ``estimate()`` returns the current estimate without probing;
* ``probe_bytes`` is the cumulative monitoring cost (Sec 6.4 "Cost of Delay
  Monitoring") — exactly 0 for ground-truth playback.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from ..core.latency import LatencyTrace
from ..core.monitor import LatencyMonitor, VivaldiConfig, VivaldiSystem

__all__ = [
    "NetworkView",
    "TraceView",
    "MonitorView",
    "VivaldiView",
    "as_view",
]


@runtime_checkable
class NetworkView(Protocol):
    """Protocol every latency source implements."""

    n: int

    def sample(self) -> np.ndarray:
        """Advance one control round (probing as needed); return the fresh
        (n, n) latency estimate in ms."""
        ...

    def estimate(self) -> np.ndarray:
        """Current (n, n) estimate without new probes."""
        ...

    @property
    def probe_bytes(self) -> int:
        """Cumulative monitoring traffic in bytes."""
        ...


class TraceView:
    """Ground-truth trace playback (the simulator's oracle view).

    Accepts a :class:`~repro.core.latency.LatencyTrace`, a (t, n, n) frame
    stack, a single static (n, n) matrix, or a sequence of matrices.  By
    default the trace loops; with ``loop=False`` the final frame repeats.
    Probe cost is zero: this is the view the WAN simulator already paid for.
    """

    def __init__(
        self,
        frames: LatencyTrace | np.ndarray | Sequence[np.ndarray],
        *,
        loop: bool = True,
    ):
        if isinstance(frames, LatencyTrace):
            stack = np.asarray(frames.frames, dtype=float)
        else:
            stack = np.asarray(frames, dtype=float)
            if stack.ndim == 2:
                stack = stack[None]
        if stack.ndim != 3 or stack.shape[1] != stack.shape[2]:
            raise ValueError(f"need (t, n, n) frames, got {stack.shape}")
        self._frames = stack
        self._loop = loop
        self._idx = -1  # sample() advances to 0 first
        self.n = int(stack.shape[1])

    @property
    def rounds(self) -> int:
        return int(self._frames.shape[0])

    def sample(self) -> np.ndarray:
        if self._loop:
            self._idx = (self._idx + 1) % self.rounds
        else:
            self._idx = min(self._idx + 1, self.rounds - 1)
        return self._frames[self._idx].copy()

    def estimate(self) -> np.ndarray:
        return self._frames[max(self._idx, 0)].copy()

    @property
    def probe_bytes(self) -> int:
        return 0


def as_view(source) -> NetworkView:
    """Coerce a matrix / trace / view into a :class:`NetworkView`."""
    if isinstance(source, (LatencyTrace, np.ndarray, list, tuple)):
        return TraceView(source)
    if isinstance(source, NetworkView):
        return source
    raise TypeError(f"cannot interpret {type(source).__name__} as a NetworkView")


class MonitorView:
    """Full-mesh EWMA probing against a truth source.

    Each ``sample()`` advances the underlying truth one round and runs one
    full-mesh probing round through a :class:`LatencyMonitor` (optionally
    with multiplicative log-normal probe noise).  The estimate is the
    monitor's EWMA matrix — symmetric with zero diagonal whenever the truth
    is; probe traffic is ``n*(n-1)`` probes per round, accounted exactly.
    """

    def __init__(
        self,
        truth,
        *,
        alpha: float = 0.3,
        noise: float = 0.0,
        rng: np.random.Generator | None = None,
        monitor: LatencyMonitor | None = None,
    ):
        self._truth = as_view(truth)
        self.n = self._truth.n
        self.noise = noise
        self._rng = rng or np.random.default_rng(0)
        self.monitor = monitor or LatencyMonitor(self.n, alpha=alpha)
        if self.monitor.n != self.n:
            raise ValueError(
                f"monitor is sized for {self.monitor.n} nodes, truth has {self.n}"
            )

    def sample(self) -> np.ndarray:
        t = self._truth.sample()
        return self.monitor.probe_all(t, self._rng, self.noise).copy()

    def estimate(self) -> np.ndarray:
        return self.monitor.estimate()

    @property
    def probe_bytes(self) -> int:
        return self.monitor.probe_bytes


class VivaldiView:
    """Vivaldi network-coordinate estimation against a truth source.

    The large-scale regime (Sec 5): O(n * samples_per_node) probes per round
    instead of the monitor's O(n^2), with periodic verification sampling
    (every ``verify_every`` rounds) that pins drifting entries back to direct
    measurements.  The estimate is symmetrized with a zero diagonal so
    downstream planners see a valid latency matrix.

    ``warmup_rounds > 0`` enables the monitor-seeded warmup: the first K
    ``sample()`` calls run a full-mesh direct measurement (paying the
    monitor's ``n*(n-1)`` probes), seed the coordinate system from the
    measured matrix (classical-MDS placement,
    :meth:`~repro.core.monitor.VivaldiSystem.seed_from_matrix`) and return
    the direct measurement itself.  This fixes the poor small-n relay-order
    agreement of randomly initialized coordinates: after warmup the spring
    system starts near-correct and the cheap sparse rounds only track drift.
    """

    def __init__(
        self,
        truth,
        *,
        samples_per_node: int = 8,
        verify_every: int = 10,
        verify_frac: float = 0.05,
        verify_tol: float = 0.25,
        warmup_rounds: int = 0,
        cfg: VivaldiConfig | None = None,
        seed: int = 0,
    ):
        self._truth = as_view(truth)
        self.n = self._truth.n
        self.samples_per_node = samples_per_node
        self.verify_every = max(1, verify_every)
        self.verify_frac = verify_frac
        self.verify_tol = verify_tol
        self.warmup_rounds = max(0, warmup_rounds)
        self._rng = np.random.default_rng(seed)
        self.system = VivaldiSystem(self.n, cfg, seed=seed)
        self._round = 0
        self._est = self.system.estimate()

    def _clean(self, est: np.ndarray) -> np.ndarray:
        est = (est + est.T) / 2.0
        np.fill_diagonal(est, 0.0)
        return np.maximum(est, 0.0)

    def sample(self) -> np.ndarray:
        t = self._truth.sample()
        self._round += 1
        if self._round <= self.warmup_rounds:
            # monitor-seeded warmup: full-mesh direct RTTs seed the
            # coordinates and ARE the estimate for this round
            self.system.seed_from_matrix(t)
            self.system.probe_count += self.n * (self.n - 1)
            self._est = self._clean(t.copy())
            return self._est.copy()
        self.system.fit(
            t, rounds=1, samples_per_node=self.samples_per_node, rng=self._rng
        )
        if self._round % self.verify_every == 0:
            est = self.system.verify_and_correct(
                t, sample_frac=self.verify_frac, rng=self._rng,
                tol=self.verify_tol,
            )
        else:
            est = self.system.estimate()
        self._est = self._clean(est)
        return self._est.copy()

    def estimate(self) -> np.ndarray:
        return self._est.copy()

    @property
    def probe_bytes(self) -> int:
        return self.system.probe_bytes
