"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed top-8
(arXiv:2412.19437; hf).

61L d_model=7168 128H d_expert=2048 vocab=129280.  First 3 layers dense
(d_ff 18432) per the DeepSeek-V3 architecture; the MTP head is out of scope
(noted in DESIGN.md).
"""

from .base import Block, MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,                       # dense layers' hidden dim
        vocab_size=129_280,
        blocks_prefix=(Block("mla", "dense"),) * 3,
        blocks_pattern=(Block("mla", "moe"),),
        moe=MoEConfig(
            n_experts=256, top_k=8, d_expert=2048, n_shared=1, d_shared=2048,
            capacity_factor=1.25,
        ),
        mla=MLAConfig(
            q_lora_rank=1536, kv_lora_rank=512,
            qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        blocks_prefix=(Block("mla", "dense"),),
        blocks_pattern=(Block("mla", "moe"),),
        # high capacity factor: no token drops -> decode/full-forward parity
        # is exactly testable (drops are a capacity artifact, not semantics)
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1, d_shared=32,
                      capacity_factor=8.0),
        mla=MLAConfig(
            q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        ),
    )
