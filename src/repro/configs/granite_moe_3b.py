"""granite-moe-3b-a800m [moe]: 40 experts top-8
(hf:ibm-granite/granite-3.0-1b-a400m-base; hf).

32L d_model=1536 24H (GQA kv=8) d_expert=512 vocab=49155.
"""

from .base import Block, ModelConfig, MoEConfig

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49_155,
        blocks_pattern=(Block("attn", "moe"),),
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, capacity_factor=1.25),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        blocks_pattern=(Block("attn", "moe"),),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0),
        tie_embeddings=True,
    )
