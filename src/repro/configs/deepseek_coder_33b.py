"""deepseek-coder-33b [dense]: llama-arch (arXiv:2401.14196; hf).

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""

from .base import Block, ModelConfig

ARCH_ID = "deepseek-coder-33b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32_256,
        blocks_pattern=(Block("attn", "dense"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=56,          # 56 = 4 heads x 14? keep multiple of heads: use 56/4=14
        n_heads=4,
        n_kv_heads=2,
        d_ff=112,
        vocab_size=512,
        blocks_pattern=(Block("attn", "dense"),),
    )
