"""qwen2.5-32b [dense]: GQA with QKV bias (hf:Qwen/Qwen2.5-0.5B; hf).

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""

from .base import Block, ModelConfig

ARCH_ID = "qwen2.5-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152_064,
        qkv_bias=True,
        blocks_pattern=(Block("attn", "dense"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        qkv_bias=True,
        blocks_pattern=(Block("attn", "dense"),),
    )
