"""Model / shape configuration dataclasses for the assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

__all__ = ["MoEConfig", "MLAConfig", "Block", "ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0             # shared-expert hidden dim (0 -> d_expert)
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    @property
    def shared_hidden(self) -> int:
        return self.d_shared or self.d_expert


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dimensions."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


# mixer:  attn | attn_local | attn_cross | mla | rwkv | rglru
# ffn:    dense | moe | rwkv_cmix | none
@dataclasses.dataclass(frozen=True)
class Block:
    mixer: str = "attn"
    ffn: str = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | moe | audio | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    causal: bool = True           # False for encoder-only (hubert)
    blocks_prefix: tuple[Block, ...] = ()
    blocks_pattern: tuple[Block, ...] = (Block(),)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    local_window: int = 0         # window for attn_local mixers
    n_img_tokens: int = 0         # vlm: stub image-token sequence length
    frontend: Literal["token", "frames", "patches"] = "token"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # rwkv/rglru specifics
    rwkv_head_dim: int = 64
    rglru_conv_width: int = 4
    rglru_lru_width: int = 0      # 0 -> d_model
    # training niceties
    remat: bool = True

    # ---- derived ------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def block_list(self) -> tuple[Block, ...]:
        """The full, explicit per-layer block sequence."""
        blocks = list(self.blocks_prefix)
        pat = self.blocks_pattern
        while len(blocks) < self.n_layers:
            blocks.extend(pat)
        return tuple(blocks[: self.n_layers])

    def scan_partition(self) -> tuple[tuple[Block, ...], int, tuple[Block, ...], tuple[Block, ...]]:
        """Partition layers into (prefix, n_scan_superblocks, pattern, suffix).

        The scanned region covers whole pattern repetitions after the prefix;
        the remainder is unrolled as a suffix.  This keeps HLO compact (one
        scan body per pattern) while supporting heterogeneous stacks.
        """
        pre = self.blocks_prefix
        rest = self.n_layers - len(pre)
        p = len(self.blocks_pattern)
        n_scan = rest // p
        suffix = self.blocks_pattern[: rest % p]
        return pre, n_scan, self.blocks_pattern, suffix

    @property
    def is_attention_free(self) -> bool:
        mixers = {b.mixer for b in self.block_list()}
        return mixers <= {"rwkv", "rglru"}

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing: SSM / hybrid / windowed-only attn."""
        mixers = {b.mixer for b in self.block_list()}
        quadratic = {"attn", "mla", "attn_cross"}
        return not (mixers & quadratic)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeSpec]:
    """The shape cells this architecture runs (skips per DESIGN.md §4)."""
    out = []
    for s in SHAPES.values():
        if s.kind == "decode" and cfg.is_encoder_only:
            continue  # encoder-only: no autoregressive step
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # pure full-attention archs skip 500k decode
        out.append(s)
    return out
