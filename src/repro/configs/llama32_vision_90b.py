"""llama-3.2-vision-90b [vlm]: cross-attn image layers
(hf:meta-llama/Llama-3.2-11B-Vision; unverified).

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
cross-attends to stub image-token embeddings (the vision tower is a STUB per
the assignment: ``input_specs()`` supplies precomputed patch embeddings).
"""

from .base import Block, ModelConfig

ARCH_ID = "llama-3.2-vision-90b"

N_IMG_TOKENS = 1601  # (448/14)^2 patches + CLS, one tile


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128_256,
        n_img_tokens=N_IMG_TOKENS,
        blocks_pattern=(
            Block("attn", "dense"),
            Block("attn", "dense"),
            Block("attn", "dense"),
            Block("attn", "dense"),
            Block("attn_cross", "dense"),
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        n_img_tokens=16,
        blocks_pattern=(
            Block("attn", "dense"),
            Block("attn", "dense"),
            Block("attn", "dense"),
            Block("attn", "dense"),
            Block("attn_cross", "dense"),
        ),
    )
