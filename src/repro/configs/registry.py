"""Architecture registry: ``--arch <id>`` resolution for every entry point."""

from __future__ import annotations

from . import (
    deepseek_7b,
    deepseek_coder_33b,
    deepseek_v3_671b,
    granite_moe_3b,
    hubert_xlarge,
    llama32_vision_90b,
    minitron_8b,
    qwen2_5_32b,
    recurrentgemma_9b,
    rwkv6_7b,
)
from .base import ModelConfig, ShapeSpec, SHAPES, applicable_shapes

_MODULES = {
    m.ARCH_ID: m
    for m in (
        minitron_8b,
        deepseek_7b,
        deepseek_coder_33b,
        qwen2_5_32b,
        rwkv6_7b,
        deepseek_v3_671b,
        granite_moe_3b,
        hubert_xlarge,
        recurrentgemma_9b,
        llama32_vision_90b,
    )
}

ARCHS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    return _MODULES[arch].smoke_config()


def cells(archs: tuple[str, ...] = ARCHS) -> list[tuple[str, ShapeSpec]]:
    """All runnable (arch, shape) cells after the DESIGN.md §4 skips."""
    out = []
    for a in archs:
        cfg = get_config(a)
        for s in applicable_shapes(cfg):
            out.append((a, s))
    return out
