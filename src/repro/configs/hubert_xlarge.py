"""hubert-xlarge [audio]: encoder-only transformer backbone
(arXiv:2106.07447; unverified).

48L d_model=1280 16H d_ff=5120 vocab=504 (codebook targets).  The conv
waveform frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings.  Encoder-only -> no decode shapes.
"""

from .base import Block, ModelConfig

ARCH_ID = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        frontend="frames",
        blocks_pattern=(Block("attn", "dense"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=64,
        causal=False,
        frontend="frames",
        blocks_pattern=(Block("attn", "dense"),),
    )
