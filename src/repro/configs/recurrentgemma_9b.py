"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern
(arXiv:2402.19427; unverified).

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000,
local attention window 2048.  Sub-quadratic -> runs long_500k.
"""

from .base import Block, ModelConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab_size=256_000,
        local_window=2048,
        rglru_lru_width=4096,
        blocks_pattern=(
            Block("rglru", "dense"),
            Block("rglru", "dense"),
            Block("attn_local", "dense"),
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        local_window=32,
        rglru_lru_width=64,
        blocks_pattern=(
            Block("rglru", "dense"),
            Block("rglru", "dense"),
            Block("attn_local", "dense"),
        ),
    )
