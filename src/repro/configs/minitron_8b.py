"""minitron-8b [dense]: pruned Nemotron (arXiv:2407.14679; hf).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""

from .base import Block, ModelConfig

ARCH_ID = "minitron-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=256_000,
        blocks_pattern=(Block("attn", "dense"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        blocks_pattern=(Block("attn", "dense"),),
    )
