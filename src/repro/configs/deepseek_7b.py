"""deepseek-7b [dense]: llama-arch (arXiv:2401.02954; hf).

30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400.
"""

from .base import Block, ModelConfig

ARCH_ID = "deepseek-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab_size=102_400,
        blocks_pattern=(Block("attn", "dense"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        blocks_pattern=(Block("attn", "dense"),),
    )
