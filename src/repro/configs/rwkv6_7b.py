"""rwkv6-7b [ssm]: Finch, data-dependent decay, attention-free
(arXiv:2404.05892; hf).

32L d_model=4096 d_ff=14336 vocab=65536.  Runs long_500k (O(1) state).
"""

from .base import Block, ModelConfig

ARCH_ID = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,            # d_model / rwkv_head_dim
        n_kv_heads=64,
        d_ff=14336,
        vocab_size=65_536,
        rwkv_head_dim=64,
        blocks_pattern=(Block("rwkv", "rwkv_cmix"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        rwkv_head_dim=16,
        blocks_pattern=(Block("rwkv", "rwkv_cmix"),),
    )
