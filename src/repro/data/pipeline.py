"""Deterministic synthetic token pipeline.

Produces reproducible LM batches (Zipfian unigram mixture with in-context
structure so the loss has learnable signal), shardable across hosts: batch
``i`` is a pure function of (seed, step), so any host can regenerate any
shard after a restart — the data-plane half of fault tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    theta: float = 1.1          # unigram Zipf exponent
    copy_prob: float = 0.6      # P(next token copies a recent token)
    window: int = 8


class SyntheticLM:
    """Markov-ish synthetic LM stream: next token either copies a recent
    token (learnable structure) or draws from a Zipfian unigram."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.theta)
        self._p = p / p.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self._p)
        for t in range(1, s + 1):
            copy = rng.random(b) < cfg.copy_prob
            back = rng.integers(1, min(t, cfg.window) + 1, size=b)
            copied = toks[np.arange(b), t - back]
            fresh = rng.choice(cfg.vocab_size, size=b, p=self._p)
            toks[:, t] = np.where(copy & (t > 1), copied, fresh)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch(cfg: DataConfig, step: int, *, device_put=True, sharding=None):
    arrs = SyntheticLM(cfg).batch(step)
    out = {k: jnp.asarray(v) for k, v in arrs.items()}
    if device_put and sharding is not None:
        out = {k: jax.device_put(v, sharding[k]) for k, v in out.items()}
    return out
