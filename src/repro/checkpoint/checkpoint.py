"""Sharded, versioned, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<n>/   (atomic rename from a .tmp directory)
             meta.json                     — step, pytree structure, shapes
             arr_<i>.npy                   — one file per leaf (host-gathered)

Design points for the 1000+-node story (documented; exercised here on one
process):

* **mesh-agnostic**: leaves are saved as full logical arrays + their axis
  metadata, so a checkpoint written on a (2,16,16) mesh restores onto any
  other mesh/device count — elastic scaling is a restore-time resharding
  (``restore(..., shardings=new)``), not a migration tool.
* **atomic**: writers fill ``step_N.tmp`` then rename; readers only ever see
  complete checkpoints; interrupted saves are garbage-collected.
* **async**: ``save_async`` snapshots device arrays then writes on a worker
  thread so the train loop is not blocked (jax arrays are immutable — the
  snapshot is free).
* **duplicate-safe**: restoring the same checkpoint twice or on top of live
  state is idempotent, matching the CRDT recovery semantics of the sync
  layer.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "available_steps"]


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Synchronous sharded save (host-gathers each leaf)."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    meta = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        meta["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(ckpt_dir: str, step: int, tree: Any) -> threading.Thread:
    """Non-blocking save; returns the writer thread (join() to fence).

    The host snapshot happens *synchronously*: jax arrays are immutable,
    but the train step donates its input buffers — a lazily-captured device
    array can be deleted before the writer thread serializes it ("Array has
    been deleted"), silently dropping the checkpoint.  Copying to host
    first fences against donation; only the file I/O runs on the thread.
    """
    host_tree = jax.tree.map(np.asarray, tree)
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree), daemon=True
    )
    t.start()
    return t


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    like: Any,
    *,
    shardings: Any | None = None,
) -> Any:
    """Restore into the structure of ``like``; optionally place each leaf
    with ``shardings`` (a matching pytree of NamedSharding) — this is the
    elastic-rescale path: the target mesh may differ arbitrarily from the
    mesh that wrote the checkpoint."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    by_key = {l["key"]: l for l in meta["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = treedef.flatten_up_to(shardings)
    out = []
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        ent = by_key[key]
        arr = np.load(os.path.join(d, ent["file"]))
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != expected {expect}"
            )
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


def gc_incomplete(ckpt_dir: str) -> int:
    """Remove interrupted .tmp checkpoints; returns count removed."""
    if not os.path.isdir(ckpt_dir):
        return 0
    n = 0
    for name in os.listdir(ckpt_dir):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name))
            n += 1
    return n
