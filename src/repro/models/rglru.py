"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)                  (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                  (input gate)
    a_t = exp(c * r_t * log(sigmoid(Lambda)))     (data-dependent decay, c=8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Implemented with ``jax.lax.associative_scan`` over (a, b) pairs — O(log T)
depth, O(T*D) memory.  The surrounding Griffin recurrent block is:

    x -> [ gelu(W_gate x) ]  *  [ RG-LRU(conv1d_4(W_in x)) ]  -> W_out

Decode is O(1): carry (h, conv window).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Params, dense_apply, dense_init

__all__ = ["rglru_block_init", "rglru_block_apply", "rglru_init_state"]

_C = 8.0


def rglru_block_init(
    key, d_model: int, lru_width: int, conv_width: int = 4, dtype=jnp.float32
) -> Params:
    ks = jax.random.split(key, 7)
    # Lambda init so that a = sigmoid(Lambda) in ~[0.9, 0.999]
    lam = jax.random.uniform(ks[0], (lru_width,), jnp.float32, 2.0, 7.0)
    return {
        "w_in": dense_init(ks[1], d_model, lru_width, dtype=dtype),
        "w_gate": dense_init(ks[2], d_model, lru_width, dtype=dtype),
        "conv_w": jax.random.normal(ks[3], (conv_width, lru_width), dtype) * 0.1,
        "conv_b": jnp.zeros((lru_width,), dtype),
        "wa": dense_init(ks[4], lru_width, lru_width, bias=True, dtype=dtype),
        "wx": dense_init(ks[5], lru_width, lru_width, bias=True, dtype=dtype),
        "lam": lam.astype(dtype),
        "w_out": dense_init(ks[6], lru_width, d_model,
                            scale=0.02 / math.sqrt(2), dtype=dtype),
    }


def _causal_conv1d(
    w: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray, prev: jnp.ndarray | None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv, width W.  prev: (B, W-1, D) history or None."""
    width = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(width)
    ) + b.astype(x.dtype)
    new_prev = xp[:, -(width - 1):] if width > 1 else prev
    return out, new_prev


def _rglru_scan(a: jnp.ndarray, bterm: jnp.ndarray, h0: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t*h_{t-1} + b_t via associative scan; returns (h_1..T, h_T)."""

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    # fold h0 into the first b term
    b0 = bterm.at[:, 0].add(a[:, 0] * h0)
    aa, bb = jax.lax.associative_scan(combine, (a, b0), axis=1)
    return bb, bb[:, -1]


def rglru_block_apply(
    p: Params,
    x: jnp.ndarray,                  # (B, T, d_model)
    *,
    state: Params | None = None,     # {"h": (B, D), "conv": (B, W-1, D)}
) -> tuple[jnp.ndarray, Params | None]:
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x))
    u = dense_apply(p["w_in"], x)
    u, conv_state = _causal_conv1d(
        p["conv_w"], p["conv_b"],
        u, state["conv"] if state is not None else None,
    )

    r = jax.nn.sigmoid(dense_apply(p["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["wx"], u).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))  # < 0
    log_a = _C * r * log_a_base[None, None]
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    h0 = (
        state["h"].astype(jnp.float32)
        if state is not None
        else jnp.zeros_like(bterm[:, 0])
    )
    h, h_last = _rglru_scan(a, bterm, h0)
    h = h.astype(x.dtype)

    out = dense_apply(p["w_out"], h * gate)
    new_state = None
    if state is not None:
        new_state = {"h": h_last.astype(state["h"].dtype), "conv": conv_state}
    return out, new_state


def rglru_init_state(
    b: int, lru_width: int, conv_width: int = 4, dtype=jnp.float32
) -> Params:
    return {
        "h": jnp.zeros((b, lru_width), jnp.float32),
        "conv": jnp.zeros((b, conv_width - 1, lru_width), dtype),
    }
