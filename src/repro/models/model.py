"""Composable model assembly for all assigned architectures.

A model is a stack of residual blocks described by ``cfg.block_list()``;
heterogeneous stacks are compiled compactly via the scan partition
(prefix unrolled | pattern super-blocks scanned | suffix unrolled), so a
100-layer VLM lowers to one scan body instead of 100 inlined layers.

Public API:
    init_params(cfg, key)                      -> params pytree
    forward(cfg, params, batch, cache=None)    -> (logits, new_cache)
    init_cache(cfg, batch, max_len)            -> decode cache pytree
    param_count(cfg)                           -> int (via eval_shape)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import Block, ModelConfig
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv6 as rwkv_mod
from .layers import (
    Params,
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    gqa_apply,
    gqa_init,
    gqa_init_cache,
    rmsnorm_apply,
    rmsnorm_init,
    unembed_apply,
)

__all__ = ["init_params", "forward", "init_cache", "param_count", "num_params"]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, block: Block) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"norm1": rmsnorm_init(d)}
    hd = cfg.resolved_head_dim
    if block.mixer in ("attn", "attn_local", "attn_cross"):
        p["mixer"] = gqa_init(k1, d, cfg.n_heads, cfg.n_kv_heads, hd,
                              bias=cfg.qkv_bias)
    elif block.mixer == "mla":
        assert cfg.mla is not None
        p["mixer"] = mla_mod.mla_init(k1, d, cfg.n_heads, cfg.mla)
    elif block.mixer == "rwkv":
        p["mixer"] = rwkv_mod.rwkv_tmix_init(k1, d, cfg.rwkv_head_dim)
    elif block.mixer == "rglru":
        p["mixer"] = rglru_mod.rglru_block_init(
            k1, d, cfg.rglru_lru_width or d, cfg.rglru_conv_width
        )
    else:
        raise ValueError(f"unknown mixer {block.mixer!r}")

    if block.ffn != "none":
        p["norm2"] = rmsnorm_init(d)
    if block.ffn == "dense":
        from .layers import swiglu_init

        p["ffn"] = swiglu_init(k2, d, cfg.d_ff)
    elif block.ffn == "moe":
        assert cfg.moe is not None
        p["ffn"] = moe_mod.moe_init(
            k2, d, cfg.moe.n_experts, cfg.moe.d_expert,
            n_shared=cfg.moe.n_shared, d_shared=cfg.moe.d_shared,
        )
    elif block.ffn == "rwkv_cmix":
        p["ffn"] = rwkv_mod.rwkv_cmix_init(k2, d, cfg.d_ff)
    elif block.ffn != "none":
        raise ValueError(f"unknown ffn {block.ffn!r}")
    return p


def _block_cache(cfg: ModelConfig, block: Block, b: int, max_len: int,
                 dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    if block.mixer == "attn":
        return gqa_init_cache(b, max_len, cfg.n_kv_heads, hd, dtype=dtype)
    if block.mixer == "attn_local":
        return gqa_init_cache(
            b, max_len, cfg.n_kv_heads, hd,
            window=min(cfg.local_window, max_len), dtype=dtype,
        )
    if block.mixer == "attn_cross":
        return {"len": jnp.zeros((), jnp.int32)}  # context static; nothing cached
    if block.mixer == "mla":
        return mla_mod.mla_init_cache(b, max_len, cfg.mla, dtype)
    if block.mixer == "rwkv":
        return rwkv_mod.rwkv_init_state(b, cfg.d_model, cfg.rwkv_head_dim,
                                        dtype=dtype)
    if block.mixer == "rglru":
        return rglru_mod.rglru_init_state(
            b, cfg.rglru_lru_width or cfg.d_model, cfg.rglru_conv_width,
            dtype=dtype,
        )
    raise ValueError(block.mixer)


def _block_apply(
    cfg: ModelConfig,
    block: Block,
    p: Params,
    x: jnp.ndarray,
    *,
    img_ctx: jnp.ndarray | None = None,
    cache: Params | None = None,
):
    eps = cfg.norm_eps
    h = rmsnorm_apply(p["norm1"], x, eps=eps)
    new_cache = cache
    hd = cfg.resolved_head_dim

    if block.mixer in ("attn", "attn_local"):
        window = cfg.local_window if block.mixer == "attn_local" else 0
        y, new_attn_cache = gqa_apply(
            p["mixer"], h,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
            causal=cfg.causal, window=window, rope_theta=cfg.rope_theta,
            cache=cache,
        )
        if cache is not None:
            new_cache = new_attn_cache
    elif block.mixer == "attn_cross":
        assert img_ctx is not None, "cross-attention block needs image context"
        y, _ = gqa_apply(
            p["mixer"], h,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
            causal=False, rope_theta=cfg.rope_theta, kv_source=img_ctx,
        )
        if cache is not None:
            new_cache = {"len": cache["len"] + x.shape[1]}
    elif block.mixer == "mla":
        y, new_mla_cache = mla_mod.mla_apply(
            p["mixer"], h, n_heads=cfg.n_heads, mla=cfg.mla,
            causal=cfg.causal, rope_theta=cfg.rope_theta, cache=cache,
        )
        if cache is not None:
            new_cache = new_mla_cache
    elif block.mixer == "rwkv":
        y, new_t = rwkv_mod.rwkv_tmix_apply(
            p["mixer"], h, head_dim=cfg.rwkv_head_dim,
            state=cache["tmix"] if cache is not None else None,
        )
        if cache is not None:
            new_cache = dict(cache)
            new_cache["tmix"] = new_t
    elif block.mixer == "rglru":
        y, new_r = rglru_mod.rglru_block_apply(
            p["mixer"], h, state=cache if cache is not None else None
        )
        if cache is not None:
            new_cache = new_r
    else:
        raise ValueError(block.mixer)
    x = x + y

    if block.ffn == "none":
        return x, new_cache
    h2 = rmsnorm_apply(p["norm2"], x, eps=eps)
    if block.ffn == "dense":
        from .layers import swiglu_apply

        x = x + swiglu_apply(p["ffn"], h2)
    elif block.ffn == "moe":
        x = x + moe_mod.moe_apply(
            p["ffn"], h2, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
        )
    elif block.ffn == "rwkv_cmix":
        y2, new_c = rwkv_mod.rwkv_cmix_apply(
            p["ffn"], h2,
            state=cache["cmix"] if (cache is not None and block.mixer == "rwkv") else None,
        )
        x = x + y2
        if cache is not None and block.mixer == "rwkv":
            new_cache = dict(new_cache)
            new_cache["cmix"] = new_c
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Params:
    prefix, n_scan, pattern, suffix = cfg.scan_partition()
    k_embed, k_head, k_pre, k_scan, k_suf = jax.random.split(key, 5)

    params: Params = {}
    if cfg.frontend == "token":
        params["embed"] = embed_init(k_embed, cfg.vocab_size, cfg.d_model)
    else:
        # modality frontend is a stub: inputs arrive as embeddings; a single
        # projection adapts them (stands in for the conv/patch stack)
        params["embed_proj"] = dense_init(k_embed, cfg.d_model, cfg.d_model)

    params["prefix"] = tuple(
        _block_init(k, cfg, b)
        for k, b in zip(jax.random.split(k_pre, max(len(prefix), 1)), prefix)
    )
    if n_scan > 0:
        def init_superblock(k):
            kk = jax.random.split(k, len(pattern))
            return tuple(_block_init(ki, cfg, b) for ki, b in zip(kk, pattern))

        params["scan"] = jax.vmap(init_superblock)(
            jax.random.split(k_scan, n_scan)
        )
    params["suffix"] = tuple(
        _block_init(k, cfg, b)
        for k, b in zip(jax.random.split(k_suf, max(len(suffix), 1)), suffix)
    )
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend != "token":
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                       scale=0.02)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    prefix, n_scan, pattern, suffix = cfg.scan_partition()
    cache: Params = {
        "prefix": tuple(_block_cache(cfg, b, batch, max_len, dtype) for b in prefix),
        "suffix": tuple(_block_cache(cfg, b, batch, max_len, dtype) for b in suffix),
    }
    if n_scan > 0:
        one = tuple(_block_cache(cfg, b, batch, max_len, dtype) for b in pattern)
        cache["scan"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_scan,) + a.shape).copy(), one
        )
    return cache


def forward(
    cfg: ModelConfig,
    params: Params,
    batch: dict[str, jnp.ndarray],
    *,
    cache: Params | None = None,
    compute_dtype=jnp.bfloat16,
    act_constrain=None,
    embed_fn=None,
):
    """Run the model.  ``batch`` has "tokens" (B,S) or "embeds" (B,S,d),
    optionally "img" (B,N_img,d) for VLM cross-attention.  Returns
    (logits, new_cache).

    ``act_constrain`` (optional) is applied to the residual-stream activation
    at every block boundary — the hook the distributed trainer uses to pin
    activation shardings so GSPMD never resolves a weight/activation conflict
    by replicating the batch.  ``embed_fn(embed_params, tokens, dtype)``
    optionally overrides the vocab lookup (the trainer supplies an explicitly
    sharded implementation; XLA's gather partitioner is not trusted with it).
    """
    prefix, n_scan, pattern, suffix = cfg.scan_partition()
    ac = act_constrain if act_constrain is not None else (lambda x: x)

    if cfg.frontend == "token":
        if embed_fn is not None:
            x = embed_fn(params["embed"], batch["tokens"], compute_dtype)
        else:
            x = embed_apply(params["embed"], batch["tokens"], dtype=compute_dtype)
    else:
        x = dense_apply(params["embed_proj"], batch["embeds"].astype(compute_dtype))
    img_ctx = batch.get("img")
    if img_ctx is not None:
        img_ctx = img_ctx.astype(compute_dtype)

    new_cache: Params = {"prefix": [], "suffix": []} if cache is not None else None

    def run_block(blk, p, xx, c):
        xx, nc = _block_apply(cfg, blk, p, ac(xx), img_ctx=img_ctx, cache=c)
        return ac(xx), nc

    for i, blk in enumerate(prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc = run_block(blk, params["prefix"][i], x, c)
        if cache is not None:
            new_cache["prefix"].append(nc)

    if n_scan > 0:
        def superblock(xx, args):
            p_stack, c_stack = args
            ncs = []
            for j, blk in enumerate(pattern):
                c = c_stack[j] if c_stack is not None else None
                xx, nc = run_block(blk, p_stack[j], xx, c)
                ncs.append(nc)
            return xx, (tuple(ncs) if c_stack is not None else None)

        body = jax.checkpoint(superblock) if cfg.remat else superblock
        c_scan = cache["scan"] if cache is not None else None
        x, scan_caches = jax.lax.scan(
            body, x, (params["scan"], c_scan)
        )
        if cache is not None:
            new_cache["scan"] = scan_caches

    for i, blk in enumerate(suffix):
        c = cache["suffix"][i] if cache is not None else None
        x, nc = run_block(blk, params["suffix"][i], x, c)
        if cache is not None:
            new_cache["suffix"].append(nc)

    x = rmsnorm_apply(params["final_norm"], ac(x), eps=cfg.norm_eps)
    if "lm_head" in params:
        logits = dense_apply(params["lm_head"], x)
    else:
        logits = unembed_apply(params["embed"], x)
    if cache is not None:
        new_cache["prefix"] = tuple(new_cache["prefix"])
        new_cache["suffix"] = tuple(new_cache["suffix"])
    return logits, new_cache


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(math.prod(l.shape)) for l in jax.tree.leaves(shapes))


num_params = param_count


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    moe_blocks = sum(1 for b in cfg.block_list() if b.ffn == "moe")
    per_expert = 3 * cfg.d_model * cfg.moe.d_expert
    inactive = moe_blocks * (cfg.moe.n_experts - cfg.moe.top_k) * per_expert
    return total - inactive
