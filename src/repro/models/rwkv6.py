"""RWKV-6 "Finch" time-mix and channel-mix (arXiv:2404.05892).

Time-mix per head (head dim N, state S in R^{NxN}):

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with data-dependent decay ``w_t = exp(-exp(wb + lora_w(x)))`` and
data-dependent token-shift interpolation (the Finch additions over v5).
The jnp scan here is the oracle for the Pallas WKV6 kernel
(``repro.kernels.rwkv6_wkv``); the model calls the kernel's jnp reference
path so CPU tests and TPU runs share semantics.

Decode is O(1): the state (B, H, N, N) plus one token-shift vector.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Params, dense_apply, dense_init

__all__ = [
    "rwkv_tmix_init",
    "rwkv_tmix_apply",
    "rwkv_cmix_init",
    "rwkv_cmix_apply",
    "rwkv_init_state",
    "wkv6_scan",
]


def _lora_init(key, d: int, r: int, out: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "a": jax.random.normal(k1, (d, r), dtype) * 0.01,
        "b": jax.random.normal(k2, (r, out), dtype) * 0.01,
    }


def _lora_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.tanh(x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype)


def rwkv_tmix_init(key, d_model: int, head_dim: int, dtype=jnp.float32) -> Params:
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 10)
    p = {
        "mu": jnp.full((5, d_model), 0.5, dtype),          # token-shift bases (r,k,v,w,g)
        "mu_lora": _lora_init(ks[0], d_model, 32, 5 * d_model, dtype),
        "wr": dense_init(ks[1], d_model, d_model, dtype=dtype),
        "wk": dense_init(ks[2], d_model, d_model, dtype=dtype),
        "wv": dense_init(ks[3], d_model, d_model, dtype=dtype),
        "wg": dense_init(ks[4], d_model, d_model, dtype=dtype),
        "wo": dense_init(ks[5], d_model, d_model,
                         scale=0.02 / math.sqrt(2), dtype=dtype),
        "w_base": jnp.zeros((d_model,), dtype) - 6.0,       # slow decay at init
        "w_lora": _lora_init(ks[6], d_model, 64, d_model, dtype),
        "u": jax.random.normal(ks[7], (d_model,), dtype) * 0.1,
        "ln_g": jnp.ones((d_model,), dtype),                # per-head group norm gain
        "ln_b": jnp.zeros((d_model,), dtype),
    }
    return p


def wkv6_scan(
    r: jnp.ndarray,   # (B, T, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,   # decay in (0, 1), (B, T, H, N)
    u: jnp.ndarray,   # (H, N)
    state: jnp.ndarray,  # (B, H, N, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential WKV6 recurrence (jnp oracle).  Returns (y, final_state)."""

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw            # (B, H, N)
        kv = kt[..., :, None] * vt[..., None, :]          # (B, H, N, N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, y

    xs = (
        r.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        w.transpose(1, 0, 2, 3),
    )
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), final  # (B, T, H, N)


def wkv6_chunked(
    r: jnp.ndarray,   # (B, T, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,   # decay in (0, 1)
    u: jnp.ndarray,   # (H, N)
    state: jnp.ndarray,  # (B, H, N, N)
    *,
    chunk: int = 128,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked-parallel WKV6 (exact, MXU-friendly).

    The per-step recurrence updates the (N, N) state T times; on the roofline
    that is O(T) sequential state round-trips.  Chunking rewrites it as, per
    chunk of C steps (cumulative log-decays ``L_t = sum_{s<=t} log w_s``):

        y_t  = r_t (P_{t-1} * S_0)  +  sum_{s<t} (r_t * P_{t-1}/P_s) k_s v_s^T
               + (r_t * u) k_t v_t^T
        S_C  = P_C * S_0 + sum_s (P_C / P_s) k_s v_s^T

    where P_t = exp(L_t).  The intra-chunk term is a causal (C x C)
    attention-style matmul; the state is touched once per chunk — state
    traffic drops T/C-fold and the compute moves onto the MXU.  Ratios
    P_{t-1}/P_s (s < t) are products of w in (0,1): always <= 1, numerically
    safe in log space.  This mirrors the Pallas kernel's time-chunked design
    (EXPERIMENTS.md §Perf, rwkv6 iteration).
    """
    b, t, h, n = r.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c
    f32 = jnp.float32
    r, k, v = (a.astype(f32) for a in (r, k, v))
    logw = jnp.log(jnp.clip(w.astype(f32), 1e-12, 1.0))

    rs = r.reshape(b, nc, c, h, n)
    ks = k.reshape(b, nc, c, h, n)
    vs = v.reshape(b, nc, c, h, n)
    lws = logw.reshape(b, nc, c, h, n)
    u = u.astype(f32)

    def chunk_step(s0, args):
        rc, kc, vc, lw = args                      # (B, C, H, N)
        lcum = jnp.cumsum(lw, axis=1)              # L_t inclusive
        p_incl = jnp.exp(lcum)                     # P_t
        p_excl = jnp.exp(lcum - lw)                # P_{t-1}
        # cross-chunk: y_t += (r_t * P_{t-1}) . S_0
        rq = rc * p_excl
        y = jnp.einsum("bchn,bhnm->bchm", rq, s0)
        # intra-chunk: scores[t,s] = sum_n r_t[n] P_{t-1}[n]/P_s[n] k_s[n]
        kd = kc * jnp.exp(-lcum)                   # k_s / P_s
        scores = jnp.einsum("bchn,bshn->bhcs", rq, kd)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        # bonus diagonal: (r_t * u) . k_t v_t^T
        diag = jnp.einsum("bchn,bchn->bch", rc * u[None, None], kc)
        y = y + jnp.einsum("bhcs,bshm->bchm", scores, vc)
        y = y + diag[..., None] * vc
        # state update: S_C = P_C * S_0 + sum_s (P_C / P_s) k_s v_s^T
        p_c = p_incl[:, -1]                        # (B, H, N)
        kscaled = kd * p_c[:, None]                # (P_C / P_s) k_s
        s_new = p_c[..., None] * s0 + jnp.einsum("bshn,bshm->bhnm", kscaled, vc)
        return s_new, y

    xs = (
        rs.transpose(1, 0, 2, 3, 4),
        ks.transpose(1, 0, 2, 3, 4),
        vs.transpose(1, 0, 2, 3, 4),
        lws.transpose(1, 0, 2, 3, 4),
    )
    s_fin, ys = jax.lax.scan(chunk_step, state.astype(f32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, n)
    return y, s_fin


def rwkv_tmix_apply(
    p: Params,
    x: jnp.ndarray,                 # (B, T, d)
    *,
    head_dim: int,
    state: Params | None = None,    # {"s": (B,H,N,N), "shift": (B,d)}
    norm_eps: float = 1e-5,
    use_kernel: bool = False,
    chunked: bool = True,
) -> tuple[jnp.ndarray, Params | None]:
    b, t, d = x.shape
    h = d // head_dim

    x_prev = jnp.concatenate(
        [
            (state["shift"][:, None] if state is not None else jnp.zeros_like(x[:, :1])),
            x[:, :-1],
        ],
        axis=1,
    )
    lora = _lora_apply(p["mu_lora"], x).reshape(b, t, 5, d)
    mu = p["mu"].astype(x.dtype)[None, None] + lora            # (B, T, 5, d)
    xs = x[:, :, None] + (x_prev - x)[:, :, None] * mu
    xr, xk, xv, xw, xg = (xs[:, :, i] for i in range(5))

    r = dense_apply(p["wr"], xr).reshape(b, t, h, head_dim)
    k = dense_apply(p["wk"], xk).reshape(b, t, h, head_dim)
    v = dense_apply(p["wv"], xv).reshape(b, t, h, head_dim)
    g = jax.nn.silu(dense_apply(p["wg"], xg))
    w_log = p["w_base"].astype(jnp.float32) + _lora_apply(p["w_lora"], xw).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, t, h, head_dim).astype(x.dtype)
    u = p["u"].astype(x.dtype).reshape(h, head_dim)

    s0 = (
        state["s"]
        if state is not None
        else jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    )
    if use_kernel:
        from ..kernels.rwkv6_wkv import ops as wkv_ops

        y, s_fin = wkv_ops.wkv6(r, k, v, w, u, s0)
    elif chunked and t > 1:
        y, s_fin = wkv6_chunked(r, k, v, w, u.astype(jnp.float32), s0)
    else:
        y, s_fin = wkv6_scan(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w.astype(jnp.float32),
            u.astype(jnp.float32), s0,
        )
    y = y.astype(x.dtype).reshape(b, t, d)

    # per-head group norm
    yh = y.reshape(b, t, h, head_dim).astype(jnp.float32)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + norm_eps)
    y = (yh.reshape(b, t, d) * p["ln_g"].astype(jnp.float32)
         + p["ln_b"].astype(jnp.float32)).astype(x.dtype)

    out = dense_apply(p["wo"], y * g)
    new_state = None
    if state is not None:
        new_state = {"s": s_fin, "shift": x[:, -1]}
    return out, new_state


def rwkv_cmix_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "wk": dense_init(k1, d_model, d_ff, dtype=dtype),
        "wv": dense_init(k2, d_ff, d_model, scale=0.02 / math.sqrt(2), dtype=dtype),
        "wr": dense_init(k3, d_model, d_model, dtype=dtype),
    }


def rwkv_cmix_apply(
    p: Params,
    x: jnp.ndarray,
    *,
    state: Params | None = None,      # {"shift": (B, d)}
) -> tuple[jnp.ndarray, Params | None]:
    x_prev = jnp.concatenate(
        [
            (state["shift"][:, None] if state is not None else jnp.zeros_like(x[:, :1])),
            x[:, :-1],
        ],
        axis=1,
    )
    xk = x + (x_prev - x) * p["mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense_apply(p["wk"], xk)))
    out = jax.nn.sigmoid(dense_apply(p["wr"], xr)) * dense_apply(p["wv"], k)
    new_state = {"shift": x[:, -1]} if state is not None else None
    return out, new_state


def rwkv_init_state(b: int, d_model: int, head_dim: int, dtype=jnp.float32) -> Params:
    h = d_model // head_dim
    return {
        "tmix": {
            "s": jnp.zeros((b, h, head_dim, head_dim), jnp.float32),
            "shift": jnp.zeros((b, d_model), dtype),
        },
        "cmix": {"shift": jnp.zeros((b, d_model), dtype)},
    }
