"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are produced from low-rank latent compressions:

    c_q  = x W_dq            (q_lora_rank)
    q    = RMSNorm(c_q) W_uq          -> per-head [nope | rope] parts
    c_kv = x W_dkv           (kv_lora_rank)    <- THE KV cache (plus k_rope)
    k    = RMSNorm(c_kv) W_uk + shared k_rope
    v    = RMSNorm(c_kv) W_uv

Decode caches only (c_kv, k_rope): (S, kv_lora_rank + rope_dim) per token —
~10x smaller than GQA at these dims.  Attention itself is standard softmax
over qk_head_dim with a separate v_head_dim.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig
from .layers import (
    Params,
    apply_rope,
    dense_apply,
    dense_init,
    dense_attention,
    flash_attention,
    rmsnorm_apply,
    rmsnorm_init,
    _largest_chunk,
)

__all__ = ["mla_init", "mla_apply", "mla_init_cache"]


def mla_init(key, d_model: int, n_heads: int, mla: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    qk, rope = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    return {
        "wdq": dense_init(ks[0], d_model, mla.q_lora_rank, dtype=dtype),
        "q_norm": rmsnorm_init(mla.q_lora_rank, dtype),
        "wuq": dense_init(ks[1], mla.q_lora_rank, n_heads * (qk + rope), dtype=dtype),
        "wdkv": dense_init(ks[2], d_model, mla.kv_lora_rank, dtype=dtype),
        "kv_norm": rmsnorm_init(mla.kv_lora_rank, dtype),
        "wuk": dense_init(ks[3], mla.kv_lora_rank, n_heads * qk, dtype=dtype),
        "wuv": dense_init(ks[4], mla.kv_lora_rank, n_heads * mla.v_head_dim, dtype=dtype),
        "wkr": dense_init(ks[5], d_model, rope, dtype=dtype),
        "wo": dense_init(
            ks[6], n_heads * mla.v_head_dim, d_model,
            scale=0.02 / math.sqrt(2), dtype=dtype,
        ),
    }


def mla_init_cache(b: int, max_len: int, mla: MLAConfig, dtype=jnp.bfloat16) -> Params:
    return {
        "ckv": jnp.zeros((b, max_len, mla.kv_lora_rank), dtype),
        "kr": jnp.zeros((b, max_len, mla.qk_rope_head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _project_kv(p: Params, ckv: jnp.ndarray, n_heads: int, mla: MLAConfig):
    ckv_n = rmsnorm_apply(p["kv_norm"], ckv)
    b, s, _ = ckv.shape
    k_nope = dense_apply(p["wuk"], ckv_n).reshape(b, s, n_heads, mla.qk_nope_head_dim)
    v = dense_apply(p["wuv"], ckv_n).reshape(b, s, n_heads, mla.v_head_dim)
    return k_nope, v


def mla_apply(
    p: Params,
    x: jnp.ndarray,
    *,
    n_heads: int,
    mla: MLAConfig,
    causal: bool = True,
    rope_theta: float = 10_000.0,
    cache: Params | None = None,
) -> tuple[jnp.ndarray, Params | None]:
    b, s, _ = x.shape
    qk, rope_d = mla.qk_nope_head_dim, mla.qk_rope_head_dim

    cq = rmsnorm_apply(p["q_norm"], dense_apply(p["wdq"], x))
    q = dense_apply(p["wuq"], cq).reshape(b, s, n_heads, qk + rope_d)
    q_nope, q_rope = q[..., :qk], q[..., qk:]

    ckv_new = dense_apply(p["wdkv"], x)                       # (B, S, r_kv)
    kr_new = dense_apply(p["wkr"], x)                         # (B, S, rope_d)

    new_cache = None
    if cache is not None:
        clen = cache["len"]
        pos = clen + jnp.arange(s)
        q_rope = apply_rope(q_rope, pos, rope_theta)
        kr_new = apply_rope(kr_new[:, :, None, :], pos, rope_theta)[:, :, 0]
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), clen, 1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new.astype(cache["kr"].dtype), clen, 1)
        new_cache = {"ckv": ckv, "kr": kr, "len": clen + s}
        k_nope, v = _project_kv(p, ckv.astype(x.dtype), n_heads, mla)
        k_rope_b = jnp.broadcast_to(kr[:, :, None, :].astype(x.dtype),
                                    (b, kr.shape[1], n_heads, rope_d))
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = dense_attention(
            q_full, k, v, causal=causal, q_offset=clen, kv_len=clen + s
        )
    else:
        pos = jnp.arange(s)
        q_rope = apply_rope(q_rope, pos, rope_theta)
        kr_rot = apply_rope(kr_new[:, :, None, :], pos, rope_theta)[:, :, 0]
        k_nope, v = _project_kv(p, ckv_new, n_heads, mla)
        k_rope_b = jnp.broadcast_to(kr_rot[:, :, None, :], (b, s, n_heads, rope_d))
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        if s > 2048:
            out = flash_attention(
                q_full, k, v, causal=causal,
                q_chunk=_largest_chunk(s, 1024), kv_chunk=_largest_chunk(s, 1024),
            )
        else:
            out = dense_attention(q_full, k, v, causal=causal)

    y = dense_apply(p["wo"], out.reshape(b, s, n_heads * mla.v_head_dim))
    return y, new_cache
