"""Shared model primitives (pure JAX, functional, explicit param pytrees).

Conventions:
* params are nested dicts of jnp arrays; init fns take a PRNG key and return
  the dict; apply fns take (params, inputs, ...) and are jit/vmap/scan safe.
* activations compute in ``x.dtype`` (bf16 under the dry-run policy); params
  are stored in ``param_dtype``.
* attention comes in three execution strategies:
  - ``dense_attention``   — materializes scores; short sequences.
  - ``flash_attention``   — q-chunk x kv-chunk online-softmax scan; memory
    O(chunk^2) instead of O(S^2) (the jnp reference for the TPU kernel).
  - ``banded_attention``  — local-window variant that only *visits* the
    in-window band, giving truly sub-quadratic FLOPs (recurrentgemma).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Params = dict

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None,
               bias: bool = False, dtype=jnp.float32) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["g"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention strategies
# ---------------------------------------------------------------------------

_NEG = -1e30


def _expand_gqa(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, S, Hq, D) -> (B, S, Hkv, G, D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def dense_attention(
    q: jnp.ndarray,            # (B, Sq, Hq, D)
    k: jnp.ndarray,            # (B, Sk, Hkv, D)
    v: jnp.ndarray,            # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Reference attention; scores materialized.  GQA via head grouping.

    ``q_offset`` is the absolute position of q[0] (decode: cache length);
    ``kv_len`` masks padded cache entries beyond the valid length.
    """
    n_kv = k.shape[2]
    qg = _expand_gqa(q, n_kv)                              # B Sq Hkv G D
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset                       # (Sq,)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    logits = jnp.where(mask[None, None, None], logits.astype(jnp.float32), _NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    b, _, hkv, g, dv = out.shape
    return out.reshape(b, sq, hkv * g, dv)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax blockwise attention (jnp reference of the TPU pattern).

    Peak live memory is O(q_chunk x kv_chunk) scores instead of O(Sq x Sk).
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, dv = v.shape
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, "chunk must divide length"
    nq, nk = sq // q_chunk, sk // kv_chunk
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)

    qs = q.reshape(b, nq, q_chunk, hkv, g, d)
    ks = k.reshape(b, nk, kv_chunk, hkv, d)
    vs = v.reshape(b, nk, kv_chunk, hkv, dv)

    def q_block(carry, qi):
        qb = qs[:, qi]  # (B, qc, Hkv, G, D)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(state, ki):
            m, l, acc = state
            kb = ks[:, ki]
            vb = vs[:, ki]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
            if causal:
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, dv)
        return carry, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, (), jnp.arange(nq))
    # blocks: (nq, B, q_chunk, Hq, Dv)
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, dv)


def banded_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: int,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Causal local attention visiting only the in-window band.

    For each q-chunk, a static-size slice of (window + q_chunk) keys is
    gathered with dynamic_slice — FLOPs O(S * window), not O(S^2).
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, dv = v.shape
    q_chunk = min(q_chunk, sq)
    assert sq % q_chunk == 0
    assert sq == sk, "banded attention is self-attention"
    nq = sq // q_chunk
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    band = window + q_chunk  # static slice width

    # left-pad keys so every slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (band - q_chunk, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band - q_chunk, 0), (0, 0), (0, 0)))
    qs = q.reshape(b, nq, q_chunk, hkv, g, d)

    def q_block(carry, qi):
        qb = qs[:, qi]
        start = qi * q_chunk  # slice [start, start+band) of padded == kv pos start-window..start+qc
        kb = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
        qpos = start + jnp.arange(q_chunk)                       # absolute
        kpos = start - window + jnp.arange(band)                 # absolute (may be <0 = pad)
        mask = (
            (qpos[:, None] >= kpos[None, :])
            & (qpos[:, None] - kpos[None, :] < window)
            & (kpos[None, :] >= 0)
        )
        s = jnp.where(mask[None, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(qb.dtype), vb)
        return carry, out.reshape(b, q_chunk, hq, dv)

    _, blocks = jax.lax.scan(q_block, (), jnp.arange(nq))
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, dv)


def pad_heads_for_tp(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, dm: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Padded-TP head layout for head counts not dividing the TP axis.

    Without this, XLA factors the TP axis into heads x head_dim and emits an
    all-reduce per attention chunk-pair (measured 3.7 TB/device/step on
    deepseek-coder-33b prefill, EXPERIMENTS.md §Perf iteration 4).

    Exact construction: kv heads are *repeated* ``rep = lcm(KV, dm)/KV``
    times; each real group's q heads are zero-padded from ``gq = H/KV`` to
    ``gq_pad = rep * ceil(gq/rep)``.  Group-major head order is preserved, so
    padded q slot ``r*gq_pad + o`` attends padded kv head
    ``r*rep + o // (gq_pad/rep)`` — a replica of real kv head ``r``: the math
    for every real head is unchanged.  Padded q rows produce garbage
    attention that the caller slices away, costing ``H_pad/H`` extra
    attention FLOPs for clean ``H_pad % dm == 0`` TP.

    Returns (q_pad, k_rep, v_rep, gq_pad); callers unpad the output with
    ``out.reshape(B, S, KV, gq_pad, D)[:, :, :, :gq]``.
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    rep = math.lcm(kv, dm) // kv
    gq_pad = rep * (-(-g // rep))
    qg = q.reshape(b, s, kv, g, d)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, gq_pad - g), (0, 0)))
    q_pad = qg.reshape(b, s, kv * gq_pad, d)
    k_rep = jnp.repeat(k, rep, axis=2)
    v_rep = jnp.repeat(v, rep, axis=2)
    return q_pad, k_rep, v_rep, gq_pad


def attention_any(
    q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
    flash_threshold: int = 2048,
):
    """Dispatch to the right attention strategy for the shapes at hand."""
    sq, sk = q.shape[1], k.shape[1]
    if sq == 1 or sq * sk <= flash_threshold * flash_threshold // 4 or kv_len is not None:
        return dense_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset, kv_len=kv_len
        )
    if window > 0 and sq == sk:
        qc = _largest_chunk(sq, min(1024, window))
        return banded_attention(q, k, v, window=window, q_chunk=qc)
    return flash_attention(
        q, k, v, causal=causal,
        q_chunk=_largest_chunk(sq, 1024), kv_chunk=_largest_chunk(sk, 1024),
    )


def _largest_chunk(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# GQA attention block (params + apply), with KV cache support
# ---------------------------------------------------------------------------



def _attend_tp(q, k, v, n_heads, head_dim, *, causal, window=0):
    """attention_any with the padded-TP layout when the head count does not
    divide the model axis (see pad_heads_for_tp)."""
    from ..dist import context as dist_context

    ctx = dist_context.current()
    dm = ctx.model_size if ctx is not None else 1
    if dm > 1 and n_heads % dm == 0:
        pass  # clean TP; constrain_heads already pinned it in gqa_apply
    elif dm > 1:
        b, sq = q.shape[0], q.shape[1]
        n_kv = k.shape[2]
        g = n_heads // n_kv
        qp, kp, vp, gq_pad = pad_heads_for_tp(q, k, v, dm)
        qp = ctx.constrain_heads(qp)
        kp = ctx.constrain_heads(kp)
        vp = ctx.constrain_heads(vp)
        outp = attention_any(qp, kp, vp, causal=causal, window=window)
        out = outp.reshape(b, sq, n_kv, gq_pad, head_dim)[:, :, :, :g]
        return out.reshape(b, sq, n_heads, head_dim)
    return attention_any(q, k, v, causal=causal, window=window)


def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             *, bias: bool = False, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, n_heads * head_dim, bias=bias, dtype=dtype),
        "wk": dense_init(kk, d_model, n_kv * head_dim, bias=bias, dtype=dtype),
        "wv": dense_init(kv, d_model, n_kv * head_dim, bias=bias, dtype=dtype),
        "wo": dense_init(ko, n_heads * head_dim, d_model,
                         scale=0.02 / math.sqrt(2), dtype=dtype),
    }


def gqa_apply(
    p: Params,
    x: jnp.ndarray,                      # (B, S, d)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    causal: bool = True,
    window: int = 0,
    rope_theta: float = 10_000.0,
    cache: Params | None = None,         # {"k","v","len"} for decode
    kv_source: jnp.ndarray | None = None,  # cross-attention context
) -> tuple[jnp.ndarray, Params | None]:
    from ..dist import context as dist_context

    b, s, _ = x.shape
    q = dense_apply(p["wq"], x).reshape(b, s, n_heads, head_dim)
    src = x if kv_source is None else kv_source
    k = dense_apply(p["wk"], src).reshape(b, src.shape[1], n_kv, head_dim)
    v = dense_apply(p["wv"], src).reshape(b, src.shape[1], n_kv, head_dim)
    ctx = dist_context.current()
    if ctx is not None:
        # explicit head shardings: never let the partitioner split head_dim
        # (for head counts not dividing the TP axis it otherwise factors the
        # contraction dim and emits an all-reduce per attention chunk pair)
        q = ctx.constrain_heads(q)
        k = ctx.constrain_heads(k)
        v = ctx.constrain_heads(v)

    new_cache = None
    if kv_source is not None:
        # cross-attention: no positional rotation of image/context tokens
        out = _attend_tp(q, k, v, n_heads, head_dim, causal=False)
    elif cache is not None:
        offset = cache["len"]
        q = apply_rope(q, offset + jnp.arange(s), rope_theta)
        k = apply_rope(k, offset + jnp.arange(s), rope_theta)
        ck, cv, clen = cache["k"], cache["v"], cache["len"]
        k = k.astype(ck.dtype)
        v = v.astype(cv.dtype)
        max_len = ck.shape[1]
        if window > 0 and max_len == window:
            # ring buffer for local attention: O(window) cache.  Decode
            # (s == 1) uses dynamic_update_slice (partitioner-friendly);
            # multi-token writes fall back to a scatter.
            if s == 1:
                pos = clen % window
                ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, 1)
            else:
                idx = (clen + jnp.arange(s)) % window
                ck = ck.at[:, idx].set(k)
                cv = cv.at[:, idx].set(v)
            # unroll ring chronologically with the valid entries front-aligned
            valid = jnp.minimum(clen + s, window)
            order = (clen + s - valid + jnp.arange(window)) % window
            k_all = jnp.take(ck, order, axis=1)
            v_all = jnp.take(cv, order, axis=1)
            out = dense_attention(
                q, k_all, v_all, causal=True, q_offset=valid - s,
                kv_len=valid,
            )
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, clen, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, clen, 1)
            k_all, v_all = ck, cv
            out = dense_attention(
                q, k_all, v_all, causal=causal, window=window,
                q_offset=clen, kv_len=clen + s,
            )
        new_cache = {"k": ck, "v": cv, "len": clen + s}
    else:
        pos = jnp.arange(s)
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
        out = _attend_tp(q, k, v, n_heads, head_dim, causal=causal,
                         window=window)

    # attention over a higher-precision cache must not promote the residual
    out = out.astype(x.dtype)
    y = dense_apply(p["wo"], out.reshape(b, s, n_heads * head_dim))
    return y, new_cache


def gqa_init_cache(b: int, max_len: int, n_kv: int, head_dim: int, *,
                   window: int = 0, dtype=jnp.bfloat16) -> Params:
    length = window if window > 0 else max_len
    return {
        "k": jnp.zeros((b, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((b, length, n_kv, head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype=dtype),
        "wg": dense_init(k2, d_model, d_ff, dtype=dtype),
        "wo": dense_init(k3, d_ff, d_model, scale=0.02 / math.sqrt(2), dtype=dtype),
    }


def swiglu_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return dense_apply(
        p["wo"], jax.nn.silu(dense_apply(p["wg"], x)) * dense_apply(p["wi"], x)
    )


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed_apply(p: Params, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].astype(x.dtype).T
