"""Mixture-of-Experts FFN with top-k routing (+ shared experts).

Sort-free capacity dispatch: tokens pick top-k experts; within each expert
the first ``capacity`` tokens (by position-in-expert rank) are kept, the rest
drop (standard GShard/Switch semantics).  Dispatch and combine are expressed
as gather/scatter so compiled FLOPs reflect *active* expert compute
(tokens x k), not dense all-expert compute — this is what makes the MoE
roofline numbers honest.

Expert weights are stacked (E, d, d_ff) so expert parallelism is a plain
sharding annotation on the leading axis.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Params, dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(
    key,
    d_model: int,
    n_experts: int,
    d_expert: int,
    *,
    n_shared: int = 0,
    d_shared: int = 0,
    dtype=jnp.float32,
) -> Params:
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 0.02 / math.sqrt(2)
    p = {
        "router": dense_init(kr, d_model, n_experts, scale=0.02, dtype=dtype),
        "wi": jax.random.normal(ki, (n_experts, d_model, d_expert), dtype) * scale_in,
        "wg": jax.random.normal(kg, (n_experts, d_model, d_expert), dtype) * scale_in,
        "wo": jax.random.normal(ko, (n_experts, d_expert, d_model), dtype) * scale_out,
    }
    if n_shared > 0:
        d_sh = (d_shared or d_expert) * n_shared
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "wi": dense_init(k1, d_model, d_sh, dtype=dtype),
            "wg": dense_init(k2, d_model, d_sh, dtype=dtype),
            "wo": dense_init(k3, d_sh, d_model, scale=scale_out, dtype=dtype),
        }
    return p


def moe_apply(
    p: Params,
    x: jnp.ndarray,                  # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    return_aux: bool = False,
):
    """Top-k MoE.  Under a distribution context with a model axis that
    divides the expert count, dispatch runs expert-parallel inside a manual
    shard_map (each device computes only its local experts; partial outputs
    psum over `model`) — both for performance and because XLA's SPMD
    scatter partitioner cannot be trusted with sharded dispatch on CPU."""
    from ..dist import context as dist_context

    e = p["wi"].shape[0]
    ctx = dist_context.current()
    if (
        not return_aux
        and ctx is not None
        and ctx.model_size > 1
        and ctx.supports_manual_subregions
    ):
        return _moe_apply_manual_ep(p, x, top_k=top_k,
                                    capacity_factor=capacity_factor, ctx=ctx)
    return _moe_apply_dense_dispatch(
        p, x, top_k=top_k, capacity_factor=capacity_factor,
        return_aux=return_aux,
    )


def _moe_apply_manual_ep(p: Params, x: jnp.ndarray, *, top_k: int,
                         capacity_factor: float, ctx):
    """Expert parallelism: experts over `model`, tokens over `data`, expert
    weights FSDP'd over `data` and all-gathered per layer inside the manual
    region (the scan-over-layers keeps exactly one gather alive at a time).

    Every device routes its own token shard and computes only its model
    column's experts for those tokens; a psum over `model` assembles the
    per-token expert sums.  Dispatch uses top-k capacity buffers written by
    ``top_k`` scatters (never a (T*k, d) repeat).  All shard_map boundaries
    and psums are f32 (XLA's bf16 AllReducePromotion CHECK-fails on CPU).
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e = p["wi"].shape[0]
    t = b * s
    dm = ctx.model_size
    dd = ctx.data_size
    # pad the expert dim to a multiple of the model axis (dummy experts hold
    # zero weights and are never routed to: the router has only `e` outputs)
    e_pad = -(-e // dm) * dm
    e_local = e_pad // dm
    shard_tokens = dd > 1 and t % dd == 0
    t_local = t // dd if shard_tokens else t
    capacity = max(1, int(capacity_factor * top_k * t_local / e))
    fsdp_w = dd > 1 and d % dd == 0
    compute_dtype = x.dtype
    f32 = jnp.float32

    def pad_experts(w):
        if e_pad == e:
            return w
        return jnp.pad(w, ((0, e_pad - e), (0, 0), (0, 0)))

    # per-shard expert offsets as a model-sharded iota (avoids axis_index,
    # whose lowering re-binds the outer manual pod axis)
    offsets = jnp.arange(dm, dtype=jnp.int32) * e_local

    def local_ep(xf32, router_w, wi, wg, wo, off):
        xf = xf32.astype(compute_dtype)          # (T_local, d)
        if fsdp_w:
            # FSDP gather of this layer's experts (f32 boundary keeps the
            # reduce-scatter cotangent f32)
            wi_ = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wg_ = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wo_ = jax.lax.all_gather(wo, "data", axis=1, tiled=True)
        else:
            wi_, wg_, wo_ = wi, wg, wo
        wi_ = wi_.astype(compute_dtype)
        wg_ = wg_.astype(compute_dtype)
        wo_ = wo_.astype(compute_dtype)
        lo = off[0]
        tl = xf.shape[0]

        logits = (xf32 @ router_w).astype(f32)
        probs = jax.nn.softmax(logits, axis=-1)                  # (Tl, E)
        gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # (Tl, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )
        onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)
        pos_in_expert = (
            jnp.cumsum(onehot.reshape(tl * top_k, e), axis=0)
            * onehot.reshape(tl * top_k, e)
        )
        pos = (pos_in_expert.max(axis=-1) - 1).reshape(tl, top_k)
        keep = pos < capacity
        is_local = (expert_idx >= lo) & (expert_idx < lo + e_local)
        keep_l = keep & is_local
        le = jnp.where(is_local, expert_idx - lo, 0)             # (Tl, K)
        pos_c = jnp.where(keep_l, pos, capacity - 1)

        buf = jnp.zeros((e_local, capacity, d), compute_dtype)
        for j in range(top_k):  # top_k scatters — no (T*k, d) repeat
            src = xf * keep_l[:, j, None].astype(compute_dtype)
            buf = buf.at[le[:, j], pos_c[:, j]].add(src)
        h = jnp.einsum("ecd,edf->ecf", buf, wi_)
        g = jnp.einsum("ecd,edf->ecf", buf, wg_)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo_)

        out = jnp.zeros((tl, d), f32)
        for j in range(top_k):
            got = y[le[:, j], pos_c[:, j]].astype(f32)
            w_j = (gate_vals[:, j] * keep_l[:, j]).astype(f32)
            out = out + got * w_j[:, None]
        return jax.lax.psum(out, "model")

    manual = {"model"} | ({"data"} if (shard_tokens or fsdp_w) else set())
    tspec = P("data") if shard_tokens else P()
    wspec = P("model", "data") if fsdp_w else P("model")
    xf = x.reshape(t, d)
    sm = ctx.shard_map(
        local_ep,
        in_specs=(tspec, P(), wspec, wspec, wspec, P("model")),
        out_specs=tspec,
        axis_names=manual,
    )
    out = sm(
        xf.astype(f32),
        p["router"]["w"].astype(f32),
        pad_experts(p["wi"]).astype(f32),
        pad_experts(p["wg"]).astype(f32),
        pad_experts(p["wo"]).astype(f32),
        offsets,
    ).astype(x.dtype)

    if "shared" in p:
        sh = p["shared"]
        from .layers import dense_apply

        hs = jax.nn.silu(dense_apply(sh["wg"], xf)) * dense_apply(sh["wi"], xf)
        out = out + dense_apply(sh["wo"], hs)
    return out.reshape(b, s, d)


def _moe_apply_dense_dispatch(
    p: Params,
    x: jnp.ndarray,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    return_aux: bool = False,
):
    b, s, d = x.shape
    e = p["wi"].shape[0]
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]["w"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)         # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * top_k * t / e))
    # position of each (token, k) assignment within its expert's queue
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)     # (T, K, E)
    flat_oh = onehot.reshape(t * top_k, e)
    pos_in_expert = jnp.cumsum(flat_oh, axis=0) * flat_oh       # rank+1 where assigned
    pos = (pos_in_expert.max(axis=-1) - 1).reshape(t, top_k)    # (T, K)
    keep = pos < capacity

    # dispatch: scatter token vectors into (E, C, d) buffers
    buf = jnp.zeros((e, capacity, d), xf.dtype)
    flat_e = expert_idx.reshape(-1)
    flat_pos = jnp.where(keep, pos, capacity - 1).reshape(-1)   # clamp; masked below
    flat_keep = keep.reshape(-1)
    src = jnp.repeat(xf, top_k, axis=0) * flat_keep[:, None].astype(xf.dtype)
    buf = buf.at[flat_e, flat_pos].add(src)

    # expert FFN: (E, C, d) x (E, d, f)
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(xf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(xf.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(xf.dtype))

    # combine: gather each assignment's output, weight by gate
    out_tok = y[flat_e, flat_pos]                               # (T*K, d)
    out_tok = out_tok * (gate_vals.reshape(-1) * flat_keep).astype(xf.dtype)[:, None]
    out = out_tok.reshape(t, top_k, d).sum(axis=1)

    if "shared" in p:
        sh = p["shared"]
        from .layers import dense_apply

        hs = jax.nn.silu(dense_apply(sh["wg"], xf)) * dense_apply(sh["wi"], xf)
        out = out + dense_apply(sh["wo"], hs)

    out = out.reshape(b, s, d)
    if not return_aux:
        return out
    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    frac_tokens = onehot.astype(jnp.float32).sum(axis=(0, 1)) / (t * top_k)
    frac_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - flat_keep.astype(jnp.float32).mean()
    return out, {"aux_loss": aux, "drop_rate": dropped}
