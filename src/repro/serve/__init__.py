"""``repro.serve`` — the million-user read serving plane.

The third plane of the repo (beside the device plane ``repro.dist`` and the
WAN synchronization plane ``repro.core``): region-affine client populations
issue follower reads against their node's possibly-stale snapshot view —
the per-node ``DeltaCRDTStore`` views the streaming engine advances at
measured ``node_commit_ms`` times — under staleness-bounded read semantics
with redirect/reject policies and cache-aside accounting.  Wire it through
``EngineConfig(streaming=True, serve=ServeConfig(...))``; the run's
:class:`~repro.serve.stats.ServeStats` lands on ``RunStats.serve``.
"""

from .config import ServeConfig
from .plane import (
    ServingSink,
    redirect_policy,
    reject_policy,
    simulate_serving,
    view_epochs,
    view_staleness_ms,
)
from .stats import EpochServeStats, ServeStats, ServeTotals, weighted_percentile

__all__ = [
    "ServeConfig",
    "ServeStats",
    "ServeTotals",
    "ServingSink",
    "EpochServeStats",
    "simulate_serving",
    "view_epochs",
    "view_staleness_ms",
    "redirect_policy",
    "reject_policy",
    "weighted_percentile",
]
