"""Serving-plane statistics: per-epoch counters + run-level aggregates.

All counts are *expected* read counts (floats): the plane evaluates each
(node, epoch) client bucket analytically, so populations scale to millions
of simulated clients without per-request loops and every aggregate is
deterministic — which is what makes the monotonicity gates in
``benchmarks/bench_serving.py`` exact rather than statistical.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["EpochServeStats", "ServeStats", "ServeTotals", "weighted_percentile"]


def weighted_percentile(
    values: np.ndarray, weights: np.ndarray, q: float
) -> float:
    """q-th percentile (0..100) of a weighted discrete distribution.

    The serving plane's latency distribution has a handful of distinct
    values (cache hit / local read / per-target redirect RTTs) carrying
    millions of reads each, so the weighted form is exact where sampling
    would be both slow and noisy.
    """
    values = np.asarray(values, dtype=float)
    weights = np.asarray(weights, dtype=float)
    keep = weights > 0.0
    values, weights = values[keep], weights[keep]
    if values.size == 0:
        return 0.0
    order = np.argsort(values)
    values, weights = values[order], weights[order]
    cum = np.cumsum(weights)
    target = q / 100.0 * cum[-1]
    idx = int(np.searchsorted(cum, target, side="left"))
    return float(values[min(idx, values.size - 1)])


@dataclasses.dataclass
class EpochServeStats:
    """One epoch's serving outcome, summed over every node's client bucket.

    ``redirected`` counts every read whose local view violated the
    staleness bound and was *sent* to the freshest replica (the redirect
    decision is made at the serving node); ``rejected`` is the subset whose
    target was itself over-bound on arrival — so ``rejected <=
    redirected`` under the ``redirect`` policy, and served reads are
    ``reads - rejected``.
    """

    epoch: int
    reads: float
    writes: float
    served_local: float       # within-bound, answered from the node's own view
    stale_served: float       # served_local subset with a non-zero view lag
    redirected: float
    rejected: float
    cache_hits: float
    cache_misses: float
    view_staleness_ms_mean: float
    view_staleness_ms_max: float

    @property
    def served(self) -> float:
        return self.reads - self.rejected


@dataclasses.dataclass
class ServeTotals:
    """Run-level serving counters, accumulated online by
    :class:`~repro.serve.plane.ServingSink` in epoch order — the same
    left-fold the ``ServeStats`` summing properties perform over a retained
    ``epochs`` list, so the totals are byte-identical whether or not the
    per-epoch list is kept (``ServeConfig(keep_epochs=False)``)."""

    reads: float = 0.0
    writes: float = 0.0
    served: float = 0.0
    served_local: float = 0.0
    stale_served: float = 0.0
    redirected: float = 0.0
    rejected: float = 0.0
    cache_hits: float = 0.0
    cache_misses: float = 0.0


@dataclasses.dataclass
class ServeStats:
    """Run-level serving-plane report (attached as ``RunStats.serve``).

    ``latency_values_ms`` / ``latency_weights`` hold the exact weighted
    read-latency distribution (one entry per distinct latency class, with
    per-class weights summed across epochs); percentiles are computed from
    it on demand.  ``totals`` carries the online run counters; the summing
    properties read it when present and fall back to folding ``epochs``
    (hand-constructed instances, pre-sink pickles).  Under
    ``ServeConfig(keep_epochs=False)`` the ``epochs`` list is empty and
    ``totals`` is the only counter surface.
    """

    epochs: list[EpochServeStats]
    latency_values_ms: np.ndarray
    latency_weights: np.ndarray
    wall_ms: float
    max_staleness_ms: float
    policy: str
    totals: ServeTotals | None = None

    # -- totals ---------------------------------------------------------------

    @property
    def reads_total(self) -> float:
        if self.totals is not None:
            return self.totals.reads
        return sum(e.reads for e in self.epochs)

    @property
    def writes_total(self) -> float:
        if self.totals is not None:
            return self.totals.writes
        return sum(e.writes for e in self.epochs)

    @property
    def served_reads(self) -> float:
        if self.totals is not None:
            return self.totals.served
        return sum(e.served for e in self.epochs)

    @property
    def served_local(self) -> float:
        if self.totals is not None:
            return self.totals.served_local
        return sum(e.served_local for e in self.epochs)

    @property
    def stale_served(self) -> float:
        if self.totals is not None:
            return self.totals.stale_served
        return sum(e.stale_served for e in self.epochs)

    @property
    def redirected(self) -> float:
        if self.totals is not None:
            return self.totals.redirected
        return sum(e.redirected for e in self.epochs)

    @property
    def rejected(self) -> float:
        if self.totals is not None:
            return self.totals.rejected
        return sum(e.rejected for e in self.epochs)

    @property
    def cache_hits(self) -> float:
        if self.totals is not None:
            return self.totals.cache_hits
        return sum(e.cache_hits for e in self.epochs)

    @property
    def cache_misses(self) -> float:
        if self.totals is not None:
            return self.totals.cache_misses
        return sum(e.cache_misses for e in self.epochs)

    # -- rates ---------------------------------------------------------------

    @property
    def redirect_rate(self) -> float:
        t = self.reads_total
        return self.redirected / t if t else 0.0

    @property
    def reject_rate(self) -> float:
        t = self.reads_total
        return self.rejected / t if t else 0.0

    @property
    def stale_serve_rate(self) -> float:
        t = self.reads_total
        return self.stale_served / t if t else 0.0

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0

    @property
    def throughput_rps(self) -> float:
        """Served-read throughput over the run's measured wall-clock — the
        headline user-facing metric (rejected reads don't count)."""
        w = self.wall_ms / 1e3
        return self.served_reads / w if w > 0 else 0.0

    # -- latency --------------------------------------------------------------

    @property
    def read_latency_p50_ms(self) -> float:
        return weighted_percentile(
            self.latency_values_ms, self.latency_weights, 50.0
        )

    @property
    def read_latency_p99_ms(self) -> float:
        return weighted_percentile(
            self.latency_values_ms, self.latency_weights, 99.0
        )

    def summary(self) -> dict:
        """Plain-dict digest for benchmark JSON output."""
        return {
            "policy": self.policy,
            "max_staleness_ms": self.max_staleness_ms,
            "reads_total": self.reads_total,
            "served_reads": self.served_reads,
            "throughput_rps": self.throughput_rps,
            "redirect_rate": self.redirect_rate,
            "reject_rate": self.reject_rate,
            "stale_serve_rate": self.stale_serve_rate,
            "cache_hit_rate": self.cache_hit_rate,
            "read_latency_p50_ms": self.read_latency_p50_ms,
            "read_latency_p99_ms": self.read_latency_p99_ms,
        }
