"""Serving-plane configuration: region-affine client populations.

Every node fronts its own client population (the region-affinity model:
users hit the replica their region routes to, as GaussDB-Global serves
geo-distributed reads off its asynchronous standbys).  Clients issue
follower reads against that node's possibly-stale snapshot view — the one
``EngineConfig(staleness_feedback=True)`` already advances at measured
stitched commit times — under **staleness-bounded read semantics**: a view
older than ``max_staleness_ms`` triggers the configured policy (redirect to
the freshest reachable replica over the WAN, or reject).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["ServeConfig"]


@dataclasses.dataclass
class ServeConfig:
    """One serving plane over a streaming ``GeoCluster`` run.

    ``clients_per_node`` is a scalar (every node fronts the same
    population) or a per-node sequence; with ``ops_per_client_s`` it fixes
    the offered load, of which ``read_ratio`` is follower reads served by
    this plane (the write fraction rides the existing OCC write path and is
    only counted).  ``cache_keys`` > 0 models a per-node cache-aside tier:
    the steady-state hit ratio is the top-``cache_keys`` probability mass
    of a Zipf(``zipf_theta``) popularity over ``n_keys`` keys.
    """

    clients_per_node: float | Sequence[float] = 200_000.0
    ops_per_client_s: float = 1.0
    read_ratio: float = 0.95
    max_staleness_ms: float = 100.0
    policy: str = "redirect"        # registered "serve_policy" strategy
    cache_keys: int = 0             # 0 = no cache tier
    n_keys: int = 10_000
    zipf_theta: float = 0.99
    cache_hit_ms: float = 0.05      # in-memory cache lookup
    local_read_ms: float = 0.5      # replica storage-engine read
    # retain the per-epoch EpochServeStats list on ServeStats.epochs (the
    # historical surface, O(E)); False keeps only the online ServeTotals +
    # aggregated latency distribution — required for bounded-memory runs
    # (EngineConfig(keep_epochs=False); rule table: repro.analysis.
    # config_check).  Totals and percentiles are identical either way.
    keep_epochs: bool = True

    def __post_init__(self):
        # both imports are deliberately lazy: this module sits on the
        # repro.core <-> repro.serve boundary (replication imports
        # ServeConfig for its EngineConfig field), so a top-level core
        # import here would turn the layering into a cycle.  Importing the
        # plane module also guarantees the policies are registered before
        # the fail-fast lookup below.
        from ..analysis.config_check import validate_config
        from ..core import strategies as _strategies
        from . import plane as _plane  # noqa: F401

        _strategies.get("serve_policy", self.policy)
        # range/shape constraints live in the declarative rule table
        # (repro.analysis.config_check) — same historical error messages
        validate_config(self)

    def clients(self, n_nodes: int) -> np.ndarray:
        c = np.asarray(self.clients_per_node, dtype=float)
        if c.ndim == 0:
            return np.full(n_nodes, float(c))
        if c.shape != (n_nodes,):
            raise ValueError(
                f"clients_per_node has shape {c.shape}, expected ({n_nodes},)"
            )
        return c.copy()

    def reads_per_epoch(self, n_nodes: int, epoch_ms: float) -> np.ndarray:
        """Expected follower reads per node per epoch window."""
        ops = self.clients(n_nodes) * self.ops_per_client_s * (epoch_ms / 1e3)
        return ops * self.read_ratio

    def writes_per_epoch(self, n_nodes: int, epoch_ms: float) -> np.ndarray:
        ops = self.clients(n_nodes) * self.ops_per_client_s * (epoch_ms / 1e3)
        return ops * (1.0 - self.read_ratio)
