"""The read serving plane: staleness-bounded follower reads on stale views.

The model (GaussDB-Global-style bounded-staleness standby reads, layered on
this repo's stitched streaming simulation):

* Node ``i``'s **view staleness** at serving time ``t`` is how far behind
  the transaction arrival stream its snapshot view is:
  ``stal_i(t) = max(0, t - v_i(t) * epoch_ms)`` where ``v_i(t)`` is the
  number of epochs whose inbound transfers the stitched simulation has
  delivered to ``i`` by ``t`` (``node_commit_ms`` — the *same* per-node
  commit signal ``staleness_feedback`` advances the ``DeltaCRDTStore``
  views on, so serving and OCC staleness are one measurement).
* Reads of epoch ``e``'s window are evaluated at the cadence arrival time
  ``e * epoch_ms`` (the same convention the OCC loop uses for optimistic
  execution), which makes every (node, epoch) client bucket a deterministic
  closed form — populations scale to millions of clients with no sampling.
* **Policy** (registered under the ``serve_policy`` strategy kind):

  - ``redirect``: a read whose local view violates ``max_staleness_ms`` is
    sent to the *freshest* replica (minimum staleness; RTT from the
    epoch's trace matrix breaks ties), paying the WAN round trip.  If even
    the freshest replica is over-bound the read is additionally counted
    ``rejected`` (the client pays a retry).  ``rejected ⊆ redirected``,
    which is what makes both counters monotone in the bound — tightening
    the bound can only grow the redirect set ``{stal_i > S}`` and the
    reject set ``{min_j stal_j > S}`` (property-tested in
    ``tests/test_property_serve.py``).
  - ``reject``: no redirects; an over-bound read fails immediately.

* **Cache-aside accounting**: each served read passes through the serving
  node's cache tier; the steady-state hit ratio is the top-``cache_keys``
  Zipf popularity mass (an ideal cache-aside cache converges to holding
  the hottest keys).  Hits cost ``cache_hit_ms``, misses pay the
  storage-engine ``local_read_ms``; redirected reads pay the RTT on top.
"""

from __future__ import annotations

import numpy as np

from ..core import strategies as _strategies
from ..core.workload import ZipfianSampler
from .config import ServeConfig
from .stats import EpochServeStats, ServeStats

__all__ = ["simulate_serving", "view_epochs", "view_staleness_ms"]

_EPS = 1e-9


# ---------------------------------------------------------------------------
# serve policies (strategy registry kind: "serve_policy")
#
# contract: fn(staleness_ms: (n,) float array, bound: float) ->
#           (local, redirect, reject) boolean masks.  `reject ⊆ redirect`
#           under policies that attempt a redirect first; `local`,
#           `redirect` partition the nodes.
# ---------------------------------------------------------------------------


@_strategies.register("serve_policy", "redirect")
def redirect_policy(staleness_ms: np.ndarray, bound: float):
    """Over-bound reads go to the freshest replica; reject only when even
    that replica violates the bound."""
    local = staleness_ms <= bound + _EPS
    redirect = ~local
    if redirect.any() and float(staleness_ms.min()) > bound + _EPS:
        reject = redirect.copy()
    else:
        reject = np.zeros_like(redirect)
    return local, redirect, reject


@_strategies.register("serve_policy", "reject")
def reject_policy(staleness_ms: np.ndarray, bound: float):
    """Strict bounded reads: an over-bound local view fails the read."""
    local = staleness_ms <= bound + _EPS
    return local, np.zeros_like(local), ~local


# ---------------------------------------------------------------------------
# view staleness from the stitched simulation's commit-time matrix
# ---------------------------------------------------------------------------


def view_epochs(commit_ms: np.ndarray, now_ms: float) -> np.ndarray:
    """Per-node count of epochs whose inbound transfers have delivered by
    ``now_ms`` — the epoch prefix each node's snapshot view has merged
    (``GeoCluster._advance_views`` uses the identical ``<= now + eps``
    convention, so serving sees exactly the OCC loop's views)."""
    return (commit_ms <= now_ms + _EPS).sum(axis=0)


def view_staleness_ms(
    commit_ms: np.ndarray, now_ms: float, epoch_ms: float
) -> np.ndarray:
    """Per-node view staleness: the age of the oldest transaction-arrival
    epoch the node has *not* merged yet (0 when fully caught up)."""
    v = view_epochs(commit_ms, now_ms)
    return np.maximum(now_ms - v * epoch_ms, 0.0)


# ---------------------------------------------------------------------------
# the serving simulation
# ---------------------------------------------------------------------------


def simulate_serving(
    cfg: ServeConfig,
    commit_ms: np.ndarray,
    lats: list[np.ndarray] | tuple[np.ndarray, ...],
    epoch_ms: float,
    wall_ms: float,
) -> ServeStats:
    """Serve every epoch's client read load against the measured views.

    ``commit_ms`` is the ``(n_epochs, n_nodes)`` per-node commit-time
    matrix of the stitched streaming run (``node_commit_ms``); ``lats`` the
    per-epoch trace latency matrices (redirect RTTs); ``wall_ms`` the
    run's measured wall-clock (throughput denominator).
    """
    commit_ms = np.asarray(commit_ms, dtype=float)
    n_epochs, n = commit_ms.shape
    policy = _strategies.get("serve_policy", cfg.policy)
    reads = cfg.reads_per_epoch(n, epoch_ms)
    writes = cfg.writes_per_epoch(n, epoch_ms)
    if cfg.cache_keys > 0:
        sampler = ZipfianSampler(
            cfg.n_keys, cfg.zipf_theta, np.random.default_rng(0)
        )
        hit = sampler.top_mass(cfg.cache_keys)
    else:
        hit = 0.0
    bound = float(cfg.max_staleness_ms)

    epochs: list[EpochServeStats] = []
    lat_values: list[float] = []
    lat_weights: list[float] = []

    def emit(value_ms: float, weight: float):
        if weight > 0.0:
            lat_values.append(float(value_ms))
            lat_weights.append(float(weight))

    for e in range(n_epochs):
        now = e * epoch_ms
        stal = view_staleness_ms(commit_ms, now, epoch_ms)
        local, redirect, reject = policy(stal, bound)
        served_redirect = redirect & ~reject

        lat_e = np.asarray(lats[min(e, len(lats) - 1)], dtype=float)
        rtt = lat_e + lat_e.T
        # freshest replica per source: minimum staleness, nearest RTT tie-break
        fresh = stal <= float(stal.min()) + _EPS
        cand = np.where(fresh[None, :], rtt, np.inf)
        target = cand.argmin(axis=1)

        local_reads = float(reads[local].sum())
        stale_local = float(reads[local & (stal > _EPS)].sum())
        redirected = float(reads[redirect].sum())
        rejected = float(reads[reject].sum())

        # latency classes: the cache tier fronts every *served* read at its
        # serving node (local or redirect target), hits and misses split
        # each bucket by the modeled steady-state hit ratio
        emit(cfg.cache_hit_ms, local_reads * hit)
        emit(cfg.local_read_ms, local_reads * (1.0 - hit))
        served_remote = 0.0
        for i in np.flatnonzero(served_redirect):
            r = float(rtt[i, target[i]])
            emit(r + cfg.cache_hit_ms, reads[i] * hit)
            emit(r + cfg.local_read_ms, reads[i] * (1.0 - hit))
            served_remote += float(reads[i])

        served = local_reads + served_remote
        epochs.append(EpochServeStats(
            epoch=e,
            reads=float(reads.sum()),
            writes=float(writes.sum()),
            served_local=local_reads,
            stale_served=stale_local,
            redirected=redirected,
            rejected=rejected,
            cache_hits=served * hit,
            cache_misses=served * (1.0 - hit),
            view_staleness_ms_mean=float(stal.mean()) if n else 0.0,
            view_staleness_ms_max=float(stal.max()) if n else 0.0,
        ))

    return ServeStats(
        epochs=epochs,
        latency_values_ms=np.asarray(lat_values, dtype=float),
        latency_weights=np.asarray(lat_weights, dtype=float),
        wall_ms=float(wall_ms),
        max_staleness_ms=bound,
        policy=cfg.policy,
    )
