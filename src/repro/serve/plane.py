"""The read serving plane: staleness-bounded follower reads on stale views.

The model (GaussDB-Global-style bounded-staleness standby reads, layered on
this repo's stitched streaming simulation):

* Node ``i``'s **view staleness** at serving time ``t`` is how far behind
  the transaction arrival stream its snapshot view is:
  ``stal_i(t) = max(0, t - v_i(t) * epoch_ms)`` where ``v_i(t)`` is the
  number of epochs whose inbound transfers the stitched simulation has
  delivered to ``i`` by ``t`` (``node_commit_ms`` — the *same* per-node
  commit signal ``staleness_feedback`` advances the ``DeltaCRDTStore``
  views on, so serving and OCC staleness are one measurement).
* Reads of epoch ``e``'s window are evaluated at the cadence arrival time
  ``e * epoch_ms`` (the same convention the OCC loop uses for optimistic
  execution), which makes every (node, epoch) client bucket a deterministic
  closed form — populations scale to millions of clients with no sampling.
* **Policy** (registered under the ``serve_policy`` strategy kind):

  - ``redirect``: a read whose local view violates ``max_staleness_ms`` is
    sent to the *freshest* replica (minimum staleness; RTT from the
    epoch's trace matrix breaks ties), paying the WAN round trip.  If even
    the freshest replica is over-bound the read is additionally counted
    ``rejected`` (the client pays a retry).  ``rejected ⊆ redirected``,
    which is what makes both counters monotone in the bound — tightening
    the bound can only grow the redirect set ``{stal_i > S}`` and the
    reject set ``{min_j stal_j > S}`` (property-tested in
    ``tests/test_property_serve.py``).
  - ``reject``: no redirects; an over-bound read fails immediately.

* **Cache-aside accounting**: each served read passes through the serving
  node's cache tier; the steady-state hit ratio is the top-``cache_keys``
  Zipf popularity mass (an ideal cache-aside cache converges to holding
  the hottest keys).  Hits cost ``cache_hit_ms``, misses pay the
  storage-engine ``local_read_ms``; redirected reads pay the RTT on top.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import strategies as _strategies
from ..core.sinks import EpochContext
from ..core.workload import ZipfianSampler
from .config import ServeConfig
from .stats import EpochServeStats, ServeStats, ServeTotals

__all__ = ["ServingSink", "simulate_serving", "view_epochs", "view_staleness_ms"]

_EPS = 1e-9


# ---------------------------------------------------------------------------
# serve policies (strategy registry kind: "serve_policy")
#
# contract: fn(staleness_ms: (n,) float array, bound: float) ->
#           (local, redirect, reject) boolean masks.  `reject ⊆ redirect`
#           under policies that attempt a redirect first; `local`,
#           `redirect` partition the nodes.
# ---------------------------------------------------------------------------


@_strategies.register("serve_policy", "redirect")
def redirect_policy(staleness_ms: np.ndarray, bound: float):
    """Over-bound reads go to the freshest replica; reject only when even
    that replica violates the bound."""
    local = staleness_ms <= bound + _EPS
    redirect = ~local
    if redirect.any() and float(staleness_ms.min()) > bound + _EPS:
        reject = redirect.copy()
    else:
        reject = np.zeros_like(redirect)
    return local, redirect, reject


@_strategies.register("serve_policy", "reject")
def reject_policy(staleness_ms: np.ndarray, bound: float):
    """Strict bounded reads: an over-bound local view fails the read."""
    local = staleness_ms <= bound + _EPS
    return local, np.zeros_like(local), ~local


# ---------------------------------------------------------------------------
# view staleness from the stitched simulation's commit-time matrix
# ---------------------------------------------------------------------------


def view_epochs(commit_ms: np.ndarray, now_ms: float) -> np.ndarray:
    """Per-node count of epochs whose inbound transfers have delivered by
    ``now_ms`` — the epoch prefix each node's snapshot view has merged
    (``GeoCluster._advance_views`` uses the identical ``<= now + eps``
    convention, so serving sees exactly the OCC loop's views)."""
    return (commit_ms <= now_ms + _EPS).sum(axis=0)


def view_staleness_ms(
    commit_ms: np.ndarray, now_ms: float, epoch_ms: float
) -> np.ndarray:
    """Per-node view staleness: the age of the oldest transaction-arrival
    epoch the node has *not* merged yet (0 when fully caught up)."""
    v = view_epochs(commit_ms, now_ms)
    return np.maximum(now_ms - v * epoch_ms, 0.0)


# ---------------------------------------------------------------------------
# the serving simulation
# ---------------------------------------------------------------------------


class ServingSink:
    """Incremental serving plane: an :class:`~repro.core.sinks.EpochSink`
    consuming commit rows + the epoch's trace matrix *as they land*.

    The batch plane received the full ``(E, n)`` commit matrix at end of
    run and counted, per serving epoch, how many epochs each node had
    merged (``view_epochs``).  This sink maintains per-node merged-prefix
    pointers over a sliding window of pushed commit rows instead, advancing
    each pointer while the next retained row is delivered by the epoch's
    serving time, and evicting rows below the slowest pointer — memory
    O(max view lag · n), not O(E · n).

    **Soundness / byte-identity**: commit columns are non-decreasing
    (``node_commit_ms`` folds rows with a cumulative max — a requirement on
    inputs to this plane), so the epochs delivered by ``now`` form a
    contiguous prefix of the full matrix and the pointer equals the batch
    count wherever it matters: the two can differ only when *future* rows
    (epochs ``> e``) are already delivered at ``now = e * epoch_ms``, and
    then both view counts exceed ``now / epoch_ms``, so both staleness
    values clamp to exactly ``0.0``.  Every downstream number is a function
    of the staleness vector, hence byte-identical (``simulate_serving`` is
    a thin replay through this sink; ``tests/test_sinks.py`` gates a
    hand-written full-matrix reference against it).

    The latency distribution is aggregated by latency class
    (value -> summed weight, insertion-ordered) instead of appended per
    epoch — the serving plane emits a handful of distinct classes, so this
    is the exact same discrete distribution with per-class weights summed.
    ``ServeConfig(keep_epochs=False)`` additionally drops the per-epoch
    ``EpochServeStats`` list (the O(E) remainder); run totals always come
    from the online :class:`~repro.serve.stats.ServeTotals`.
    """

    def __init__(self, cfg: ServeConfig, n: int, epoch_ms: float):
        self.cfg = cfg
        self.n = int(n)
        self.epoch_ms = float(epoch_ms)
        self._policy = _strategies.get("serve_policy", cfg.policy)
        self._reads = cfg.reads_per_epoch(self.n, self.epoch_ms)
        self._writes = cfg.writes_per_epoch(self.n, self.epoch_ms)
        if cfg.cache_keys > 0:
            sampler = ZipfianSampler(
                cfg.n_keys, cfg.zipf_theta, np.random.default_rng(0)
            )
            self._hit = sampler.top_mass(cfg.cache_keys)
        else:
            self._hit = 0.0
        self._bound = float(cfg.max_staleness_ms)
        # sliding window of pushed commit rows: _rows[0] is absolute epoch
        # _base; rows below every node's merged-prefix pointer are evicted
        self._rows: list[np.ndarray] = []
        self._base = 0
        self._view = np.zeros(self.n, dtype=np.int64)
        self._next = 0
        self._epochs: list[EpochServeStats] = []
        self._totals = ServeTotals()
        self._lat: dict[float, float] = {}

    def _emit(self, value_ms: float, weight: float) -> None:
        if weight > 0.0:
            v = float(value_ms)
            self._lat[v] = self._lat.get(v, 0.0) + float(weight)

    def push(self, epoch: int, commit_row: np.ndarray, lat: np.ndarray) -> None:
        """Serve epoch ``epoch``'s client read load against the views
        implied by the commit rows pushed so far.  ``commit_row`` is the
        epoch's cumulative per-node commit row (``node_commit_ms[epoch]``
        semantics — its columns must be non-decreasing across pushes),
        ``lat`` the epoch's trace latency matrix (redirect RTTs).  Epochs
        must be pushed in order, exactly once."""
        if epoch != self._next:
            raise ValueError(
                f"ServingSink epochs must arrive in order: got {epoch}, "
                f"expected {self._next}"
            )
        self._next = epoch + 1
        self._rows.append(np.asarray(commit_row, dtype=float))
        now = epoch * self.epoch_ms
        # advance merged-prefix pointers (amortized O(1) per node per epoch:
        # each pointer only ever moves forward)
        for i in range(self.n):
            v = int(self._view[i])
            while v <= epoch and self._rows[v - self._base][i] <= now + _EPS:
                v += 1
            self._view[i] = v
        stal = np.maximum(now - self._view * self.epoch_ms, 0.0)
        # rows below the slowest pointer can never be read again
        floor = int(self._view.min()) if self.n else 0
        if floor > self._base:
            del self._rows[: floor - self._base]
            self._base = floor

        n = self.n
        reads = self._reads
        hit = self._hit
        local, redirect, reject = self._policy(stal, self._bound)
        served_redirect = redirect & ~reject

        lat_e = np.asarray(lat, dtype=float)
        rtt = lat_e + lat_e.T
        # freshest replica per source: minimum staleness, nearest RTT tie-break
        fresh = stal <= float(stal.min()) + _EPS
        cand = np.where(fresh[None, :], rtt, np.inf)
        target = cand.argmin(axis=1)

        local_reads = float(reads[local].sum())
        stale_local = float(reads[local & (stal > _EPS)].sum())
        redirected = float(reads[redirect].sum())
        rejected = float(reads[reject].sum())

        # latency classes: the cache tier fronts every *served* read at its
        # serving node (local or redirect target), hits and misses split
        # each bucket by the modeled steady-state hit ratio
        self._emit(self.cfg.cache_hit_ms, local_reads * hit)
        self._emit(self.cfg.local_read_ms, local_reads * (1.0 - hit))
        served_remote = 0.0
        for i in np.flatnonzero(served_redirect):
            r = float(rtt[i, target[i]])
            self._emit(r + self.cfg.cache_hit_ms, reads[i] * hit)
            self._emit(r + self.cfg.local_read_ms, reads[i] * (1.0 - hit))
            served_remote += float(reads[i])

        served = local_reads + served_remote
        es = EpochServeStats(
            epoch=epoch,
            reads=float(reads.sum()),
            writes=float(self._writes.sum()),
            served_local=local_reads,
            stale_served=stale_local,
            redirected=redirected,
            rejected=rejected,
            cache_hits=served * hit,
            cache_misses=served * (1.0 - hit),
            view_staleness_ms_mean=float(stal.mean()) if n else 0.0,
            view_staleness_ms_max=float(stal.max()) if n else 0.0,
        )
        # epoch-order left folds: byte-identical to summing a retained list
        t = self._totals
        t.reads += es.reads
        t.writes += es.writes
        t.served += es.served
        t.served_local += es.served_local
        t.stale_served += es.stale_served
        t.redirected += es.redirected
        t.rejected += es.rejected
        t.cache_hits += es.cache_hits
        t.cache_misses += es.cache_misses
        if self.cfg.keep_epochs:
            self._epochs.append(es)

    def on_epoch(self, stats, ctx: EpochContext | None = None) -> None:
        """EpochSink entry point: serve from the engine's per-epoch push."""
        if ctx is None or ctx.commit_row is None or ctx.lat is None:
            raise ValueError(
                "ServingSink requires an EpochContext carrying the epoch's "
                "commit_row and lat (streaming engine only)"
            )
        self.push(ctx.epoch, ctx.commit_row, ctx.lat)

    def finish(self, wall_ms: float) -> ServeStats:
        """Assemble the run-level report.  ``wall_ms`` is the run's measured
        wall-clock (throughput denominator)."""
        return ServeStats(
            epochs=list(self._epochs),
            latency_values_ms=np.asarray(list(self._lat.keys()), dtype=float),
            latency_weights=np.asarray(list(self._lat.values()), dtype=float),
            wall_ms=float(wall_ms),
            max_staleness_ms=self._bound,
            policy=self.cfg.policy,
            totals=dataclasses.replace(self._totals),
        )


def simulate_serving(
    cfg: ServeConfig,
    commit_ms: np.ndarray,
    lats,
    epoch_ms: float,
    wall_ms: float,
) -> ServeStats:
    """Serve every epoch's client read load against the measured views —
    a thin batch wrapper replaying a full commit matrix through
    :class:`ServingSink` (the results are identical by construction; the
    incremental engine drives the sink directly).

    ``commit_ms`` is the ``(n_epochs, n_nodes)`` per-node commit-time
    matrix of the stitched streaming run (``node_commit_ms`` — its columns
    are non-decreasing, which the sink's prefix pointers rely on); ``lats``
    indexes the per-epoch trace latency matrices (redirect RTTs; a list or
    an :class:`~repro.core.simulator.EpochLatencyCycle`); ``wall_ms`` the
    run's measured wall-clock (throughput denominator).
    """
    commit_ms = np.asarray(commit_ms, dtype=float)
    n_epochs, n = commit_ms.shape
    sink = ServingSink(cfg, n, epoch_ms)
    for e in range(n_epochs):
        sink.push(e, commit_ms[e], lats[min(e, len(lats) - 1)])
    return sink.finish(wall_ms)
