"""Fault-tolerant training loop.

Composes the jitted train step with:

* periodic + async checkpointing (restart-safe, elastic restore),
* **network-adaptive synchronization**: the trainer subscribes to a
  ``repro.control.ControlPlane`` — the same instance the WAN plane can
  observe.  On :class:`~repro.control.events.RelayOrderChanged` (or any
  event the configured ``device_sync`` strategy declares a reaction to in
  the registry) it rebuilds the jitted step with the new ``relay_psum``
  ring order / :class:`SyncConfig`.  Sustained straggler trips feed
  ``ControlPlane.force_replan`` — the immediate, event-driven replan path,
* **failure handling**: a step that raises (device loss) rolls back to the
  last checkpoint; duplicate replays are harmless because the optimizer
  state is versioned by ``step`` (applying the same step twice from the same
  checkpoint is deterministic and idempotent at the state level).

The pre-control ``on_straggler`` callback is deprecated: it carried no
typed payload and bypassed the strategy registry.  Pass ``control=`` a
:class:`~repro.control.plane.ControlPlane` instead.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as _ckpt_pkg  # noqa: F401  (namespace)
from ..checkpoint.checkpoint import latest_step, restore, save, save_async
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, SyntheticLM
from ..dist.collectives import SyncConfig
from ..models.model import init_params
from ..optim.adamw import adamw_init
from .train_step import TrainConfig, build_train_step

__all__ = ["TrainerConfig", "Trainer", "StragglerMonitor"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0
    straggler_threshold: float = 1.5   # step time vs EWMA
    straggler_sustain: int = 3
    control_every: int = 1             # pump the ControlPlane every N steps


class StragglerMonitor:
    """EWMA step-time tracker with sustained-deviation detection —
    the same damping policy as the WAN replanner (Sec 4.2)."""

    def __init__(self, threshold: float = 1.5, sustain: int = 3, alpha: float = 0.2):
        self.threshold = threshold
        self.sustain = sustain
        self.alpha = alpha
        self.ewma: float | None = None
        self._over = 0
        self.trips = 0

    def observe(self, dt: float) -> bool:
        """Feed one step time; returns True when mitigation should trigger."""
        if self.ewma is None:
            self.ewma = dt
            return False
        trigger = False
        if dt > self.threshold * self.ewma:
            self._over += 1
            if self._over >= self.sustain:
                trigger = True
                self.trips += 1
                self._over = 0
        else:
            self._over = 0
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return trigger


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        mesh,
        tcfg: TrainConfig,
        run_cfg: TrainerConfig,
        data_cfg: DataConfig | None = None,
        *,
        control: "Any | None" = None,
        on_straggler: Callable[["Trainer"], None] | None = None,
    ):
        """``control`` is a ``repro.control.ControlPlane``; the trainer
        subscribes for network events and, when the plane carries its own
        ``NetworkView``, pumps one control round every
        ``run_cfg.control_every`` steps.  A plane without a view (shared
        with a WAN-plane driver) is subscribe-only."""
        self.model_cfg = model_cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.run_cfg = run_cfg
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=model_cfg.vocab_size, seq_len=128, global_batch=8,
            seed=run_cfg.seed,
        )
        self.data = SyntheticLM(self.data_cfg)
        self.make_jit, self.shardings = build_train_step(model_cfg, mesh, tcfg)
        self.monitor = StragglerMonitor(
            run_cfg.straggler_threshold, run_cfg.straggler_sustain
        )
        if on_straggler is not None:
            warnings.warn(
                "Trainer(on_straggler=...) is deprecated; pass control= a "
                "repro.control.ControlPlane and subscribe to its typed "
                "NetworkEvents instead",
                DeprecationWarning,
                stacklevel=2,
            )
        self.on_straggler = on_straggler
        self.control = control
        self.network_events: list[Any] = []
        self.sync_rebuilds = 0
        if control is not None:
            control.subscribe(self._on_network_event)
        self._pending_save = None
        self.history: list[dict[str, float]] = []

        self.params = init_params(model_cfg, jax.random.PRNGKey(run_cfg.seed))
        self.params = jax.tree.map(
            lambda p: p.astype(tcfg.param_dtype), self.params
        )
        self.opt_state = adamw_init(self.params, tcfg.optim)
        self.residuals = (
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), self.params)
            if tcfg.sync.needs_residuals
            else None
        )
        self.step_idx = 0
        self._step_fn = None

    # -- control-plane plumbing --------------------------------------------------

    def _on_network_event(self, event) -> None:
        """Apply the configured strategy's declared reaction to a network
        event: an updated ``SyncConfig`` rebuilds the jitted step (new
        relay ring order, density, ...); ``None`` means no reaction."""
        self.network_events.append(event)
        spec = self.tcfg.sync.spec
        if spec.react is None:
            return
        new_sync = spec.react(self.tcfg.sync, event)
        if new_sync is None or new_sync == self.tcfg.sync:
            return
        n_pods = self.mesh.shape.get("pod", 1)
        if new_sync.ring_order is not None and len(new_sync.ring_order) != n_pods:
            return  # event from a view whose nodes are not this mesh's pods
        self.tcfg = dataclasses.replace(self.tcfg, sync=new_sync)
        self.make_jit, self.shardings = build_train_step(
            self.model_cfg, self.mesh, self.tcfg
        )
        self._step_fn = None  # recompile with the new collective program
        self.sync_rebuilds += 1

    # -- checkpoint plumbing ---------------------------------------------------

    def _state(self):
        st = {"params": self.params, "opt": self.opt_state, "step": self.step_idx}
        if self.residuals is not None:
            st["residuals"] = self.residuals
        return st

    def save_ckpt(self):
        if self.run_cfg.ckpt_dir is None:
            return
        if self._pending_save is not None:
            self._pending_save.join()
        st = self._state()
        if self.run_cfg.ckpt_async:
            self._pending_save = save_async(self.run_cfg.ckpt_dir, self.step_idx, st)
        else:
            save(self.run_cfg.ckpt_dir, self.step_idx, st)

    def maybe_resume(self) -> bool:
        if self.run_cfg.ckpt_dir is None:
            return False
        last = latest_step(self.run_cfg.ckpt_dir)
        if last is None:
            return False
        like = self._state()
        st = restore(self.run_cfg.ckpt_dir, last, like)
        self.params = st["params"]
        self.opt_state = st["opt"]
        self.residuals = st.get("residuals", self.residuals)
        self.step_idx = int(st["step"])
        return True

    # -- main loop ---------------------------------------------------------------

    def _build(self, batch):
        if self._step_fn is None:
            self._step_fn = self.make_jit(batch)
        return self._step_fn

    def run(self, *, fault_injector: Callable[[int], None] | None = None):
        cfg = self.run_cfg
        start = self.step_idx
        while self.step_idx < cfg.steps:
            batch = {
                k: jnp.asarray(v)
                for k, v in self.data.batch(self.step_idx).items()
            }
            step = self._build(batch)
            t0 = time.perf_counter()
            try:
                if fault_injector is not None:
                    fault_injector(self.step_idx)
                out = step(self.params, self.opt_state, self.residuals, batch)
                self.params, self.opt_state, self.residuals, metrics = out
                jax.block_until_ready(metrics["loss"])
            except _RECOVERABLE as e:  # device failure: roll back + replay
                resumed = self.maybe_resume()
                if not resumed:
                    raise
                self._step_fn = None  # rebuild on (possibly new) topology
                continue
            dt = time.perf_counter() - t0
            self.step_idx += 1
            rec = {
                "step": self.step_idx,
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics["grad_norm"]),
                "dt": dt,
            }
            self.history.append(rec)
            if (
                self.control is not None
                and self.control.view is not None
                and self.step_idx % max(1, cfg.control_every) == 0
            ):
                self.control.step()  # probe -> damped replan -> events
            if self.monitor.observe(dt):
                if self.control is not None:
                    # sustained step-time degradation: event-driven replan,
                    # effective immediately (not at the next observation)
                    self.control.force_replan(
                        reason=f"straggler@step{self.step_idx}"
                    )
                if self.on_straggler is not None:
                    self.on_straggler(self)
            if cfg.ckpt_dir and self.step_idx % cfg.ckpt_every == 0:
                self.save_ckpt()
            if self.step_idx % cfg.log_every == 0 or self.step_idx == cfg.steps:
                print(
                    f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
                    f"gnorm {rec['grad_norm']:.3f}  {dt*1e3:.0f} ms"
                )
        if self._pending_save is not None:
            self._pending_save.join()
        return self.history


class FaultInjected(RuntimeError):
    """Raised by test fault injectors to simulate a device failure."""


_RECOVERABLE = (FaultInjected,)
